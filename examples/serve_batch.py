"""Batched serving example: prefill a batch of prompts, then decode with a
shared KV cache — greedy continuation of synthetic prompts.

  PYTHONPATH=src python examples/serve_batch.py --arch gemma3_1b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve import make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones(
            (args.batch, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * .1
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones(
            (args.batch, cfg.n_patches, cfg.d_model), jnp.float32) * .1

    max_seq = args.prompt_len + cfg.n_patches + args.tokens
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: T.prefill_forward(cfg, p, b, max_seq=max_seq)
    )(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    print(f"prefill {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    out = [tok]
    pos0 = args.prompt_len + (cfg.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(args.tokens - 1):
        tok, cache = decode(params, cache, tok,
                            jnp.asarray(pos0 + i, jnp.int32))
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens-1} steps in {dt:.2f}s "
          f"({args.batch*(args.tokens-1)/max(dt,1e-9):.1f} tok/s)")
    print("generated ids:\n", np.asarray(gen))


if __name__ == "__main__":
    main()
