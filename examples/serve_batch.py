"""Batched serving example — measured and modeled in one script.

Default mode runs the real JAX path: prefill a batch of prompts, then
greedy decode with a shared KV cache.  ``--simulate`` replays a synthetic
request trace against the same batching policy through the serving
simulator (``repro.sim.serving``) instead — the scenario analogue of
``examples/camera_pipeline.py``'s measured-ISP + modeled-DNN split.  Both
modes share the ``repro.serve.policy`` dataclasses: the measured batch is
sized by ``policy.max_batch``; the simulator replays the full admission /
eviction semantics.

  PYTHONPATH=src python examples/serve_batch.py --arch gemma3_1b --tokens 16
  PYTHONPATH=src python examples/serve_batch.py --simulate \\
      --policy continuous --rate 50 --requests 64
"""
import argparse
import time


def run_measured(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serve import get_policy, make_decode_step

    policy = get_policy(args.policy, max_batch=args.batch)
    cfg = get_smoke_config(args.arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch_n = policy.max_batch
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (batch_n, args.prompt_len)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones(
            (batch_n, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * .1
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones(
            (batch_n, cfg.n_patches, cfg.d_model), jnp.float32) * .1

    max_seq = args.prompt_len + cfg.n_patches + args.tokens
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: T.prefill_forward(cfg, p, b, max_seq=max_seq)
    )(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    print(f"prefill {batch_n}x{args.prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    out = [tok]
    pos0 = args.prompt_len + (cfg.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(args.tokens - 1):
        tok, cache = decode(params, cache, tok,
                            jnp.asarray(pos0 + i, jnp.int32))
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens-1} steps in {dt:.2f}s "
          f"({batch_n*(args.tokens-1)/max(dt,1e-9):.1f} tok/s)")
    print("generated ids:\n", np.asarray(gen))


def run_simulated(args):
    from repro.apps.serving import serve_trace

    # model the same reduced config the measured mode runs (--full for the
    # registry's full-size config), so the two modes stay comparable
    res = serve_trace(args.arch, args.policy, rate_rps=args.rate,
                      n_requests=args.requests, max_batch=args.batch,
                      seed=args.seed, smoke=not args.full)
    s = res.stats()
    print(f"simulated {args.requests} requests @ {args.rate:g} req/s on "
          f"{args.arch}{'' if args.full else ' (smoke config)'} "
          f"({args.policy} batching, max_batch={args.batch}):")
    print(f"  wall {s['makespan_s']:.3f}s "
          f"(engine busy {res.engine.makespan:.3f}s), "
          f"{s['n_steps']:.0f} scheduler steps")
    print(f"  throughput {s['throughput_tok_s']:.0f} tok/s "
          f"({s['throughput_req_s']:.1f} req/s), "
          f"occupancy {s['occupancy']:.2f}")
    print(f"  TTFT p50/p99 {s['ttft_p50']*1e3:.4g}/{s['ttft_p99']*1e3:.4g} "
          f"ms, TPOT p50 {s['tpot_p50']*1e3:.4g} ms")
    b = res.engine.breakdown.fractions()
    print(f"  breakdown: accel {b['accelerator']*100:.0f}% / transfer "
          f"{b['transfer']*100:.0f}% / host {b['host']*100:.0f}%")
    print(res.wall_timeline().ascii(width=64))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--policy", default="static",
                    choices=["static", "dynamic", "continuous"])
    ap.add_argument("--simulate", action="store_true",
                    help="replay a synthetic trace through the serving "
                         "simulator instead of running the JAX path")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="(simulate) arrival rate, requests/s")
    ap.add_argument("--requests", type=int, default=64,
                    help="(simulate) trace length")
    ap.add_argument("--full", action="store_true",
                    help="(simulate) model the full-size registry config "
                         "instead of the smoke config the measured mode "
                         "runs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.simulate:
        run_simulated(args)
    else:
        run_measured(args)


if __name__ == "__main__":
    main()
