"""End-to-end training driver: data pipeline -> sharded train step ->
async checkpointing -> restart/restore.  The same script scales from this
CPU container (--preset cpu-small: ~5M params, a few hundred steps) to the
production pod (--preset pod: full config + 16x16 mesh via launch/train.py).

  PYTHONPATH=src python examples/train_lm.py --steps 60 --preset cpu-small
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataPipeline
from repro.train import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preset", default="cpu-small",
                    choices=["cpu-small", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.preset == "cpu-small"
           else get_config(args.arch))
    # a ~5M-param config that actually trains in CPU minutes
    if args.preset == "cpu-small":
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, n_heads=8,
                                  n_kv_heads=4, d_ff=704, vocab=2048)
    tc = TrainConfig(lr=1e-3, warmup=20, total_steps=args.steps)
    params, opt, axes, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        out = mgr.restore(template={"params": params, "opt": opt})
        params, opt = out["tree"]["params"], out["tree"]["opt"]
        start = out["step"] + 1
        print(f"resumed from step {out['step']}")

    pipe = DataPipeline(cfg, args.batch, args.seq, n_workers=2, prefetch=2)
    try:
        t0 = time.time()
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt, metrics = step_fn(params, opt, batch,
                                           jnp.asarray(i, jnp.int32))
            if i % 10 == 0 or i == args.steps - 1:
                tok_s = (i - start + 1) * args.batch * args.seq \
                    / (time.time() - t0)
                print(f"step {i:4d} loss={float(metrics['loss']):.3f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"tok/s={tok_s:.0f}", flush=True)
            if i and i % args.ckpt_every == 0:
                mgr.save_async(i, {"params": params, "opt": opt})
        mgr.save_async(args.steps - 1, {"params": params, "opt": opt})
        mgr.wait()
        print(f"done; checkpoints in {args.ckpt_dir}")
    finally:
        pipe.stop()


if __name__ == "__main__":
    main()
