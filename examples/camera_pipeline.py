"""Camera-powered deep learning pipeline (paper §V): raw 720p Bayer frame ->
JAX ISP -> downsample -> CNN10 classifier, against a 33 ms frame budget,
with the Fig 19-style execution timeline.

The simulated part goes through the unified engine's sweep layer
(``repro.sim.sweep``): one memoized lowering of CNN10, evaluated under the
SoC config — flipping the config grid (workers, interface, datapath)
explores the design space without re-lowering.

  PYTHONPATH=src python examples/camera_pipeline.py
"""
import time

import jax
import numpy as np

from repro.apps.paper_graphs import build_paper_graph
from repro.apps.camera import camera_pipeline
from repro.configs.paper_nets import PAPER_NETS
from repro.core.timeline import Timeline
from repro.sim import engine
from repro.sim.sweep import lower_graph, sweep


def main():
    rng = np.random.default_rng(0)
    raw = rng.random((720, 1280), dtype=np.float32)

    # warm
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(32, 32))
    jax.block_until_ready(rgb)
    t0 = time.perf_counter()
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(32, 32))
    jax.block_until_ready(rgb)
    isp_s = time.perf_counter() - t0
    print(f"ISP (720p raw -> RGB + 32x32 DNN input): {isp_s*1e3:.1f} ms")

    net = PAPER_NETS["cnn10"]
    g = build_paper_graph(net, batch=1)
    feed = {"input": np.asarray(dnn_in)[None]}
    t0 = time.perf_counter()
    out = g.execute(feed)
    dnn_s = time.perf_counter() - t0
    (logits,) = out.values()
    print(f"CNN10 inference: {dnn_s*1e3:.1f} ms, class="
          f"{int(np.argmax(logits))}")

    # simulated accelerator execution + combined frame timeline (Fig 19):
    # the CNN10 program under an 8-accelerator SoC, appended after the
    # MEASURED CPU ISP time (the modeled-ISP composition lives in
    # frame_sweep / bench_camera; using it here would count the ISP twice)
    dnn_prog = lower_graph(g, batch=1, max_tile_elems=16384)
    cfg = engine.EngineConfig(n_workers=8, interface="acp", hbm_ports=4)
    (res,) = sweep(dnn_prog, [cfg])
    tl = Timeline()
    tl.add("cpu", "isp", 0.0, isp_s, "host")
    for e in res.timeline.events:
        tl.add(e.worker, e.name, isp_s + e.start, e.duration, e.kind)
    total_ms = tl.makespan * 1e3
    print(f"\nframe time (ISP on CPU + CNN10 on 8 accelerators): "
          f"{total_ms:.1f} ms — {'MEETS' if total_ms < 33 else 'MISSES'} "
          f"the 33 ms budget")
    print(tl.ascii(width=64))


if __name__ == "__main__":
    main()
