"""Quickstart: the SMAUG-style declarative graph API (paper Fig 2) and the
full-stack evaluation loop on one residual unit.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.graph import (Graph, add, convolution, input_data, weight)
from repro.core.scheduler import simulate
from repro.core.tensor import TensorSpec
from repro.core.tiling import choose_tiling


def create_residual_unit():
    rng = np.random.default_rng(0)
    with Graph(name="residual", backend="mxu") as g:
        # tensor initialization (inside the context, as in the paper)
        inputs = input_data("input", rng.random((1, 32, 32, 8),
                                                dtype=np.float32))
        filter0 = weight("f0", rng.standard_normal((3, 3, 8, 64)) * 0.1)
        filter1 = weight("f1", rng.standard_normal((3, 3, 64, 8)) * 0.1)
        # network topology:
        x = convolution("conv0", inputs, filter0, stride=1, padding="same",
                        activation="relu")
        x = convolution("conv1", x, filter1, stride=1, padding="same")
        add("add", x, inputs, activation="relu")   # residual
    return g


def main():
    graph = create_residual_unit()
    graph.write_graph("/tmp/residual")              # graph serialization
    print(f"graph: {len(graph.nodes)} nodes -> /tmp/residual.json/.npz")

    # execute through the runtime (with automatic operator fusion)
    out = graph.execute({"input": np.random.default_rng(1).random(
        (1, 32, 32, 8), dtype=np.float32)})
    print("outputs:", {k: v.shape for k, v in out.items()})

    # the tiling optimizer at work (paper §II-B)
    spec = TensorSpec((1, 32, 32, 64), "NHWC", "float32")
    choice = choose_tiling(spec, max_tile_elems=16384, reduce_dim="C")
    print("tiling optimizer chose:", choice)

    # the runtime scheduler on 4 simulated accelerators (paper §II-C)
    tl = simulate(graph.tile_tasks(), n_workers=4)
    print(f"4-worker makespan: {tl.makespan*1e6:.1f} us, "
          f"utilization {tl.utilization():.2f}")
    print(tl.ascii(width=60))


if __name__ == "__main__":
    main()
