"""Fig 11 analogue: DMA-like vs fused/resident (ACP-analogue) data paths.

On the batched sweep layer: the network is lowered ONCE (memoized
``lower_graph``) and both interface configs run through ``sweep()`` over
the shared dependency plan; latency AND energy come out of each run."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine
from repro.sim.report import row
from repro.sim.sweep import lower_graph, sweep
from benchmarks.common import build_paper_graph

IFACE_CONFIGS = [engine.EngineConfig(n_workers=1, interface="dma"),
                 engine.EngineConfig(n_workers=1, interface="acp")]


def run(emit=print):
    rows = []
    for name, net in PAPER_NETS.items():
        g = build_paper_graph(net, batch=1)
        prog = lower_graph(g, batch=1, max_tile_elems=16384)
        dma, acp = sweep(prog, IFACE_CONFIGS)
        t_dma = dma.per_kind.get("transfer", 0.0)
        t_acp = acp.per_kind.get("transfer", 0.0)
        e_dma = dma.energy["total_j"]
        e_acp = acp.energy["total_j"]
        end_dma, end_acp = dma.makespan, acp.makespan
        rows.append(row(
            f"interfaces/{name}", end_dma,
            f"acp_us={end_acp*1e6:.1f} "
            f"e2e_speedup={end_dma/end_acp:.2f}x "
            f"xfer_speedup={t_dma/max(t_acp, 1e-12):.0f}x "
            f"energy_win={(1 - e_acp/max(e_dma, 1e-30))*100:.0f}%"
            f" (paper: 17-55% e2e speedup, <=56% energy)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
