"""Fig 11 analogue: DMA-like vs fused/resident (ACP-analogue) data paths.

For each paper network, sums the modeled inter-op transfer time + energy of
every intermediate tensor under both interface models."""
from __future__ import annotations

import numpy as np

from repro.configs.paper_nets import PAPER_NETS
from repro.core.interfaces import acp_transfer, dma_transfer
from repro.core.tiling import VMEM_BYTES
from benchmarks.common import build_paper_graph


def run(emit=print):
    from repro.core.scheduler import simulate
    rows = []
    for name, net in PAPER_NETS.items():
        g = build_paper_graph(net, batch=1)
        accel = simulate(g.tile_tasks(batch=1, max_tile_elems=16384),
                         1).makespan  # 1-accelerator compute time
        t_dma = e_dma = t_acp = e_acp = 0.0
        for node in g.nodes.values():
            if node.op in ("input", "weight"):
                continue
            nbytes = int(np.prod(node.shape)) * 4
            n_tiles = max(1, nbytes // (16384 * 4))
            d = dma_transfer(nbytes, n_transfers=n_tiles)
            resident = 1.0 if nbytes < VMEM_BYTES // 4 else 0.5
            a = acp_transfer(nbytes, resident_fraction=resident)
            t_dma += d.seconds
            e_dma += d.energy_j
            t_acp += a.seconds
            e_acp += a.energy_j
        end_dma = accel + t_dma
        end_acp = accel + t_acp
        rows.append({
            "name": f"interfaces/{name}",
            "us_per_call": round(end_dma * 1e6, 1),
            "derived": (f"acp_us={end_acp*1e6:.1f} "
                        f"e2e_speedup={end_dma/end_acp:.2f}x "
                        f"xfer_speedup={(t_dma/max(t_acp,1e-12)):.0f}x "
                        f"energy_win={(1 - e_acp/max(e_dma,1e-30))*100:.0f}%"
                        f" (paper: 17-55% e2e speedup, <=56% energy)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
