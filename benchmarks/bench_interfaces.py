"""Fig 11 analogue: DMA-like vs fused/resident (ACP-analogue) data paths.

Migrated to the unified engine: the SAME lowered program runs twice, once
with ``interface="dma"`` (software-managed HBM staging, serialized) and once
with ``interface="acp"`` (VMEM-resident producer->consumer path); latency
AND energy come out of each run."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, ir
from repro.sim.report import row
from benchmarks.common import build_paper_graph


def run(emit=print):
    rows = []
    for name, net in PAPER_NETS.items():
        g = build_paper_graph(net, batch=1)
        prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
        res = {}
        for iface in ("dma", "acp"):
            res[iface] = engine.run(prog, engine.EngineConfig(
                n_workers=1, interface=iface))
        t_dma = res["dma"].per_kind.get("transfer", 0.0)
        t_acp = res["acp"].per_kind.get("transfer", 0.0)
        e_dma = res["dma"].energy["total_j"]
        e_acp = res["acp"].energy["total_j"]
        end_dma, end_acp = res["dma"].makespan, res["acp"].makespan
        rows.append(row(
            f"interfaces/{name}", end_dma,
            f"acp_us={end_acp*1e6:.1f} "
            f"e2e_speedup={end_dma/end_acp:.2f}x "
            f"xfer_speedup={t_dma/max(t_acp, 1e-12):.0f}x "
            f"energy_win={(1 - e_acp/max(e_dma, 1e-30))*100:.0f}%"
            f" (paper: 17-55% e2e speedup, <=56% energy)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
