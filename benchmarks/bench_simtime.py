"""Fig 10 analogue: 'simulation time' — wall time to lower + compile +
analyze each architecture's production step (our pre-silicon evaluation
loop), read from the dry-run artifact."""
from __future__ import annotations

import json
from pathlib import Path


def run(emit=print):
    res_path = Path("experiments/dryrun/results.json")
    if not res_path.exists():
        return [{"name": "simtime/missing", "us_per_call": "",
                 "derived": "run repro.launch.dryrun first"}]
    res = json.loads(res_path.read_text())
    rows = []
    per_arch = {}
    for r in res.values():
        if r["status"] != "ok":
            continue
        per_arch.setdefault(r["arch"], []).append(
            r.get("lower_s", 0) + r.get("compile_s", 0))
    for arch, ts in sorted(per_arch.items()):
        rows.append({"name": f"simtime/{arch}",
                     "us_per_call": round(sum(ts) / len(ts) * 1e6, 1),
                     "derived": (f"cells={len(ts)} total_s={sum(ts):.1f} "
                                 f"(paper: minutes-to-hours per network)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
