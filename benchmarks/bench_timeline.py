"""Fig 14 analogue: accelerator-utilization timeline of VGG16's last layers
on an 8-worker system — shows the reduction-affinity under-utilization the
paper calls out, plus the camera-pipeline trace (Fig 19) in bench_camera."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.core.scheduler import simulate
from benchmarks.common import build_paper_graph


def run(emit=print):
    net = PAPER_NETS["vgg16"]
    g = build_paper_graph(net, batch=1)
    tasks = g.tile_tasks(batch=1, max_tile_elems=16384)
    tl = simulate(tasks[-120:], 8, shared_bw_penalty=0.05)
    print(tl.ascii())
    return [{"name": "timeline/vgg16_tail",
             "us_per_call": round(tl.makespan * 1e6, 1),
             "derived": f"util={tl.utilization():.2f} events={len(tl.events)}"}]


if __name__ == "__main__":
    for r in run():
        print(r)
