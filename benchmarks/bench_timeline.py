"""Fig 14 analogue: accelerator-utilization timeline of VGG16's last layers
on an 8-worker system — the reduction-affinity under-utilization the paper
calls out, rendered from an engine run (the camera-pipeline trace, Fig 19,
lives in bench_camera)."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, ir
from repro.sim.report import row
from benchmarks.common import build_paper_graph


def run(emit=print):
    net = PAPER_NETS["vgg16"]
    g = build_paper_graph(net, batch=1)
    prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
    tail = ir.Program(prog.ops[-120:], name="vgg16_tail", source="graph")
    res = engine.run(tail, engine.EngineConfig(
        n_workers=8, interface="hbm", hbm_ports=4))
    print(res.timeline.ascii())
    return [row("timeline/vgg16_tail", res.makespan,
                f"util={res.utilization():.2f} "
                f"events={len(res.timeline.events)}")]


if __name__ == "__main__":
    for r in run():
        print(r)
