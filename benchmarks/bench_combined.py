"""Fig 18 analogue: stack all three case-study optimizations (fused data
path + 8 workers + 8 host threads) per paper network and report combined
end-to-end latency reduction vs the baseline (DMA, 1 accelerator, 1
thread).  Paper: 42-80% reduction (1.8-5x).

Baseline and optimized are one two-config ``sweep()`` over the same
(memoized) lowering — interface choice, worker count, HBM ports and host
threading all compose inside one simulation instead of three separate
bolt-on sums."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine
from repro.sim.report import row
from repro.sim.sweep import lower_graph, sweep
from benchmarks.common import build_paper_graph

HOST_DISPATCH_S = 1e-6   # per-tile command-queue push (framework)
HOST_BW = 20e9           # host-side tiling/untiling memcpy bandwidth


def _config(*, n_acc, fused, host_threads):
    return engine.EngineConfig(
        n_workers=n_acc,
        interface="acp" if fused else "dma",
        hbm_ports=4,
        host_dispatch_s=HOST_DISPATCH_S,
        host_bw=HOST_BW,
        host_threads=host_threads)


CONFIGS = [_config(n_acc=1, fused=False, host_threads=1),
           _config(n_acc=8, fused=True, host_threads=8)]


def run(emit=print):
    rows = []
    for name, net in PAPER_NETS.items():
        g = build_paper_graph(net, batch=1)
        prog = lower_graph(g, batch=1, max_tile_elems=16384)
        base, opt = sweep(prog, CONFIGS)
        rows.append(row(
            f"combined/{name}", opt.makespan,
            f"baseline_us={base.makespan*1e6:.1f} "
            f"speedup={base.makespan/opt.makespan:.2f}x "
            f"reduction={(1 - opt.makespan/base.makespan)*100:.0f}% "
            f"(paper: 1.8-5x, 42-80%)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
