"""Fig 18 analogue: stack all three case-study optimizations (fused data
path + 8 workers + 8 host threads) per paper network and report combined
end-to-end latency reduction vs the baseline (DMA, 1 accelerator, 1
thread).  Paper: 42-80% reduction (1.8-5x)."""
from __future__ import annotations

import numpy as np

from repro.configs.paper_nets import PAPER_NETS
from repro.core.interfaces import acp_transfer, dma_transfer
from repro.core.scheduler import simulate
from repro.core.tiling import VMEM_BYTES
from benchmarks.common import build_paper_graph


def _endtoend(net, *, n_acc, fused, host_threads):
    g = build_paper_graph(net, batch=1)
    tasks = g.tile_tasks(batch=1, max_tile_elems=16384)
    tl = simulate(tasks, n_acc, shared_bw_penalty=0.05)
    accel = tl.makespan
    xfer = host = 0.0
    for node in g.nodes.values():
        if node.op in ("input", "weight"):
            continue
        nbytes = int(np.prod(node.shape)) * 4
        n_tiles = max(1, nbytes // (16384 * 4))
        if fused:
            resident = 1.0 if nbytes < VMEM_BYTES // 4 else 0.5
            xfer += acp_transfer(nbytes, resident).seconds
        else:
            xfer += dma_transfer(nbytes, n_tiles).seconds
        # host tiling/untiling: bandwidth-limited, scaled by threads
        host += 2 * nbytes / 20e9 / host_threads + 3e-6
    return accel + xfer + host, (accel, xfer, host)


def run(emit=print):
    rows = []
    for name, net in PAPER_NETS.items():
        base, parts_b = _endtoend(net, n_acc=1, fused=False, host_threads=1)
        opt, parts_o = _endtoend(net, n_acc=8, fused=True, host_threads=8)
        rows.append({
            "name": f"combined/{name}",
            "us_per_call": round(opt * 1e6, 1),
            "derived": (f"baseline_us={base*1e6:.1f} "
                        f"speedup={base/opt:.2f}x "
                        f"reduction={(1-opt/base)*100:.0f}% "
                        f"(paper: 1.8-5x, 42-80%)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
