"""Training study: pipeline-parallel schedule x microbatch x stage count.

The training analogue of the serving study: one simulated execution per
(model x schedule x n_stages x n_microbatches) cell through
``repro.sim.training`` — gemma-2b and tinyllama split over 1..8 pipeline
stages on a shared-link SoC, GPipe vs 1F1B, reporting step time,
tokens/s, per-stage utilization and the measured pipeline bubble next to
the analytic ``(p-1)/(m+p-1)`` bound.  The headline derived value is the
1F1B-vs-GPipe step-time ratio at the deepest pipe — and it is NOT always
>= 1 here: on a port-constrained shared link, 1F1B's steady state keeps
forward and backward weight streams in flight simultaneously across all
stages, roughly doubling link concurrency versus GPipe's phase-separated
flush, so contention can invert the textbook ordering.  The uncontended
homogeneous regime (where 1F1B provably never loses and the bubble bound
is exact) is what the ``--quick`` probes pin down.

Full mode (``python -m benchmarks.bench_training``) writes the grid and
the CI budgets to ``BENCH_training.json`` at the repo root.

``--quick`` (the ``tools/ci.sh`` gate) re-times the grid against the
recorded budget with the 2x-regression gate and runs two correctness
probes on homogeneous stage splits with an uncontended link: 1F1B never
slower than GPipe (to 1 ulp), and ideal-interface measured bubble equal
to the analytic bound.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.configs.tinyllama_1_1b import FULL as TINYLLAMA
from repro.sim.engine import EngineConfig
from repro.sim.report import row
from repro.sim.sweep import as_training_records, training_sweep
from repro.sim.training import bubble_bound, simulate_training

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_training.json"

MODELS = (GEMMA_2B, TINYLLAMA)
SCHEDULES = ("gpipe", "1f1b")
STAGE_GRID = (1, 2, 4, 8)
MB_GRID = (2, 8)
SEQ_LEN = 512
GLOBAL_BATCH = 8
# datacenter chip, shared HBM link: transfers contend, dispatch costs
CONFIG = EngineConfig(interface="hbm", hbm_ports=2, host_dispatch_s=10e-6)


def _grid():
    out = []
    for model in MODELS:
        out.extend(training_sweep(
            model, schedules=SCHEDULES, n_stages_grid=STAGE_GRID,
            n_microbatches_grid=MB_GRID, seq_len=SEQ_LEN,
            global_batch=GLOBAL_BATCH, base_config=CONFIG))
    return out


def measure():
    t0 = time.perf_counter()
    results = _grid()
    sweep_s = time.perf_counter() - t0
    records = as_training_records(results)
    rows = []
    by_cell = {}
    for res, rec in zip(results, records):
        key = (rec["model"], res.schedule, res.n_stages, res.n_microbatches)
        by_cell[key] = res
        rows.append(row(
            f"training/{rec['model']}/{res.schedule}"
            f"/p{res.n_stages}m{res.n_microbatches}",
            res.step_time_s,
            f"tok_s={res.tokens_per_s:.0f} "
            f"bubble={res.bubble_fraction:.3f} "
            f"bound={res.bubble_bound:.3f} "
            f"util={rec['stage_util_mean']:.2f}"))
    p, m = max(STAGE_GRID), max(MB_GRID)
    for model in MODELS:
        g = by_cell[(model.name, "gpipe", p, m)]
        o = by_cell[(model.name, "1f1b", p, m)]
        rows.append(row(
            f"training/{model.name}/1f1b_vs_gpipe@p{p}m{m}",
            o.step_time_s,
            f"speedup={g.step_time_s / o.step_time_s:.3f}x "
            f"({o.step_time_s*1e3:.2f} vs {g.step_time_s*1e3:.2f} ms; "
            f"<1 means shared-port contention favors the flush "
            f"schedule)"))
    out = {
        "records": records,
        "budget_s": {"training_sweep": round(sweep_s, 6)},
        "grid": {"models": [mdl.name for mdl in MODELS],
                 "schedules": list(SCHEDULES),
                 "n_stages": list(STAGE_GRID),
                 "n_microbatches": list(MB_GRID),
                 "seq_len": SEQ_LEN, "global_batch": GLOBAL_BATCH},
    }
    return out, rows, results, sweep_s


def check_probes() -> bool:
    """The training layer's cheap correctness gate, on homogeneous stage
    splits (layer count divisible by the stage count) with an uncontended
    link — the regime where 1F1B provably never loses to GPipe and the
    ideal-interface bubble equals the analytic bound exactly.  The main
    grid deliberately does NOT satisfy either premise (uneven splits,
    2-port link), which is what makes its records interesting."""
    import dataclasses
    homog = dataclasses.replace(GEMMA_2B, n_layers=16)
    # no port contention, no serial host dispatch: both are globally
    # ordered shared resources on which 1F1B's two-directions-in-flight
    # steady state can genuinely lose to a flush schedule
    cfg = EngineConfig(interface="hbm")
    ok = True
    for p in (2, 4, 8):
        for m in (4, 8):
            g = simulate_training(homog, n_stages=p, n_microbatches=m,
                                  schedule="gpipe", seq_len=SEQ_LEN,
                                  global_batch=GLOBAL_BATCH, config=cfg)
            o = simulate_training(homog, n_stages=p, n_microbatches=m,
                                  schedule="1f1b", seq_len=SEQ_LEN,
                                  global_batch=GLOBAL_BATCH, config=cfg)
            if o.step_time_s > g.step_time_s * (1 + 1e-12):
                print(f"training probe FAILED: 1f1b slower than gpipe at "
                      f"p{p}m{m}: {o.step_time_s} vs {g.step_time_s}",
                      file=sys.stderr)
                ok = False
    for p, m in ((2, 8), (4, 8)):
        for schedule in SCHEDULES:
            r = simulate_training(
                homog, n_stages=p, n_microbatches=m, schedule=schedule,
                seq_len=128, global_batch=m,
                config=EngineConfig(interface="ideal"))
            want = bubble_bound(p, m)
            if abs(r.bubble_fraction - want) > 1e-9:
                print(f"training probe FAILED: ideal bubble "
                      f"{r.bubble_fraction} != bound {want} at "
                      f"{schedule}/p{p}m{m}", file=sys.stderr)
                ok = False
    return ok


def run(emit=print):
    """benchmarks.run driver entry: the grid rows (no file writes)."""
    _, rows, _, _ = measure()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="sweep timing vs the BENCH_training.json budget "
                         "(2x gate) + the schedule/bubble probes")
    args = ap.parse_args()
    out, rows, _, sweep_s = measure()
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    if args.quick:
        failed = not check_probes()
        if not failed:
            print("perf-smoke training: schedule/bubble probes OK")
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        budgets = json.loads(BENCH_JSON.read_text()).get("budget_s", {})
        for name, measured in out["budget_s"].items():
            budget = budgets.get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured*1e3:.1f}ms vs budget "
                  f"{budget*1e3:.1f}ms (2x gate) {verdict}")
            failed |= verdict != "OK"
        if failed:
            print("bench_training smoke failed (perf >2x budget or "
                  "probe broken)", file=sys.stderr)
            sys.exit(1)
        return
    if not check_probes():
        sys.exit(1)
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("pipeline-parallel training sweep (model x schedule x "
                   "n_stages x n_microbatches) through repro.sim.training; "
                   "budget_s feeds the tools/ci.sh --quick 2x gate; "
                   "regenerate with `PYTHONPATH=src python -m "
                   "benchmarks.bench_training`")
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
