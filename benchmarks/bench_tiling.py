"""Fig 6 analogue: tiling-strategy transformation cost, measured + modeled.

Tiles a medium (1x16x16x128) and large (1x64x64x512) NHWC tensor with each
feasible strategy; MEASURES real host memcpy time (numpy, the framework's
data-preparation path) and reports the tiling optimizer's modeled cost next
to it.  Paper result to reproduce: row-wise tiling is ~1.8x faster than
channel-wise on the medium tensor, and DimHW ~6.5x cheaper than DimCH on the
large one.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.tensor import TensorSpec
from repro.core.tiling import enumerate_tilings


def materialize_tiles(arr: np.ndarray, tile):
    """Copy every tile into its own contiguous buffer (data preparation)."""
    shape = arr.shape
    outs = []
    for i0 in range(0, shape[0], tile[0]):
        for i1 in range(0, shape[1], tile[1]):
            for i2 in range(0, shape[2], tile[2]):
                for i3 in range(0, shape[3], tile[3]):
                    outs.append(np.ascontiguousarray(
                        arr[i0:i0 + tile[0], i1:i1 + tile[1],
                            i2:i2 + tile[2], i3:i3 + tile[3]]))
    return outs


def run(emit=print):
    rows = []
    for shape in [(1, 16, 16, 128), (1, 64, 64, 512)]:
        spec = TensorSpec(shape, "NHWC", "float32")
        arr = np.random.default_rng(0).standard_normal(shape).astype(
            np.float32)
        cands = {c.strategy: c for c in
                 enumerate_tilings(spec, 16384, reduce_dim="C",
                                   reduce_quantum=32)}
        for strat in sorted(cands):
            c = cands[strat]
            if c.n_tiles > 4096:
                continue
            t0 = time.perf_counter()
            for _ in range(3):
                materialize_tiles(arr, c.tile_shape)
            meas = (time.perf_counter() - t0) / 3
            rows.append({"name": f"tiling/{shape}/{strat}",
                         "us_per_call": round(meas * 1e6, 1),
                         "derived": (f"modeled={c.host_cost_s*1e6:.1f}us "
                                     f"memcpys={c.n_memcpys} "
                                     f"run={c.contiguous_run}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
