"""Engine-performance benchmark: the perf trajectory of ``repro.sim``.

Times the executor on small / medium / large programs — a lenet5 tile
graph, a vgg16 tile DAG at scratchpad-sized tiles, and a multi-thousand-op
gemma-2b token-by-token decode lowering (``ir.from_decode``) — plus an
8-config design-space ``sweep()`` of the decode program.

Full mode (``python -m benchmarks.bench_engine_perf``) also times the
frozen PR-base executor (``tests/_reference_engine.py``) on every case and
writes the before/after numbers to ``BENCH_engine.json`` at the repo root,
which doubles as the CI perf budget.

``--quick`` (the ``tools/ci.sh`` perf smoke) times only the current engine
and exits 1 if any case runs slower than 2x its recorded budget.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import pathlib
import sys
import time

from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, hw, ir, training
from repro.sim.report import row
from repro.sim.sweep import lower_graph, sweep
from benchmarks.common import build_paper_graph

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_engine.json"

# recorded fused-vs-dict-loop speedups are CI floors (bench_fleet/bench_dse
# convention): --quick fails if the committed value ever drops below these
FUSION_FLOORS = {"fusion_training_dag": 1.4,
                 "fusion_parallel_collective": 2.0}

SWEEP_CONFIGS = [
    engine.EngineConfig(n_workers=1, interface="hbm", hbm_ports=4),
    engine.EngineConfig(n_workers=1, interface="acp", hbm_ports=4),
    engine.EngineConfig(n_workers=2, interface="dma", hbm_ports=4),
    engine.EngineConfig(n_workers=4, interface="hbm", hbm_ports=1,
                        host_dispatch_s=1e-6),
    engine.EngineConfig(n_workers=1, interface="hbm"),
    engine.EngineConfig(n_workers=8, interface="acp", hbm_ports=2,
                        host_dispatch_s=1e-6, host_bw=20e9, host_threads=8),
    engine.EngineConfig(n_workers=1, interface="dma", hbm_ports=4,
                        host_dispatch_s=1e-6),
    engine.EngineConfig(n_workers=2, interface="hbm", hbm_ports=0.5,
                        datapath_scale=0.5),
]
CASE_CONFIG = engine.EngineConfig(n_workers=8, interface="hbm", hbm_ports=4)


def _load_reference():
    p = ROOT / "tests" / "_reference_engine.py"
    spec = importlib.util.spec_from_file_location("_reference_engine", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.run_reference


def _cases():
    small = lower_graph(build_paper_graph(PAPER_NETS["lenet5"], batch=1),
                        batch=1, max_tile_elems=16384)
    medium = lower_graph(build_paper_graph(PAPER_NETS["vgg16"], batch=1),
                         batch=1, max_tile_elems=2048)
    large = lower_graph(build_paper_graph(PAPER_NETS["vgg16"], batch=1),
                        batch=1, max_tile_elems=128)
    decode = ir.from_decode(GEMMA_2B, n_tokens=640, ops_per_token=8)
    return [("graph_small_lenet5", small), ("graph_medium_vgg16", medium),
            ("graph_large_vgg16_3k", large),
            ("decode_5k_gemma2b", decode)]


def _fusion_cases():
    """DAG workloads whose tier hops are LPT-neutral linear runs — the
    linear-run-fusion + typed-array-core target.  Each case is (name,
    program, config)."""
    tr = training.simulate_training(
        GEMMA_2B, n_stages=8, n_microbatches=32, dp_degree=2, tp_degree=2,
        fabric=hw.Fabric.cluster(32), seq_len=512, global_batch=32)
    fab = hw.Fabric.single_tier(1024)
    lanes = ir.Program(
        [op
         for lane in range(4)
         for op in ir.from_collective(
             "all_reduce", 64e6,
             tuple(range(lane * 256, lane * 256 + 256)),
             fab, prefix=f"lane{lane}").ops],
        name="parallel-collective-4x256")
    # the collective lanes must be fusion_resolvable (that is what lets
    # sweep.batched price them exactly); the training DAG has more
    # segments than the resolvability cap — it benchmarks the typed-array
    # core with fusion engaged, not the exact-grid path
    return [("fusion_training_dag", tr.program, tr.config, False),
            ("fusion_parallel_collective", lanes,
             engine.EngineConfig(n_workers=4), True)]


def _assert_bit_identical(a, b, name):
    ok = (a.makespan == b.makespan and a.breakdown == b.breakdown
          and a.energy == b.energy
          and a.timeline.events == b.timeline.events)
    if not ok:
        raise AssertionError(
            f"{name}: fused loop diverged from the dict loop")


def _best_of(fn, repeats=3, inner=1):
    """Best-of-``repeats`` mean over ``inner`` calls: sub-millisecond
    cases need the inner loop for a stable reading on a shared box."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        ts.append((time.perf_counter() - t0) / inner)
    return min(ts)


def measure(full: bool):
    run_reference = _load_reference() if full else None
    out = {"cases": {}, "budget_s": {}}
    rows = []
    cases = _cases()
    for name, prog in cases:
        plan = engine.prepare(prog)
        engine.run(prog, CASE_CONFIG, plan=plan)        # warm (numpy etc.)
        inner = 40 if len(prog.ops) < 256 else 1
        t_new = _best_of(lambda: engine.run(prog, CASE_CONFIG, plan=plan),
                         inner=inner)
        case = {"n_ops": len(prog.ops), "engine_s": round(t_new, 6)}
        if full:
            t_ref = _best_of(
                lambda: run_reference(prog, CASE_CONFIG), repeats=2,
                inner=inner)
            case["reference_s"] = round(t_ref, 6)
            case["speedup"] = round(t_ref / t_new, 2)
        out["cases"][name] = case
        out["budget_s"][name] = round(t_new, 6)
        rows.append(row(f"engine_perf/{name}", t_new,
                        f"n_ops={len(prog.ops)} "
                        + (f"pr_base_us={case['reference_s']*1e6:.0f} "
                           f"speedup={case['speedup']}x" if full else
                           "quick")))
    # linear-run fusion + typed-array event core: fused vs dict loop on
    # DAG workloads (the loops must stay bit-identical; full mode records
    # the speedup, which --quick gates as a floor)
    out["fusion"] = {}
    for name, prog, cfg, resolvable in _fusion_cases():
        plan = engine.prepare(prog)
        cp = plan.compiled()
        assert cp.n_run_interior > 0, name          # fusion engaged
        assert engine.fusion_resolvable(plan) == resolvable, name
        _assert_bit_identical(
            engine.run(prog, cfg, plan=plan, fuse=True),
            engine.run(prog, cfg, plan=plan, fuse=False), name)
        t_fused = _best_of(
            lambda: engine.run(prog, cfg, plan=plan, fuse=True), repeats=5)
        case = {"n_ops": len(prog.ops),
                "n_segments": len(cp.op_list) - cp.n_run_interior,
                "fused_s": round(t_fused, 6), "bit_identical": True}
        if full:
            t_dict = _best_of(
                lambda: engine.run(prog, cfg, plan=plan, fuse=False),
                repeats=5)
            case["dict_loop_s"] = round(t_dict, 6)
            case["speedup"] = round(t_dict / t_fused, 2)
        out["fusion"][name] = case
        out["budget_s"][name] = round(t_fused, 6)
        rows.append(row(
            f"engine_perf/{name}", t_fused,
            f"n_ops={case['n_ops']} n_segments={case['n_segments']} "
            + (f"dict_loop_us={case['dict_loop_s']*1e6:.0f} "
               f"speedup={case['speedup']}x" if full else "quick")))

    decode = cases[-1][1]
    sweep(decode, SWEEP_CONFIGS[:1])                    # warm
    t_sweep = _best_of(lambda: sweep(decode, SWEEP_CONFIGS), repeats=2)
    sw = {"n_ops": len(decode.ops), "n_configs": len(SWEEP_CONFIGS),
          "sweep_s": round(t_sweep, 6)}
    if full:
        t_serial = _best_of(
            lambda: [run_reference(decode, c) for c in SWEEP_CONFIGS],
            repeats=1)
        sw["serial_reference_s"] = round(t_serial, 6)
        sw["speedup"] = round(t_serial / t_sweep, 2)
    out["sweep_8cfg_decode_5k"] = sw
    out["budget_s"]["sweep_8cfg_decode_5k"] = round(t_sweep, 6)
    rows.append(row("engine_perf/sweep_8cfg_decode_5k", t_sweep,
                    f"n_ops={sw['n_ops']} n_configs={sw['n_configs']} "
                    + (f"serial_pr_base_s={sw['serial_reference_s']:.3f} "
                       f"speedup={sw['speedup']}x" if full else "quick")))
    return out, rows


def run(emit=print):
    """benchmarks.run driver entry: quick engine-side timings only."""
    _, rows = measure(full=False)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="engine-only timing + regression gate vs the "
                         "budgets in BENCH_engine.json (CI perf smoke)")
    args = ap.parse_args()
    out, rows = measure(full=not args.quick)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    if args.quick:
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        recorded = json.loads(BENCH_JSON.read_text())
        budgets = recorded.get("budget_s", {})
        failed = False
        for name, measured in out["budget_s"].items():
            budget = budgets.get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured*1e3:.1f}ms vs budget "
                  f"{budget*1e3:.1f}ms (2x gate) {verdict}")
            failed |= verdict != "OK"
        # recorded fused-vs-dict speedups are floors (measured in full
        # mode, committed in BENCH_engine.json): the fused core must keep
        # beating the dict loop on DAG workloads
        for name, floor in FUSION_FLOORS.items():
            sp = (out["fusion"].get(name, {}).get("speedup")
                  or recorded.get("fusion", {}).get(name, {})
                  .get("speedup"))
            ok = sp is not None and sp >= floor
            print(f"perf-smoke {name}: recorded fused speedup {sp}x "
                  f"(floor {floor}x) {'OK' if ok else 'REGRESSION'}")
            failed |= not ok
        if failed:
            print("engine perf regressed (>2x budget or a fused-speedup "
                  "floor broke) against BENCH_engine.json",
                  file=sys.stderr)
            sys.exit(1)
        return
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("engine_s/sweep_s: current engine; reference_s: frozen "
                   "PR-base executor (tests/_reference_engine.py); "
                   "fusion.*: linear-run-fused typed-array core vs the "
                   "dict-based event loop on DAG workloads, bit-identical "
                   "by construction (speedup gated as a CI floor); "
                   "budget_s feeds the tools/ci.sh --quick 2x gate")
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
