"""Calibration benchmark: measured Pallas kernels vs the cost backends.

Times the real ``repro/kernels/`` Pallas kernels (``nvdla_matmul``,
``flash_attention``, ``mamba_scan``) over a shape grid
(``repro.kernels.calibrate``), fits per-kernel cost parameters by least
squares, and writes ``BENCH_calibration.json`` at the repo root.

Gates (both modes):

* **n_improved >= 2** — the fitted model's MAPE must beat the
  uncalibrated roofline (at the canonical TPU constants) on at least 2
  of the 3 kernels.
* **matmul MAPE floor** — the fitted matmul error must stay under
  ``MATMUL_MAPE_CEIL``; a linear (flops, bytes, overhead) model that
  cannot track its own measured matmul grid means the accounting broke.
* **table round-trip** — the measured ``TableBackend`` must reproduce
  every sample it was built from bit-exactly.

``--quick`` (the ``tools/ci.sh`` smoke) re-measures the 2-shape quick
grid, re-runs the gates, and checks the measurement wall against the
recorded quick budget (2x gate).  Full mode runs the full grid and
records the artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

from repro.kernels import calibrate
from repro.sim.report import row

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_calibration.json"

N_IMPROVED_FLOOR = 2          # fitted beats roofline on >= 2 of 3 kernels
MATMUL_MAPE_CEIL = 0.35       # fitted matmul MAPE must stay under this
TABLE_RT_TOL = 1e-12          # measured table reproduces its own samples


def measure(full: bool):
    grid = "full" if full else "quick"
    t0 = time.perf_counter()
    records, meta = calibrate.measure(grid=grid, repeat=3 if full else 2)
    t_measure = time.perf_counter() - t0
    out = calibrate.build_report(records, meta)
    out["budget_s"] = {f"measure_{grid}_grid": round(t_measure, 6)}

    rows = []
    for name in sorted(out["kernels"]):
        f = out["kernels"][name]
        rows.append(row(
            f"calibration/{name}", f["fitted"]["overhead_s"] or 0.0,
            f"n={f['n_samples']} roofline_mape={f['roofline_mape']:.3g} "
            f"fitted_mape={f['fitted_mape']:.3g} "
            f"backend={out['backend']}"))
    rows.append(row(
        f"calibration/measure_{grid}_grid", t_measure,
        f"n_samples={len(records)} n_improved={out['n_improved']} "
        f"interpret={out['interpret']}"))
    return out, rows


def _check(out):
    """The modeled-vs-measured gates (same in quick and full mode)."""
    failed = False
    if out["n_improved"] < N_IMPROVED_FLOOR:
        print(f"calibration smoke: fitted model beat the roofline on only "
              f"{out['n_improved']} kernels (floor {N_IMPROVED_FLOOR}); "
              f"improved={out['improved']}", file=sys.stderr)
        failed = True
    mm = out["kernels"].get("matmul")
    if mm is None or mm["fitted_mape"] > MATMUL_MAPE_CEIL:
        got = None if mm is None else round(mm["fitted_mape"], 4)
        print(f"calibration smoke: matmul fitted MAPE {got} over the "
              f"{MATMUL_MAPE_CEIL} ceiling", file=sys.stderr)
        failed = True
    worst_rt = max(f["table_max_rel_err"] for f in out["kernels"].values())
    if worst_rt > TABLE_RT_TOL:
        print(f"calibration smoke: TableBackend round-trip error "
              f"{worst_rt} > {TABLE_RT_TOL}", file=sys.stderr)
        failed = True
    return failed


def run(emit=print):
    """benchmarks.run driver entry: quick-grid rows only (no file writes)."""
    _, rows = measure(full=False)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="quick-grid re-measure + the n_improved / matmul "
                         "MAPE / table round-trip gates + the 2x budget "
                         "gate vs BENCH_calibration.json (CI smoke)")
    args = ap.parse_args()
    out, rows = measure(full=not args.quick)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    failed = _check(out)
    if args.quick:
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        recorded = json.loads(BENCH_JSON.read_text())
        for name, measured in out["budget_s"].items():
            budget = recorded.get("budget_s", {}).get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured*1e3:.1f}ms vs budget "
                  f"{budget*1e3:.1f}ms (2x gate) {verdict}")
            failed |= verdict != "OK"
        if recorded.get("n_improved", 0) < N_IMPROVED_FLOOR:
            print(f"calibration smoke: recorded artifact has n_improved="
                  f"{recorded.get('n_improved')}", file=sys.stderr)
            failed = True
        if failed:
            print("bench_calibration smoke failed (a calibration gate "
                  "broke or measurement went >2x budget)", file=sys.stderr)
            sys.exit(1)
        return
    if failed:
        sys.exit(1)
    # record the quick-grid budget too, so --quick has one to gate on.
    # A fresh subprocess, not this warm process: --quick pays kernel
    # tracing inside its measured wall, and a warm-cache budget would
    # gate every cold CI run as a false regression.
    t0 = time.perf_counter()
    subprocess.run(
        [sys.executable, "-c",
         "from repro.kernels import calibrate; "
         "calibrate.measure(grid='quick', repeat=2)"],
        check=True, cwd=ROOT,
        env={**os.environ,
             "PYTHONPATH": str(ROOT / "src") + os.pathsep
             + os.environ.get("PYTHONPATH", "")})
    out["budget_s"]["measure_quick_grid"] = round(
        time.perf_counter() - t0, 6)
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("best-of-k wall times of the interpret-mode Pallas "
                   "kernels over the full shape grid; per-kernel "
                   "least-squares (flops, bytes, overhead) fits vs the "
                   "uncalibrated TPU-constant roofline; budget_s feeds "
                   "the tools/ci.sh --quick 2x gate")
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
