"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.apps.paper_graphs import build_paper_graph  # noqa: F401

def timeit(fn, *args, repeat: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def emit(rows: List[Dict], header: List[str]):
    """name,us_per_call,derived CSV convention (benchmarks/run.py)."""
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
