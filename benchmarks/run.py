"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness convention.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--profile]

``--profile`` wraps the selected modules in cProfile and prints the
top-20 functions by cumulative time to stderr — the standing answer to
"where does the wall go" when tuning the engine hot paths.
"""
import argparse
import importlib
import sys
import traceback

MODULES = [
    ("breakdown", "Fig 1  — end-to-end latency breakdown"),
    ("tiling", "Fig 6  — tiling-strategy transformation cost"),
    ("sampling", "Fig 8  — sampled-simulation error"),
    ("simtime", "Fig 10 — evaluation-loop (lower+compile) time"),
    ("interfaces", "Fig 11 — DMA vs fused/resident data path"),
    ("multiacc", "Fig 12/13 — multi-accelerator scaling"),
    ("hostpipe", "Fig 15/16/17 — multithreaded data preparation"),
    ("combined", "Fig 18 — combined optimizations"),
    ("timeline", "Fig 14 — utilization timeline"),
    ("camera", "Fig 19/20 — camera vision pipeline"),
    ("soc", "SoC tuning — heterogeneous camera-SoC topology sweep"),
    ("roofline", "§Roofline — per-cell roofline terms"),
    ("serving", "serving — trace-driven batching policy x arrival rate"),
    ("training", "training — pipeline-parallel schedule x microbatch x "
                 "stage count"),
    ("engine_perf", "infra — executor scaling (small/medium/5k-op sweep)"),
    ("dse", "DSE — vectorized analytic cost model + gradient port study"),
    ("fleet", "fleet — memoized multi-replica serving replay at scale"),
    ("cluster", "cluster — DP x TP x PP over the hierarchical network "
                "fabric with first-class collectives"),
    ("calibration", "calibration — measured Pallas kernels vs the fitted "
                    "cost backends"),
]


def _run_modules(only) -> int:
    failures = 0
    print("name,us_per_call,derived")
    for mod_name, title in MODULES:
        if only and only != mod_name:
            continue
        print(f"# === bench_{mod_name}: {title} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.bench_{mod_name}")
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the selected benchmarks; print the "
                         "top-20 cumulative-time functions to stderr")
    args = ap.parse_args()
    if args.profile:
        import cProfile
        import io
        import pstats
        prof = cProfile.Profile()
        prof.enable()
        try:
            failures = _run_modules(args.only)
        finally:
            prof.disable()
            buf = io.StringIO()
            (pstats.Stats(prof, stream=buf)
             .sort_stats("cumulative").print_stats(20))
            print(buf.getvalue(), file=sys.stderr, flush=True)
    else:
        failures = _run_modules(args.only)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
