"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness convention.

  PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
import argparse
import importlib
import sys
import traceback

MODULES = [
    ("breakdown", "Fig 1  — end-to-end latency breakdown"),
    ("tiling", "Fig 6  — tiling-strategy transformation cost"),
    ("sampling", "Fig 8  — sampled-simulation error"),
    ("simtime", "Fig 10 — evaluation-loop (lower+compile) time"),
    ("interfaces", "Fig 11 — DMA vs fused/resident data path"),
    ("multiacc", "Fig 12/13 — multi-accelerator scaling"),
    ("hostpipe", "Fig 15/16/17 — multithreaded data preparation"),
    ("combined", "Fig 18 — combined optimizations"),
    ("timeline", "Fig 14 — utilization timeline"),
    ("camera", "Fig 19/20 — camera vision pipeline"),
    ("soc", "SoC tuning — heterogeneous camera-SoC topology sweep"),
    ("roofline", "§Roofline — per-cell roofline terms"),
    ("serving", "serving — trace-driven batching policy x arrival rate"),
    ("training", "training — pipeline-parallel schedule x microbatch x "
                 "stage count"),
    ("engine_perf", "infra — executor scaling (small/medium/5k-op sweep)"),
    ("dse", "DSE — vectorized analytic cost model + gradient port study"),
    ("fleet", "fleet — memoized multi-replica serving replay at scale"),
    ("cluster", "cluster — DP x TP x PP over the hierarchical network "
                "fabric with first-class collectives"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    print("name,us_per_call,derived")
    for mod_name, title in MODULES:
        if args.only and args.only != mod_name:
            continue
        print(f"# === bench_{mod_name}: {title} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.bench_{mod_name}")
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
