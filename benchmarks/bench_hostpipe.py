"""Fig 15/16/17 analogue: multithreaded data preparation/finalization.

Measures REAL tiling (scatter to contiguous tiles) + untiling (gather back)
of layer-sized tensors with 1..8 host workers; numpy memcpys release the
GIL so the pool scales on real machines (on this 1-core container the
speedup ceiling is 1; the benchmark reports measured scaling honestly and
the simulator's bandwidth-model prediction alongside)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.scheduler import ThreadPool


def _make_tiles(arr, tile_rows):
    return [np.ascontiguousarray(arr[i:i + tile_rows])
            for i in range(0, arr.shape[0], tile_rows)]


def _untile(tiles, out):
    r = 0
    for t in tiles:
        out[r:r + t.shape[0]] = t
        r += t.shape[0]
    return out


def run(emit=print):
    rows = []
    arr = np.random.default_rng(0).standard_normal((4096, 2048)).astype(
        np.float32)  # ~32MB layer tensor
    out = np.empty_like(arr)
    tile_rows = 128
    ranges = list(range(0, arr.shape[0], tile_rows))
    base = None
    for n in (1, 2, 4, 8):
        pool = ThreadPool(n)
        try:
            def prep(i):
                t = np.ascontiguousarray(arr[i:i + tile_rows])   # prepare
                out[i:i + tile_rows] = t                          # finalize
                return t.nbytes
            pool.map(prep, ranges)  # warm
            t0 = time.perf_counter()
            for _ in range(3):
                pool.map(prep, ranges)
            dt = (time.perf_counter() - t0) / 3
        finally:
            pool.shutdown()
        if base is None:
            base = dt
        bw = 2 * arr.nbytes / dt / 1e9
        rows.append({"name": f"hostpipe/threads{n}",
                     "us_per_call": round(dt * 1e6, 1),
                     "derived": (f"speedup={base/dt:.2f}x bw={bw:.1f}GB/s")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
