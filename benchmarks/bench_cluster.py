"""Cluster-scale training over the hierarchical fabric: DP x TP x PP.

Four studies, all feeding ``BENCH_cluster.json`` at the repo root:

* **cluster_grid** — gemma-2b and deepseek-v2-lite trained across
  8 / 64 / 512 accelerators, every power-of-two (dp, pp, tp) placement
  (``sweep.placements_for``), ring / tree / hierarchical gradient
  all-reduce — one ``as_cluster_records`` row per cell with whole-cluster
  tokens/s, per-step energy and TCO (``hw.tco_per_step``), plus a
  ``speedup`` column against the model's best smallest-cluster cell.
  The "cheapest N-accelerator config under a step-time target" question
  is answered inline (min TCO subject to ``step_time_s <= target``).
* **bounds** — the engine's makespan on an uncontended lowered collective
  must equal the closed forms: ring all-reduce
  ``2 (p-1)/p B/bw + 2 (p-1) lat``, tree ``2 ceil(log2 p) (lat + B/bw)``,
  and the hierarchical per-tier composition (rel 1e-12).
* **hier_vs_ring** — hierarchical <= ring for every node-spanning
  all-reduce on the multi-tier cluster fabric (the point of the algo).
* **single_tier_identity** — a single-tier fabric must be invisible:
  the no-collective training step is bit-identical to the flat config,
  and the dp ring's collective lane time equals the pre-refactor ring
  wire term ``2 (d-1)/d grad_bytes / ici_bw`` (rel 1e-12).

``--quick`` (the ``tools/ci.sh`` perf smoke) runs a reduced grid against
its recorded budget (2x gate) plus all three correctness probes; the
512-accelerator sweep runs only in full mode.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
import time

from repro.configs.deepseek_v2_lite_16b import FULL as DEEPSEEK
from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.sim import hw, ir, training
from repro.sim.engine import EngineConfig
from repro.sim.report import row
from repro.sim.sweep import as_cluster_records, cluster_sweep

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_cluster.json"

MODELS = (GEMMA_2B, DEEPSEEK)
ALGOS = ("ring", "tree", "hierarchical")
FULL_GRID = (8, 64, 512)
QUICK_GRID = (8, 32)
STEP_TARGET_S = 1.0            # the headline "train under target" question
REL = 1e-12


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1e-300)


# -- compact artifact format -------------------------------------------------
# the grid dominates the artifact (hundreds of cells x ~25 columns); it is
# committed columnar ({"columns": [...], "rows": [[...], ...]}) with floats
# rounded to 6 significant digits and one row per line, which shrinks the
# file ~8x without losing anything a reader of the study needs.  The
# correctness probes (bounds / identities) keep full precision.


def _round6(v):
    if isinstance(v, float) and not v.is_integer():
        return float(f"{v:.6g}")
    return v


def _to_columnar(recs):
    cols = list(recs[0]) if recs else []
    return {"columns": cols,
            "rows": [[_round6(r[c]) for c in cols] for r in recs]}


def grid_records(doc):
    """Decode a BENCH_cluster.json ``cluster_grid`` back to row dicts
    (accepts both the columnar and the legacy list-of-dicts form)."""
    g = doc["cluster_grid"]
    if isinstance(g, list):
        return g
    cols = g["columns"]
    return [dict(zip(cols, r)) for r in g["rows"]]


def _compact_json(out) -> str:
    """indent=2 everywhere except the grid rows, which go one per line."""
    head = {k: v for k, v in out.items() if k != "cluster_grid"}
    txt = json.dumps(head, indent=2)
    g = out["cluster_grid"]
    rows = ",\n      ".join(json.dumps(r, separators=(",", ":"))
                            for r in g["rows"])
    grid_txt = ('"cluster_grid": {\n'
                f'    "columns": {json.dumps(g["columns"])},\n'
                f'    "rows": [\n      {rows}\n    ]\n  }}')
    assert txt.endswith("\n}")
    return txt[:-2] + ",\n  " + grid_txt + "\n}\n"


def _grid(full: bool):
    """The placement grid rows for every model, with speedup columns."""
    grid = FULL_GRID if full else QUICK_GRID
    rows = []
    for cfg in (MODELS if full else MODELS[:1]):
        results = cluster_sweep(cfg, n_accel_grid=grid, algos=ALGOS,
                                seq_len=512, global_batch=32)
        recs = as_cluster_records(results)
        n_min = min(r["n_accel"] for r in recs)
        base = max(r["cluster_tokens_per_s"] for r in recs
                   if r["n_accel"] == n_min)
        for r in recs:
            r["speedup"] = r["cluster_tokens_per_s"] / base
        rows.extend(recs)
    return rows


def _cheapest(rows, model: str, target_s: float):
    """min TCO/step subject to the step-time target (None if infeasible)."""
    feas = [r for r in rows
            if r["model"] == model and r["step_time_s"] <= target_s]
    if not feas:
        return None
    best = min(feas, key=lambda r: r["tco_usd_per_step"])
    return {k: best[k] for k in
            ("model", "n_accel", "dp_degree", "pp_degree", "tp_degree",
             "collective_algo", "step_time_s", "tco_usd_per_step",
             "cluster_tokens_per_s")}


def _bounds():
    """Engine makespan == closed form on uncontended lowered collectives."""
    cfg = EngineConfig()
    checks = []
    flat = hw.Fabric.single_tier(16)
    B = 64e6
    lat, bw = hw.resolve_tier_params(cfg, flat.tiers[0].name)
    for p in (2, 5, 16):
        g = tuple(range(p))
        t = ir.collective_time("all_reduce", B, g, flat, config=cfg,
                               algo="ring")
        closed = 2.0 * (p - 1) / p * B / bw + 2.0 * (p - 1) * lat
        checks.append(("ring_allreduce", p, _rel(t, closed)))
        tt = ir.collective_time("all_reduce", B, g, flat, config=cfg,
                                algo="tree")
        depth = max(1, (p - 1).bit_length())
        closed_t = 2.0 * depth * (lat + B / bw)
        checks.append(("tree_allreduce", p, _rel(tt, closed_t)))
    fab = hw.Fabric(tiers=(hw.FabricTier("node", 4),
                           hw.FabricTier("inter", 4)))
    lat_n, bw_n = hw.resolve_tier_params(cfg, fab.tiers[0].name)
    lat_i, bw_i = hw.resolve_tier_params(cfg, fab.tiers[1].name)
    th = ir.collective_time("all_reduce", B, tuple(range(16)), fab,
                            config=cfg, algo="hierarchical")
    k, n = 4, 4
    rs = (k - 1) * (lat_n + (B / k) / bw_n)
    ar = 2.0 * (n - 1) * (lat_i + (B / (k * n)) / bw_i)
    checks.append(("hier_allreduce", 16, _rel(th, 2.0 * rs + ar)))
    worst = max(c[2] for c in checks)
    return {"checks": [{"algo": a, "p": p, "rel_err": e}
                       for a, p, e in checks],
            "worst_rel_err": worst, "exact": bool(worst <= REL)}


def _hier_vs_ring():
    """hierarchical <= ring on every node-spanning group of the cluster."""
    cfg = EngineConfig()
    fab = hw.Fabric.cluster(64)
    cells = []
    for p in (8, 16, 32, 64):
        g = tuple(range(p))
        r = ir.collective_time("all_reduce", 128e6, g, fab, config=cfg,
                               algo="ring")
        h = ir.collective_time("all_reduce", 128e6, g, fab, config=cfg,
                               algo="hierarchical")
        cells.append({"p": p, "ring_s": r, "hier_s": h,
                      "hier_le_ring": bool(h <= r * (1.0 + REL))})
    return {"fabric": fab.describe(), "bytes": 128e6, "cells": cells,
            "all_hold": bool(all(c["hier_le_ring"] for c in cells))}


def _single_tier_identity():
    """Single-tier fabric invisible; dp ring == pre-refactor wire term."""
    cfg = EngineConfig()
    flat = hw.Fabric.single_tier(8)
    a = training.simulate_training(GEMMA_2B, global_batch=8)
    b = training.simulate_training(GEMMA_2B, global_batch=8, fabric=flat)
    no_dp_identical = a.step_time_s == b.step_time_s

    d = 4
    r = training.simulate_training(GEMMA_2B, global_batch=8, dp_degree=d,
                                   fabric=flat)
    legacy = ir.from_training_step(GEMMA_2B, seq_len=512, batch=8,
                                   dp_degree=d)
    wire = next(op.wire_bytes for op in legacy.ops
                if op.name == "train/reduce")
    expect = wire / cfg.ici_bw      # 2 (d-1)/d grad_bytes / ici_bw
    err = _rel(r.stats()["collective_s"], expect)
    return {"no_dp_bit_identical": bool(no_dp_identical),
            "dp_ring_collective_s": r.stats()["collective_s"],
            "legacy_ring_wire_s": expect, "rel_err": err,
            "dp_ring_matches": bool(err <= REL)}


def measure(full: bool):
    out = {"budget_s": {}}
    rows = []

    t0 = time.perf_counter()
    grid = _grid(full)
    wall = time.perf_counter() - t0
    key = "cluster_grid" if full else "cluster_grid_quick"
    out["cluster_grid"] = grid
    out["budget_s"][key] = round(wall, 3)
    best = max(grid, key=lambda r: r["cluster_tokens_per_s"])
    rows.append(row(
        f"cluster/grid_{len(grid)}cells", wall,
        f"best={best['model']}@{best['n_accel']} "
        f"dp{best['dp_degree']}xpp{best['pp_degree']}xtp"
        f"{best['tp_degree']}/{best['collective_algo']} "
        f"{best['cluster_tokens_per_s']:,.0f}tok/s"))

    if full:
        # record the quick-sized budget too, so --quick has a recorded
        # baseline of its own size to gate against
        t0 = time.perf_counter()
        _grid(False)
        out["budget_s"]["cluster_grid_quick"] = round(
            time.perf_counter() - t0, 3)

        out["cheapest_under_target"] = {
            "target_step_s": STEP_TARGET_S,
            **{m.name: _cheapest(grid, m.name, STEP_TARGET_S)
               for m in MODELS}}
        ds = out["cheapest_under_target"].get(DEEPSEEK.name)
        rows.append(row(
            "cluster/cheapest_under_target", 0.0,
            f"deepseek@<= {STEP_TARGET_S}s: "
            + (f"{ds['n_accel']} accel dp{ds['dp_degree']}"
               f"xpp{ds['pp_degree']}xtp{ds['tp_degree']} "
               f"${ds['tco_usd_per_step']:.4f}/step"
               if ds else "infeasible")))

    bd = _bounds()
    out["bounds"] = bd
    rows.append(row(
        "cluster/closed_form_bounds", 0.0,
        f"checks={len(bd['checks'])} worst_rel={bd['worst_rel_err']:.2e} "
        f"exact={bd['exact']}"))

    hr = _hier_vs_ring()
    out["hier_vs_ring"] = hr
    rows.append(row(
        "cluster/hier_vs_ring", 0.0,
        f"fabric={hr['fabric']} all_hold={hr['all_hold']}"))

    sid = _single_tier_identity()
    out["single_tier_identity"] = sid
    rows.append(row(
        "cluster/single_tier_identity", 0.0,
        f"no_dp_identical={sid['no_dp_bit_identical']} "
        f"dp_ring_rel={sid['rel_err']:.2e}"))
    return out, rows


def _check(out):
    failed = False
    if not out["bounds"]["exact"]:
        print(f"cluster smoke: closed-form bound mismatch "
              f"(worst rel {out['bounds']['worst_rel_err']:.2e})",
              file=sys.stderr)
        failed = True
    if not out["hier_vs_ring"]["all_hold"]:
        print("cluster smoke: hierarchical all-reduce slower than ring "
              "on the multi-tier fabric", file=sys.stderr)
        failed = True
    sid = out["single_tier_identity"]
    if not (sid["no_dp_bit_identical"] and sid["dp_ring_matches"]):
        print("cluster smoke: single-tier fabric is not invisible",
              file=sys.stderr)
        failed = True
    for r in out["cluster_grid"]:
        if not (math.isfinite(r["step_time_s"]) and r["step_time_s"] > 0):
            print(f"cluster smoke: non-finite step time in {r['program']}",
                  file=sys.stderr)
            failed = True
            break
    return failed


def run(emit=print):
    """benchmarks.run driver entry: probes + the quick grid only."""
    _, rows = measure(full=False)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid vs the BENCH_cluster.json budget "
                         "(2x gate) + bound / hier<=ring / single-tier "
                         "identity probes (CI perf smoke)")
    args = ap.parse_args()
    out, rows = measure(full=not args.quick)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    failed = _check(out)
    if args.quick:
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        recorded = json.loads(BENCH_JSON.read_text())
        for name, measured in out["budget_s"].items():
            budget = recorded.get("budget_s", {}).get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured:.2f}s vs budget "
                  f"{budget:.2f}s (2x gate) {verdict}")
            failed |= verdict != "OK"
        if failed:
            print("bench_cluster smoke failed (perf >2x budget or a "
                  "collective correctness gate broke)", file=sys.stderr)
            sys.exit(1)
        return
    if failed:
        sys.exit(1)
    out["cluster_grid"] = _to_columnar(out["cluster_grid"])
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("DP x TP x PP placement grid over the hierarchical "
                   "cluster fabric (8-512 accelerators, ring / tree / "
                   "hierarchical gradient all-reduce) with cluster "
                   "tokens/s, per-step energy and TCO, plus closed-form "
                   "collective bound / hier<=ring / single-tier identity "
                   "probes; budget_s feeds the tools/ci.sh --quick 2x "
                   "gate")
    BENCH_JSON.write_text(_compact_json(out))
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
