"""Fig 8 analogue: sampled-simulation error across kernels and shapes.

Measures real wall time of S/M/L convolution-as-matmul, attention, and
scan kernels at full iteration counts vs the 2-point sampled estimate
unsampled through the loop tree; reports relative error (paper: <=6%,
avg ~1%).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampling import measure_sampled, sampling_error, unsample


def _timed(fn):
    fn()  # compile
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _loop_cost(body, n, repeat=5):
    """Wall time of running `body` n times (jitted scan of length n).
    min-of-N to suppress scheduler noise on this shared 1-core host."""
    @jax.jit
    def run(x):
        def step(c, _):
            return body(c), ()
        y, _ = jax.lax.scan(step, x, None, length=n)
        return y
    x = jnp.ones((512, 128), jnp.float32)
    run(x).block_until_ready()
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        run(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(emit=print):
    w_s = jnp.ones((128, 128), jnp.float32) * 0.01
    w_m = jnp.ones((128, 1024), jnp.float32) * 0.01
    cases = {
        # paper: S-Conv 16x1x1x8 / M-Conv 64 2x2x16 / L-Conv 256 3x3x64 —
        # conv lowers to matmul on the MXU, so sizes map to matmul dims
        "s_conv": lambda c: jnp.tanh(c @ w_s),
        "m_conv": lambda c: jnp.tanh((c @ w_m) @ w_m.T),
        "l_conv": lambda c: jnp.tanh((c @ w_m) @ (w_m.T @ (w_s + 0.001))),
        "elementwise": lambda c: jnp.exp(jnp.sin(c) * 0.5),
    }
    rows = []
    errs = []
    for name, body in cases.items():
        trips = 64
        true = _loop_cost(body, trips)
        node = measure_sampled(lambda n: _loop_cost(body, n), trips=trips,
                               sample=2)  # most aggressive sampling
        est = unsample(node)
        err = sampling_error(est, true)
        errs.append(err)
        rows.append({"name": f"sampling/{name}",
                     "us_per_call": round(true * 1e6, 1),
                     "derived": f"est={est*1e6:.1f}us err={err*100:.2f}%"})
    rows.append({"name": "sampling/avg_error",
                 "us_per_call": "",
                 "derived": f"{np.mean(errs)*100:.2f}% (paper: avg 1%, max 6%)"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
