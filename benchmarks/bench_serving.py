"""Serving study: batching policy x arrival rate on a trace-driven load.

The end-to-end scenario the paper argues per-layer models miss, scaled to
a served workload: Poisson request traces against gemma-2b, three batching
policies (static / dynamic max-wait / continuous per-token batching) over
three arrival rates, all through ``repro.sim.serving`` and the engine's
sweep layer.  Reports TTFT p50/p99, TPOT p50, throughput and decode-slot
occupancy per cell; the headline derived value is the continuous-vs-static
throughput gain at the highest (saturating) rate.

``python -m benchmarks.bench_serving`` additionally records the full grid
in ``BENCH_serving.json`` at the repo root (``BENCH_engine.json`` style),
so the numbers are diffable across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.serve.policy import get_policy
from repro.sim.engine import EngineConfig
from repro.sim.report import row
from repro.sim.serving import as_serving_records, serving_sweep

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_serving.json"

POLICIES = [get_policy("static", max_batch=8),
            get_policy("dynamic", max_batch=8, max_wait_s=0.010),
            get_policy("continuous", max_batch=8)]
RATES_RPS = [10.0, 50.0, 200.0]
N_REQUESTS = 64
# host_dispatch_s models the per-step framework overhead the paper's Fig 1
# measures around the accelerator; it hits many-small-step schedules harder
CONFIG = EngineConfig(n_workers=1, interface="hbm", hbm_ports=4,
                      host_dispatch_s=50e-6)


def _grid():
    return serving_sweep(GEMMA_2B, POLICIES, RATES_RPS,
                         n_requests=N_REQUESTS, config=CONFIG, seed=0)


def _rows(results):
    rows = []
    by_cell = {}
    for res in results:
        s = res.stats()
        rate = res.meta["rate_rps"]
        by_cell[(res.policy.kind, rate)] = res
        rows.append(row(
            f"serving/{res.policy.kind}@{rate:g}rps", s["makespan_s"],
            f"thru={s['throughput_tok_s']:.0f}tok/s "
            f"ttft_p50={s['ttft_p50']*1e3:.1f}ms "
            f"ttft_p99={s['ttft_p99']*1e3:.1f}ms "
            f"tpot_p50={s['tpot_p50']*1e3:.2f}ms "
            f"occ={s['occupancy']:.2f} steps={s['n_steps']:.0f}"))
    top = max(RATES_RPS)
    cont = by_cell[("continuous", top)].throughput_tok_s
    stat = by_cell[("static", top)].throughput_tok_s
    rows.append(row(
        f"serving/continuous_vs_static@{top:g}rps",
        by_cell[("continuous", top)].makespan_s,
        f"throughput_gain={cont/stat:.2f}x "
        f"({cont:.0f} vs {stat:.0f} tok/s; continuous must win at "
        f"saturation)"))
    return rows


def run(emit=print):
    """benchmarks.run driver entry: the policy x rate grid as CSV rows."""
    return _rows(_grid())


def main():
    t0 = time.time()
    results = _grid()
    for r in _rows(results):
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    out = {
        "model": GEMMA_2B.name,
        "n_requests": N_REQUESTS,
        "config": {"interface": CONFIG.interface,
                   "host_dispatch_s": CONFIG.host_dispatch_s,
                   "hbm_ports": CONFIG.hbm_ports},
        "grid": as_serving_records(results),
        "recorded": time.strftime("%Y-%m-%d"),
        "elapsed_s": round(time.time() - t0, 3),
        "note": "policy x arrival-rate serving sweep "
                "(benchmarks/bench_serving.py); regenerate with "
                "`PYTHONPATH=src python -m benchmarks.bench_serving`",
    }
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
