"""Fig 19/20 analogue: camera ISP + CNN10 under a 33 ms frame deadline.

Runs the real JAX ISP on a 720p raw frame for the measured host number,
then composes the modeled ISP program with the CNN10 graph program and
sweeps the WHOLE frame over the accelerator-size grid in one batched
``frame_sweep`` call (Fig 20's 8x8 / 4x8 / 4x4 PE sweep maps to worker
count + peak-FLOPS scaling)."""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.apps.camera import camera_pipeline, frame_sweep
from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine
from repro.sim.report import row
from repro.sim.sweep import lower_graph, sweep
from benchmarks.common import build_paper_graph

# the paper's measured on-SoC camera-pipeline time; the wall-clock row above
# it is this 1-core host running the same JAX ISP (reported for honesty)
ISP_SOC_MS = 13.2

PE_GRID = ((8, 1.0, "8x8PE"), (4, 0.5, "4x8PE"), (2, 0.25, "4x4PE"))


def run(emit=print):
    rows = []
    rng = np.random.default_rng(0)
    raw = rng.random((720, 1280), dtype=np.float32)
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(32, 32))
    jax.block_until_ready(rgb)
    t0 = time.perf_counter()
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(32, 32))
    jax.block_until_ready(rgb)
    isp_s = time.perf_counter() - t0
    rows.append(row("camera/isp_720p", isp_s,
                    "frame_budget_ms=33 (paper ISP: 13.2ms)"))

    g = build_paper_graph(PAPER_NETS["cnn10"], batch=1)
    dnn_prog = lower_graph(g, batch=1, max_tile_elems=16384)
    # calibrate the simulated CNN10 8x8-PE point to the paper's 7.3 ms
    base_cfg = engine.EngineConfig(n_workers=8, interface="acp", hbm_ports=4)
    (base_dnn,) = sweep(dnn_prog, [base_cfg])
    scale = 7.3e-3 / base_dnn.makespan
    configs = [dataclasses.replace(base_cfg, n_workers=workers,
                                   peak_flops=base_cfg.peak_flops * pe_frac,
                                   datapath_scale=pe_frac)
               for workers, pe_frac, _ in PE_GRID]
    _, results = frame_sweep(dnn_prog, configs, hw=(720, 1280),
                             dnn_hw=(32, 32))
    for (workers, pe_frac, label), res in zip(PE_GRID, results):
        phases = res.per_phase
        isp_ms = phases.get("isp", 0.0) * 1e3  # modeled, unscaled
        dnn_ms = (res.makespan - phases.get("isp", 0.0)) * scale * 1e3
        total_ms = ISP_SOC_MS + dnn_ms
        rows.append(row(
            f"camera/cnn10_{label}", dnn_ms * 1e-3,
            f"total_ms={total_ms:.1f} sim_isp_ms={isp_ms:.2f} "
            f"meets_33ms={'yes' if total_ms < 33 else 'NO'} "
            f"(paper Fig 20: 8x8+4x8 meet, 4x4 misses)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
