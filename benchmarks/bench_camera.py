"""Fig 19/20 analogue: camera ISP + CNN10 under a 33 ms frame deadline.

Runs the real JAX ISP on a 720p raw frame and the CNN10 graph on the
downsampled output, measures wall time of each stage (host CPU here),
and sweeps the simulated accelerator size for the DNN part (Fig 20's
8x8 / 4x8 / 4x4 PE sweep maps to worker count in the scheduler model)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.apps.camera import camera_pipeline
from repro.configs.paper_nets import PAPER_NETS
from repro.core.scheduler import simulate
from benchmarks.common import build_paper_graph


def run(emit=print):
    rows = []
    rng = np.random.default_rng(0)
    raw = rng.random((720, 1280), dtype=np.float32)
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(32, 32))
    jax.block_until_ready(rgb)
    t0 = time.perf_counter()
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(32, 32))
    jax.block_until_ready(rgb)
    isp_s = time.perf_counter() - t0
    rows.append({"name": "camera/isp_720p",
                 "us_per_call": round(isp_s * 1e6, 1),
                 "derived": f"frame_budget_ms=33 (paper ISP: 13.2ms)"})

    net = PAPER_NETS["cnn10"]
    g = build_paper_graph(net, batch=1)
    tasks = g.tile_tasks(batch=1, max_tile_elems=16384)
    ISP_SOC_MS = 13.2  # the paper's measured camera-pipeline time on-SoC;
    # our 611 ms is this 1-core host running the same JAX ISP — reported
    # above for honesty, but the frame-budget check uses the SoC number.
    for workers, label in ((8, "8x8PE"), (4, "4x8PE"), (2, "4x4PE")):
        tl = simulate(tasks, workers, shared_bw_penalty=0.05)
        # scale simulated per-tile time up as the PE array shrinks; absolute
        # scale calibrated to the paper's 7.3 ms CNN10 point at 8x8
        dnn_ms = tl.makespan / simulate(tasks, 8).makespan * 7.3 \
            * (8 / workers)
        total_ms = ISP_SOC_MS + dnn_ms
        rows.append({
            "name": f"camera/cnn10_{label}",
            "us_per_call": round(dnn_ms * 1e3, 1),
            "derived": (f"total_ms={total_ms:.1f} "
                        f"meets_33ms={'yes' if total_ms < 33 else 'NO'} "
                        f"(paper Fig 20: 8x8+4x8 meet, 4x4 misses)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
