"""DSE benchmark: the vectorized analytic cost model vs the exact engine.

Three studies, all feeding ``BENCH_dse.json`` at the repo root:

* **speedup** — a 1024-point hardware grid (peak_flops x hbm_bw x
  hbm_ports x host_dispatch_s) over the 5120-op gemma-2b decode chain,
  priced by ``sweep.batched`` (one vectorized parameter matrix, exact on
  chains, top-k exact-verified) against ``sweep(executor="process")``
  running the event engine per point.  Full mode times both sides and
  records ``speedup_vs_process`` (acceptance: >= 50x).
* **dag_fidelity** — the analytic lower/upper bracket on a vgg16 tile
  DAG across 32 configs: the bracket must hold point-for-point, and the
  mean/max lower-bound error is recorded.
* **port_study** — the Fig-13 shared-port question re-answered by
  ``sweep.optimize`` (``benchmarks.bench_soc.port_study_optimize``):
  gradient descent over a continuous port range must land within 2% of
  the exact grid-best makespan.

``--quick`` (the ``tools/ci.sh`` perf smoke) re-times only the analytic
side against the recorded budget (2x gate) and re-checks the recorded
speedup floor, the DAG bracket, and the port-study gap — the minutes-long
process-pool sweep runs only in full mode.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import pathlib
import sys
import time

import numpy as np

from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, ir
from repro.sim.report import row
from repro.sim.sweep import batched, lower_graph, sweep
from benchmarks.common import build_paper_graph
from benchmarks.bench_soc import port_study_optimize

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_dse.json"

SPEEDUP_FLOOR = 50.0          # batched vs process-pool sweep (acceptance)
PORT_STUDY_TOL = 0.02         # optimize vs exact grid best (acceptance)

GRID_BASE = engine.EngineConfig(interface="hbm", n_workers=1)


def _decode():
    return ir.from_decode(GEMMA_2B, n_tokens=640, ops_per_token=8)


def _grid_1024():
    """8 x 8 x 4 x 4 = 1024 design points around the datacenter chip."""
    peaks = np.geomspace(2e13, 4e14, 8)
    bws = np.geomspace(2e11, 1.6e12, 8)
    ports = (0.5, 1.0, 2.0, 4.0)
    hds = (0.0, 5e-7, 1e-6, 2e-6)
    return [dataclasses.replace(GRID_BASE, peak_flops=float(p),
                                hbm_bw=float(b), hbm_ports=float(k),
                                host_dispatch_s=float(h))
            for p, b, k, h in itertools.product(peaks, bws, ports, hds)]


def _dag_fidelity():
    """Bracket quality of the analytic bounds on a real tile DAG."""
    g = build_paper_graph(PAPER_NETS["vgg16"], batch=1)
    dag = lower_graph(g, batch=1, max_tile_elems=2048)
    rng = np.random.default_rng(7)
    lower, upper, exact, n_cfgs = [], [], [], 0
    # batched() prices one interface (one set of statics) per call —
    # split the mixed grid per interface, n_workers per sub-batch
    for iface in ("hbm", "dma", "acp", "ideal"):
        for nw in (1, 4):
            cfgs = [engine.EngineConfig(
                interface=iface, n_workers=nw,
                peak_flops=float(rng.uniform(2e13, 4e14)),
                hbm_bw=float(rng.uniform(2e11, 1.6e12)),
                hbm_ports=float(rng.choice((0.5, 1.0, 2.0, 4.0))),
                host_dispatch_s=float(rng.choice((0.0, 1e-6))))
                for _ in range(4)]
            bs = batched(dag, cfgs, top_k=0)
            lower.extend(bs.lower)
            upper.extend(bs.upper)
            exact.extend(r.makespan for r in sweep(dag, cfgs))
            n_cfgs += len(cfgs)
    lower, upper = np.asarray(lower), np.asarray(upper)
    exact = np.asarray(exact)
    holds = bool(np.all(lower <= exact * (1 + 1e-12))
                 and np.all(exact <= upper * (1 + 1e-12)))
    lb_err = 1.0 - lower / exact
    return {"program": dag.name, "n_ops": len(dag.ops),
            "n_configs": n_cfgs, "bracket_holds": holds,
            "lb_err_mean": round(float(lb_err.mean()), 4),
            "lb_err_max": round(float(lb_err.max()), 4),
            "ub_over_exact_mean": round(float((upper / exact).mean()), 3)}


def measure(full: bool):
    out = {"budget_s": {}}
    rows = []

    decode = _decode()
    cfgs = _grid_1024()
    batched(decode, cfgs[:4], top_k=0)                   # warm
    t0 = time.perf_counter()
    bs = batched(decode, cfgs, top_k=3)
    t_batched = time.perf_counter() - t0
    sp = {"n_ops": len(decode.ops), "n_configs": len(cfgs),
          "backend": bs.backend, "top_k": 3,
          "batched_s": round(t_batched, 6),
          "per_point_us": round(t_batched / len(cfgs) * 1e6, 2),
          "max_verified_relaxation_err": max(
              abs(v["relaxation_err"]) for v in bs.verified)}
    if full:
        t0 = time.perf_counter()
        exact = sweep(decode, cfgs, executor="process")
        t_proc = time.perf_counter() - t0
        best = bs.verified[0]
        exact_best = min(r.makespan for r in exact)
        sp["process_s"] = round(t_proc, 3)
        sp["speedup_vs_process"] = round(t_proc / t_batched, 1)
        sp["best_matches_exact"] = bool(best["exact_s"] == exact_best)
    out["speedup"] = sp
    out["budget_s"]["batched_1024x5k_decode"] = round(t_batched, 6)
    rows.append(row(
        "dse/batched_1024x5k_decode", t_batched,
        f"n_ops={sp['n_ops']} n_configs={sp['n_configs']} "
        f"per_point_us={sp['per_point_us']} "
        + (f"speedup_vs_process={sp['speedup_vs_process']}x" if full
           else "quick")))

    fid = _dag_fidelity()
    out["dag_fidelity"] = fid
    rows.append(row(
        "dse/dag_bracket_vgg16", 0.0,
        f"n_configs={fid['n_configs']} holds={fid['bracket_holds']} "
        f"lb_err_mean={fid['lb_err_mean']} lb_err_max={fid['lb_err_max']}"))

    t0 = time.perf_counter()
    ps = port_study_optimize()
    t_opt = time.perf_counter() - t0
    out["port_study"] = ps
    out["budget_s"]["optimize_port_study"] = round(t_opt, 6)
    rows.append(row(
        "dse/optimize_port_study", t_opt,
        f"opt_ports={ps['opt_ports']} grid_best_ports={ps['grid_best_ports']} "
        f"within_frac={ps['within_frac']} knee_ports={ps['knee_ports']}"))
    return out, rows


def _check(out, recorded=None):
    """The correctness gates (quick mode checks the recorded speedup)."""
    failed = False
    if not out["dag_fidelity"]["bracket_holds"]:
        print("DSE smoke: DAG lower/upper bracket violated", file=sys.stderr)
        failed = True
    err = out["speedup"]["max_verified_relaxation_err"]
    if not (np.isfinite(err) and err == 0.0):
        print(f"DSE smoke: chain relaxation_err {err} != 0", file=sys.stderr)
        failed = True
    if abs(out["port_study"]["within_frac"]) > PORT_STUDY_TOL:
        print(f"DSE smoke: optimize landed "
              f"{out['port_study']['within_frac']:+.2%} off the grid best "
              f"(tol {PORT_STUDY_TOL:.0%})", file=sys.stderr)
        failed = True
    speedup = (out["speedup"].get("speedup_vs_process")
               or (recorded or {}).get("speedup", {}).get(
                   "speedup_vs_process"))
    if speedup is None or speedup < SPEEDUP_FLOOR:
        print(f"DSE smoke: batched speedup {speedup} below the "
              f"{SPEEDUP_FLOOR:.0f}x floor", file=sys.stderr)
        failed = True
    return failed


def run(emit=print):
    """benchmarks.run driver entry: analytic-side rows only (no process
    sweep, no file writes)."""
    _, rows = measure(full=False)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="analytic-side timing vs the BENCH_dse.json "
                         "budget (2x gate) + bracket/speedup/port-study "
                         "checks (CI perf smoke)")
    args = ap.parse_args()
    out, rows = measure(full=not args.quick)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    if args.quick:
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        recorded = json.loads(BENCH_JSON.read_text())
        failed = _check(out, recorded)
        for name, measured in out["budget_s"].items():
            budget = recorded.get("budget_s", {}).get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured*1e3:.1f}ms vs budget "
                  f"{budget*1e3:.1f}ms (2x gate) {verdict}")
            failed |= verdict != "OK"
        if failed:
            print("bench_dse smoke failed (perf >2x budget or a DSE "
                  "correctness gate broke)", file=sys.stderr)
            sys.exit(1)
        return
    if _check(out):
        sys.exit(1)
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("batched analytic grid vs process-pool exact sweep on "
                   "the gemma-2b decode chain; DAG bound bracket on the "
                   "vgg16 tile DAG; Fig-13 port study via sweep.optimize "
                   "(bench_soc.port_study_optimize); budget_s feeds the "
                   "tools/ci.sh --quick 2x gate")
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
