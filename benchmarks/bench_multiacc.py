"""Fig 12/13 analogue: multi-accelerator scaling on the paper's networks,
simulated on genuine ``SoCTopology`` objects — a CPU frontend device
preprocesses each input and feeds 1..8 NN accelerators over ONE shared
HBM link (4 ports), so reduction affinity caps the speedup, concurrent
tile transfers contend for the shared ports (the Fig 13 effect), and the
serial frontend bounds the end-to-end scaling (Amdahl — the SMAUG claim
that SoC-level effects dominate).  The accelerator-count grid is one
``topology_sweep()`` over a single lowering per network."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine
from repro.sim.hw import Device, Link, SoCTopology
from repro.sim.ir import BYTES_PER_ELEM, CostedOp, Program
from repro.sim.report import row
from repro.sim.sweep import lower_graph, topology_sweep
from benchmarks.common import build_paper_graph

ACCEL_GRID = (1, 2, 4, 8)
BASE = engine.EngineConfig(interface="hbm")
FRONTEND_PEAK = 1e12           # embedded CPU cluster feeding the accels


def soc(n_accels: int) -> SoCTopology:
    """1 CPU frontend + ``n_accels`` accelerators on one 4-port link."""
    return SoCTopology(
        devices=(Device("cpu0", kind="cpu", peak_flops=FRONTEND_PEAK),)
        + tuple(Device(f"acc{i}") for i in range(n_accels)),
        links=(Link("hbm", ports=4.0),),
        name=f"cpu+{n_accels}acc")


def frontend_program(g, batch: int = 1) -> Program:
    """Host preprocessing for one inference: decode + normalize the input
    tensor on the CPU device (a few ops/byte), feeding the network."""
    inp = next(n for n in g.nodes.values() if n.op == "input")
    elems = 1.0
    for d in inp.shape:
        elems *= d
    elems *= batch
    nbytes = BYTES_PER_ELEM * elems
    return Program([CostedOp(
        "frontend/prep", flops=8.0 * elems, bytes_in=nbytes,
        bytes_out=nbytes, phase="frontend", device_class="cpu")],
        name="frontend", source="custom")


def run(emit=print):
    rows = []
    topologies = [soc(n) for n in ACCEL_GRID]
    for name in ("minerva", "lenet5", "cnn10", "vgg16", "elu16"):
        net = PAPER_NETS[name]
        g = build_paper_graph(net, batch=1)
        # small tiles ~ the paper's 32KB scratchpads -> rich tile parallelism
        dnn = lower_graph(g, batch=1, max_tile_elems=2048)
        prog = frontend_program(g).then(dnn, name=f"{name}+frontend")
        results = topology_sweep(prog, topologies, BASE)
        base = results[0].makespan
        for n_acc, res in zip(ACCEL_GRID, results):
            kinds = res.per_kind
            dev_util = res.device_utilization()
            rows.append(row(
                f"multiacc/{name}/acc{n_acc}", res.makespan,
                f"speedup={base / res.makespan:.2f}x "
                f"util={res.utilization():.2f} "
                f"cpu_util={dev_util['cpu0']:.2f} "
                f"xfer_s={kinds.get('transfer', 0):.2e} "
                f"tiles={len(prog)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
