"""Fig 12/13 analogue: multi-accelerator (worker) scaling on the paper's
networks via the unified engine — reduction affinity caps the speedup and
concurrent tile transfers contend for HBM ports (the Fig 13 effect)."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, ir
from repro.sim.report import row
from benchmarks.common import build_paper_graph


def run(emit=print):
    rows = []
    for name in ("minerva", "lenet5", "cnn10", "vgg16", "elu16"):
        net = PAPER_NETS[name]
        g = build_paper_graph(net, batch=1)
        # small tiles ~ the paper's 32KB scratchpads -> rich tile parallelism
        prog = ir.from_graph(g, batch=1, max_tile_elems=2048)
        base = None
        for n_acc in (1, 2, 4, 8):
            res = engine.run(prog, engine.EngineConfig(
                n_workers=n_acc, interface="hbm", hbm_ports=4))
            if base is None:
                base = res.makespan
            kinds = res.per_kind
            rows.append(row(
                f"multiacc/{name}/acc{n_acc}", res.makespan,
                f"speedup={base / res.makespan:.2f}x "
                f"util={res.utilization():.2f} "
                f"xfer_s={kinds.get('transfer', 0):.2e} "
                f"tiles={len(prog)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
