"""Fig 12/13 analogue: multi-accelerator (worker) scaling via the runtime-
scheduler simulation on the paper's networks, including the reduction-
affinity cap and shared-bandwidth contention."""
from __future__ import annotations

from repro.configs.paper_nets import PAPER_NETS
from benchmarks.common import build_paper_graph


def run(emit=print):
    from repro.core.scheduler import simulate
    rows = []
    for name in ("minerva", "lenet5", "cnn10", "vgg16", "elu16"):
        net = PAPER_NETS[name]
        g = build_paper_graph(net, batch=1)
        tasks = g.tile_tasks(batch=1, max_tile_elems=2048)
        # small tiles ~ the paper's 32KB scratchpads -> rich tile-level parallelism
        base = None
        for n_acc in (1, 2, 4, 8):
            tl = simulate(tasks, n_acc, shared_bw_penalty=0.05)
            if base is None:
                base = tl.makespan
            speed = base / tl.makespan
            kinds = tl.per_kind()
            rows.append({
                "name": f"multiacc/{name}/acc{n_acc}",
                "us_per_call": round(tl.makespan * 1e6, 1),
                "derived": (f"speedup={speed:.2f}x "
                            f"util={tl.utilization():.2f} "
                            f"xfer_s={kinds.get('transfer', 0):.2e} "
                            f"tiles={len(tasks)}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
