"""Fig 12/13 analogue: multi-accelerator (worker) scaling on the paper's
networks — reduction affinity caps the speedup and concurrent tile
transfers contend for HBM ports (the Fig 13 effect).  The worker-count grid
is one ``sweep()`` over a single lowering per network."""
from __future__ import annotations

import dataclasses

from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine
from repro.sim.report import row
from repro.sim.sweep import lower_graph, sweep
from benchmarks.common import build_paper_graph

WORKER_GRID = (1, 2, 4, 8)
BASE = engine.EngineConfig(interface="hbm", hbm_ports=4)


def run(emit=print):
    rows = []
    configs = [dataclasses.replace(BASE, n_workers=n) for n in WORKER_GRID]
    for name in ("minerva", "lenet5", "cnn10", "vgg16", "elu16"):
        net = PAPER_NETS[name]
        g = build_paper_graph(net, batch=1)
        # small tiles ~ the paper's 32KB scratchpads -> rich tile parallelism
        prog = lower_graph(g, batch=1, max_tile_elems=2048)
        results = sweep(prog, configs)
        base = results[0].makespan
        for n_acc, res in zip(WORKER_GRID, results):
            kinds = res.per_kind
            rows.append(row(
                f"multiacc/{name}/acc{n_acc}", res.makespan,
                f"speedup={base / res.makespan:.2f}x "
                f"util={res.utilization():.2f} "
                f"xfer_s={kinds.get('transfer', 0):.2e} "
                f"tiles={len(prog)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
