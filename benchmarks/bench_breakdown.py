"""Fig 1 analogue: end-to-end step breakdown (accelerator compute vs data
transfer vs host/framework vs collectives) per architecture.

Migrated to the unified engine: each dry-run HLO record lowers to a
``repro.sim`` Program and ONE engine run yields the breakdown, the roofline
terms, and the energy of the same simulated execution."""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.simulator import HOST_OVERHEAD_S
from repro.sim import engine, ir
from repro.sim.report import fractions_str, row


def run(emit=print):
    res_path = Path("experiments/dryrun/results.json")
    if not res_path.exists():
        return [{"name": "breakdown/missing", "us_per_call": "",
                 "derived": "run repro.launch.dryrun first"}]
    res = json.loads(res_path.read_text())
    rows = []
    for key, r in sorted(res.items()):
        if r["status"] != "ok" or r["mesh"] != "pod16x16":
            continue
        if r["shape"] != "train_4k":
            continue
        prog = ir.from_hlo(r["hlo"], name=r["arch"])
        result = engine.run(prog, engine.EngineConfig(
            n_workers=1, interface="hbm",
            host_floor_s=100e-6 + HOST_OVERHEAD_S))
        b = result.breakdown
        rows.append(row(
            f"breakdown/{r['arch']}", b.total_s,
            f"{fractions_str(b)} "
            f"step_j={result.energy['total_j']:.2f} "
            f"(paper: accel ~25%, xfer ~34%, cpu ~42%)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
