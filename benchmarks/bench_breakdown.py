"""Fig 1 analogue: end-to-end step breakdown (accelerator compute vs data
transfer vs host/framework vs collectives) per architecture, derived from
the committed dry-run artifacts via the full-stack simulator."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config
from repro.core.config import SHAPE_BY_NAME
from repro.core.simulator import breakdown


def run(emit=print):
    res_path = Path("experiments/dryrun/results.json")
    if not res_path.exists():
        return [{"name": "breakdown/missing", "us_per_call": "",
                 "derived": "run repro.launch.dryrun first"}]
    res = json.loads(res_path.read_text())
    rows = []
    for key, r in sorted(res.items()):
        if r["status"] != "ok" or r["mesh"] != "pod16x16":
            continue
        if r["shape"] != "train_4k":
            continue
        b = breakdown(r["hlo"], host_prep_s=100e-6)
        f = b.fractions()
        rows.append({
            "name": f"breakdown/{r['arch']}",
            "us_per_call": round(b.total_s * 1e6, 1),
            "derived": (f"accel={f['accelerator']*100:.0f}% "
                        f"transfer={f['transfer']*100:.0f}% "
                        f"host={f['host']*100:.0f}% "
                        f"coll={f['collective']*100:.0f}% "
                        f"(paper: accel ~25%, xfer ~34%, cpu ~42%)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
