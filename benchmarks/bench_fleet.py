"""Fleet-scale serving replay: the memoized fast path at 1M requests.

Four studies, all feeding ``BENCH_fleet.json`` at the repo root:

* **headline** — a 1M-request diurnal trace replayed across a 4-replica
  gemma-2b fleet through ``simulate_fleet`` (one shared
  ``StepCostTable``).  Records the simulated-request rate and the memo
  hit rate.  Acceptance: >= 50k requests/s, <= 20s wall.
* **speedup** — ``replay_serving`` (memoized lite path) vs
  ``simulate_serving(memoize=False)`` (per-step ``ir.from_serving_step``
  + ``engine.chain_op_costs`` + the engine run) on the same 10k-request
  trace.  Acceptance: >= 10x, bit-identical wall/busy clocks.
* **bit_identity** — replay vs the full co-simulation across all three
  batching policies on a 256-request trace: every ``stats()`` field must
  match exactly (the memo and the aggregate-counter scheduler change the
  cost of the simulation, never its arithmetic).
* **fleet_grid / autoscale** — the router x replica-count grid
  (``sweep.fleet_sweep``) plus a queue-depth autoscaler ride-through of a
  bursty trace: SLO attainment, cost-per-token and scale events per cell.

``--quick`` (the ``tools/ci.sh`` perf smoke) replays a 100k-request slice
against its recorded budget (2x gate), enforces a replay-rate floor at
HALF the recorded headline rate, and re-runs the bit-identity and
conservation probes — the 1M-request and unmemoized sides run only in
full mode.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.serve.policy import QueueDepthAutoscaler, get_policy
from repro.sim.engine import EngineConfig
from repro.sim.report import row
from repro.sim.serving import (as_fleet_records, bursty_trace,
                               diurnal_trace, poisson_trace,
                               replay_serving, simulate_fleet,
                               simulate_serving)
from repro.sim.sweep import fleet_sweep

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_fleet.json"

REPLAY_RATE_FLOOR = 50_000.0   # simulated requests/s on the 1M headline
HEADLINE_WALL_CAP_S = 20.0
SPEEDUP_FLOOR = 10.0           # replay vs unmemoized co-simulation

N_HEADLINE = 1_000_000
N_QUICK = 100_000
CONFIG = EngineConfig(n_workers=1, interface="hbm", hbm_ports=4,
                      host_dispatch_s=50e-6)
FLEET_POLICY = get_policy("continuous", max_batch=64)


def _headline_trace(n: int):
    """The diurnal day: 1M requests over a sinusoidal arrival wave."""
    return diurnal_trace(n, 4000.0, output_len=(4, 16), seed=0,
                         arrays=True)


def _replay_headline(n: int):
    trace = _headline_trace(n)
    t0 = time.perf_counter()
    f = simulate_fleet(GEMMA_2B, trace, FLEET_POLICY, CONFIG,
                       n_replicas=4, router="round_robin")
    wall = time.perf_counter() - t0
    return {"n_requests": n, "n_replicas": f.n_replicas,
            "router": "round_robin", "policy": FLEET_POLICY.kind,
            "max_batch": FLEET_POLICY.max_batch,
            "wall_s": round(wall, 3),
            "replay_rate_rps": round(n / wall, 1),
            "n_steps": f.n_steps,
            "memo_hit_rate": round(f.meta["memo_hit_rate"], 4),
            "occupancy": round(f.occupancy, 4),
            "slo_attainment": round(f.slo_attainment(), 4)}, wall


def _speedup():
    """Memoized lite replay vs the unmemoized full co-simulation."""
    trace = poisson_trace(10_000, 2000.0, output_len=(32, 96), seed=1)
    policy = get_policy("continuous", max_batch=8)
    replay_serving(GEMMA_2B, trace[:256], policy, CONFIG)       # warm
    t0 = time.perf_counter()
    fast = replay_serving(GEMMA_2B, trace, policy, CONFIG)
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    slow = simulate_serving(GEMMA_2B, trace, policy, CONFIG,
                            memoize=False, max_steps=10_000_000)
    t_slow = time.perf_counter() - t0
    return {"n_requests": len(trace), "replay_s": round(t_fast, 4),
            "unmemoized_s": round(t_slow, 3),
            "speedup": round(t_slow / t_fast, 1),
            "bit_identical": bool(fast.busy_s == slow.busy_s
                                  and fast.makespan_s == slow.makespan_s
                                  and fast.stats() == slow.stats())}


def _bit_identity():
    """Replay == full co-simulation, all policies, every stats field."""
    ok = True
    for kind, gen in (("poisson", poisson_trace), ("bursty", bursty_trace)):
        trace = gen(256, 120.0, seed=3)
        for pname in ("static", "dynamic", "continuous"):
            policy = get_policy(pname, max_batch=8)
            a = simulate_serving(GEMMA_2B, trace, policy, CONFIG)
            b = replay_serving(GEMMA_2B, trace, policy, CONFIG)
            ok &= a.stats() == b.stats()
            f = simulate_fleet(GEMMA_2B, trace, policy, CONFIG,
                               n_replicas=1)
            ok &= f.makespan_s == b.makespan_s \
                and f.busy_s == b.busy_s
    return {"n_requests": 256, "traces": ["poisson", "bursty"],
            "policies": ["static", "dynamic", "continuous"],
            "bit_identical": bool(ok)}


def _conservation():
    """Every routed request is served exactly once, on every router."""
    import numpy as np
    trace = diurnal_trace(2000, 500.0, seed=5, arrays=True)
    ok = True
    for router in ("round_robin", "least_outstanding", "session_affinity"):
        f = simulate_fleet(GEMMA_2B, trace, FLEET_POLICY, CONFIG,
                           n_replicas=3, router=router)
        ok &= bool(np.isfinite(np.asarray(f.finish_s)).all())
        ok &= sum(len(r.rid) for r in f.replicas) == f.n_requests
    return {"n_requests": len(trace), "n_replicas": 3,
            "all_served_once": bool(ok)}


def _fleet_grid():
    # one replica sustains ~36 req/s under CONFIG, so 100 rps sweeps the
    # fleet from overloaded (N=1) to comfortable (N=4)
    results = fleet_sweep(GEMMA_2B, replica_counts=(1, 2, 4),
                          n_requests=2000, rate_rps=100.0,
                          config=CONFIG)
    return as_fleet_records(results)


def _autoscale():
    trace = bursty_trace(5000, 400.0, seed=2)
    scaler = QueueDepthAutoscaler(min_replicas=1, max_replicas=4,
                                  scale_up_depth=16.0,
                                  scale_down_depth=2.0, cooldown_s=0.5)
    f = simulate_fleet(GEMMA_2B, trace, get_policy("continuous",
                                                   max_batch=8),
                       CONFIG, n_replicas=1, router="least_outstanding",
                       autoscaler=scaler)
    ups = sum(1 for e in f.scale_events if e.action == "up")
    return {"n_requests": len(trace), "n_scale_events": len(f.scale_events),
            "scale_ups": ups, "scale_downs": len(f.scale_events) - ups,
            "peak_replicas": max((e.n_replicas for e in f.scale_events),
                                 default=1),
            "slo_attainment": round(f.slo_attainment(), 4),
            "cost_per_token_j": f.cost_per_token_j()}


def measure(full: bool):
    out = {"budget_s": {}}
    rows = []

    n = N_HEADLINE if full else N_QUICK
    hl, wall = _replay_headline(n)
    key = "fleet_replay_1m" if full else "fleet_replay_100k_quick"
    out["headline" if full else "headline_quick"] = hl
    out["budget_s"][key] = round(wall, 3)
    rows.append(row(
        f"fleet/replay_{n//1000}k_diurnal", wall,
        f"rate={hl['replay_rate_rps']:,.0f}req/s steps={hl['n_steps']} "
        f"hit={hl['memo_hit_rate']} occ={hl['occupancy']}"))
    if full:
        # record the quick-sized budget too, so --quick has a recorded
        # baseline of its own size to gate against
        hq, wq = _replay_headline(N_QUICK)
        out["headline_quick"] = hq
        out["budget_s"]["fleet_replay_100k_quick"] = round(wq, 3)

        sp = _speedup()
        out["speedup"] = sp
        rows.append(row(
            "fleet/replay_vs_unmemoized", sp["replay_s"],
            f"speedup={sp['speedup']}x over {sp['n_requests']} requests "
            f"bit_identical={sp['bit_identical']}"))

    bi = _bit_identity()
    out["bit_identity"] = bi
    rows.append(row(
        "fleet/bit_identity_replay_vs_sim", 0.0,
        f"policies={len(bi['policies'])}x{len(bi['traces'])}traces "
        f"identical={bi['bit_identical']}"))

    cons = _conservation()
    out["conservation"] = cons
    rows.append(row(
        "fleet/router_conservation", 0.0,
        f"routers=3 all_served_once={cons['all_served_once']}"))

    if full:
        out["fleet_grid"] = _fleet_grid()
        best = max(out["fleet_grid"], key=lambda r: r["slo_attainment"])
        rows.append(row(
            "fleet/router_x_replicas_grid", 0.0,
            f"cells={len(out['fleet_grid'])} best={best['router']}"
            f"x{best['n_replicas']} slo={best['slo_attainment']:.3f}"))

        asc = _autoscale()
        out["autoscale"] = asc
        rows.append(row(
            "fleet/queue_depth_autoscaler", 0.0,
            f"events={asc['n_scale_events']} "
            f"peak_replicas={asc['peak_replicas']} "
            f"slo={asc['slo_attainment']:.3f}"))
    return out, rows


def _check(out, recorded=None):
    """The correctness/perf gates (quick mode checks recorded floors)."""
    failed = False
    if not out["bit_identity"]["bit_identical"]:
        print("fleet smoke: replay is not bit-identical to the full "
              "co-simulation", file=sys.stderr)
        failed = True
    if not out["conservation"]["all_served_once"]:
        print("fleet smoke: router lost or duplicated requests",
              file=sys.stderr)
        failed = True
    hl = out.get("headline")
    if hl is not None:
        if hl["replay_rate_rps"] < REPLAY_RATE_FLOOR:
            print(f"fleet smoke: headline replay rate "
                  f"{hl['replay_rate_rps']:,.0f} req/s below the "
                  f"{REPLAY_RATE_FLOOR:,.0f} floor", file=sys.stderr)
            failed = True
        if hl["wall_s"] > HEADLINE_WALL_CAP_S:
            print(f"fleet smoke: headline wall {hl['wall_s']}s above the "
                  f"{HEADLINE_WALL_CAP_S}s cap", file=sys.stderr)
            failed = True
    else:
        # quick: the scaled replay must hold half the recorded headline
        rec_rate = (recorded or {}).get("headline", {}) \
            .get("replay_rate_rps")
        q_rate = out["headline_quick"]["replay_rate_rps"]
        if rec_rate is None or q_rate < rec_rate / 2.0:
            print(f"fleet smoke: quick replay rate {q_rate:,.0f} req/s "
                  f"below half the recorded headline "
                  f"({rec_rate} req/s)", file=sys.stderr)
            failed = True
    sp = out.get("speedup") or (recorded or {}).get("speedup", {})
    if not sp.get("bit_identical", False):
        print("fleet smoke: speedup probe lost bit-identity",
              file=sys.stderr)
        failed = True
    if sp.get("speedup", 0.0) < SPEEDUP_FLOOR:
        print(f"fleet smoke: memoized speedup {sp.get('speedup')} below "
              f"the {SPEEDUP_FLOOR:.0f}x floor", file=sys.stderr)
        failed = True
    return failed


def run(emit=print):
    """benchmarks.run driver entry: the probes only (no 1M replay, no
    unmemoized side, no file writes)."""
    _, rows = measure(full=False)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="100k-request replay vs the BENCH_fleet.json "
                         "budget (2x gate) + half-headline rate floor + "
                         "bit-identity/conservation probes (CI perf "
                         "smoke)")
    args = ap.parse_args()
    out, rows = measure(full=not args.quick)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    if args.quick:
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        recorded = json.loads(BENCH_JSON.read_text())
        failed = _check(out, recorded)
        for name, measured in out["budget_s"].items():
            budget = recorded.get("budget_s", {}).get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured:.2f}s vs budget "
                  f"{budget:.2f}s (2x gate) {verdict}")
            failed |= verdict != "OK"
        if failed:
            print("bench_fleet smoke failed (perf >2x budget, rate below "
                  "floor, or a fleet correctness gate broke)",
                  file=sys.stderr)
            sys.exit(1)
        return
    if _check(out):
        sys.exit(1)
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("memoized fleet replay headline (1M-request diurnal "
                   "trace, 4 replicas) + replay-vs-unmemoized speedup + "
                   "bit-identity / router-conservation probes + the "
                   "router x replica grid and queue-depth autoscaler "
                   "study; budget_s feeds the tools/ci.sh --quick 2x "
                   "gate")
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
