"""§Roofline source: the three roofline terms per (arch x shape) cell from
the dry-run artifact (single-pod mesh), with bottleneck + useful-FLOPs
ratio.  This is the table EXPERIMENTS.md §Roofline embeds."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config
from repro.core.config import SHAPE_BY_NAME
from repro.core.simulator import roofline


def compute_all(mesh_name="pod16x16"):
    res_path = Path("experiments/dryrun/results.json")
    res = json.loads(res_path.read_text())
    out = {}
    for key, r in sorted(res.items()):
        if r["mesh"] != mesh_name:
            continue
        if r["status"] == "skip":
            out[key] = {"status": "skip", "reason": r["reason"],
                        "arch": r["arch"], "shape": r["shape"]}
            continue
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        shape = SHAPE_BY_NAME[r["shape"]]
        n_chips = 512 if "2x16" in mesh_name else 256
        rl = roofline(r["hlo"], cfg, shape, n_chips)
        from repro.core.simulator import energy
        e = energy(r["hlo"], rl.step_s, n_chips)
        out[key] = {"status": "ok", "arch": r["arch"], "shape": r["shape"],
                    **rl.to_dict(),
                    "energy_j_per_chip": e["total_j"],
                    "energy_j_total": e["total_j_all_chips"]}
    return out


def run(emit=print):
    rows = []
    for key, r in compute_all().items():
        if r["status"] == "skip":
            rows.append({"name": f"roofline/{r['arch']}/{r['shape']}",
                         "us_per_call": "", "derived": f"SKIP: {r['reason']}"})
            continue
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": round(r["step_s"] * 1e6, 1),
            "derived": (f"compute={r['compute_s']:.2e}s "
                        f"memory={r['memory_s']:.2e}s "
                        f"coll={r['collective_s']:.2e}s "
                        f"bound={r['bound']} "
                        f"useful={r['useful_ratio']*100:.0f}% "
                        f"roofline_frac={r['roofline_fraction']*100:.1f}%")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
