"""Camera-SoC tuning: the heterogeneous topology sweep (SMAUG §V).

One simulated execution per SoC: the camera ISP runs on the frontend
device (embedded CPU or vector DSP) and feeds the CNN10 tile program to
1..8 NN accelerators over ONE shared HBM link — varying the *topology*
(frontend kind x accelerator count x shared-port count), exactly the
knobs the paper's camera-SoC study turns.  Per-device utilization and
breakdown separate the frontend from the accelerators, which a flat
worker pool cannot express.

Full mode (``python -m benchmarks.bench_soc``) writes the grid and the
CI budgets to ``BENCH_soc.json`` at the repo root.

``--quick`` (the ``tools/ci.sh`` perf smoke) re-times the sweep against
the recorded budget with the 2x-regression gate, and additionally runs
the homogeneous-equivalence probe: a flat ``EngineConfig`` and its
explicit ``SoCTopology.homogeneous`` expansion must produce bit-identical
results (exit 1 on either failure).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

from repro.apps.camera import camera_soc, soc_frame_sweep
from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, ir
from repro.sim.hw import SoCTopology
from repro.sim.report import row
from repro.sim.sweep import lower_graph
from benchmarks.common import build_paper_graph

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = ROOT / "BENCH_soc.json"

# the SoC grid: frontend kind x accelerator count x shared-port count
FRONTENDS = ("cpu", "dsp")
ACCEL_GRID = (1, 2, 4, 8)
PORT_GRID = (1.0, 4.0)  # narrow vs wide shared-port pool
# embedded-SoC base point (the paper's regime, not the datacenter chip):
# 128 GFLOP/s NN accelerators (8x8 PE at GHz scale) streaming over a
# shared LPDDR4-class link; the frontend peaks come from
# apps.camera.FRONTEND_PEAK and its stencils run fused via acp
BASE = engine.EngineConfig(interface="dma", peak_flops=1.28e11,
                           hbm_bw=25.6e9, vmem_bw=1e12,
                           host_dispatch_s=1e-6)


def _grid():
    return [camera_soc(n, frontend, link_ports=p)
            for frontend in FRONTENDS for n in ACCEL_GRID
            for p in PORT_GRID]


def _dnn_program():
    g = build_paper_graph(PAPER_NETS["cnn10"], batch=1)
    return lower_graph(g, batch=1, max_tile_elems=2048)


def measure():
    dnn = _dnn_program()
    t0 = time.perf_counter()
    cells = soc_frame_sweep(dnn, _grid(), BASE)
    sweep_s = time.perf_counter() - t0
    records, rows = [], []
    for topo, frame, res in cells:
        frontend = topo.devices[0]
        util = res.device_utilization()
        accel_utils = [util[d.name] for d in topo.devices
                       if d.kind == "accel"]
        bds = res.device_breakdowns()
        fbd = bds.get(frontend.name)
        phases = res.per_phase
        rec = {
            "topology": topo.name, "frontend": frontend.kind,
            "n_accels": topo.n_accel,
            "link_ports": topo.links[0].ports,
            "makespan_s": res.makespan,
            "isp_s": phases.get("isp", 0.0),
            "frontend_util": util[frontend.name],
            "accel_util_mean": sum(accel_utils) / len(accel_utils),
            "frontend_compute_s": fbd.accelerator_s if fbd else 0.0,
            "frontend_transfer_s": fbd.transfer_s if fbd else 0.0,
            "accel_compute_s": sum(
                bds[d.name].accelerator_s for d in topo.devices
                if d.kind == "accel" and d.name in bds),
            "accel_transfer_s": sum(
                bds[d.name].transfer_s for d in topo.devices
                if d.kind == "accel" and d.name in bds),
            "transfer_s": res.breakdown.transfer_s,
            "bound": res.roofline.bound,
            "total_j": res.energy["total_j"],
        }
        records.append(rec)
        rows.append(row(
            f"soc/{topo.name}", res.makespan,
            f"front_util={rec['frontend_util']:.2f} "
            f"acc_util={rec['accel_util_mean']:.2f} "
            f"isp_ms={rec['isp_s']*1e3:.2f} "
            f"bound={rec['bound']}"))
    return {"records": records,
            "budget_s": {"soc_sweep_16cells": round(sweep_s, 6)},
            "grid": {"frontends": list(FRONTENDS),
                     "n_accels": list(ACCEL_GRID),
                     "link_ports": list(PORT_GRID)}}, rows, sweep_s


# ---------------------------------------------------------------------------
# the Fig-13 shared-port question, re-answered by gradient search: instead
# of sweeping a hand-picked port grid, ``sweep.optimize`` descends the
# analytic cost model over a continuous port range and the event engine
# verifies the returned design.  The exact grid is kept as the referee —
# the optimizer's design must land within 2% of the grid-best makespan
# (gated by bench_dse --quick and tests/test_artifacts.py).

PORT_SPACE = (0.25, 8.0)
PORT_STUDY_GRID = (0.25, 0.375, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0)


def port_study_optimize(n_accels: int = 4):
    """Minimize CNN10 frame latency over the shared-port count on the
    embedded base point with ``n_accels`` accelerators.

    Returns a record with the exact grid (ports -> makespan), the grid
    best, the optimizer's design + exact-verified makespan, the relative
    gap ``within_frac``, and the saturation knee (smallest gridded port
    count whose exact latency is within 5% of the saturated best)."""
    from repro.sim.sweep import optimize, sweep
    dnn = _dnn_program()
    base = dataclasses.replace(BASE, n_workers=n_accels)
    cfgs = [dataclasses.replace(base, hbm_ports=p) for p in PORT_STUDY_GRID]
    exact = [r.makespan for r in sweep(dnn, cfgs)]
    best_i = min(range(len(exact)), key=exact.__getitem__)
    opt = optimize(dnn, {"hbm_ports": PORT_SPACE}, base_config=base,
                   n_starts=6, steps=30, seed=0)
    knee = next(p for p, e in zip(PORT_STUDY_GRID, exact)
                if e <= 1.05 * exact[best_i])
    return {
        "program": dnn.name, "n_ops": len(dnn.ops), "n_accels": n_accels,
        "port_space": list(PORT_SPACE),
        "grid_ports": list(PORT_STUDY_GRID),
        "grid_exact_s": [round(e, 9) for e in exact],
        "grid_best_ports": PORT_STUDY_GRID[best_i],
        "grid_best_s": round(exact[best_i], 9),
        "opt_ports": round(opt.params["hbm_ports"], 4),
        "opt_exact_s": round(opt.exact_s, 9),
        "opt_analytic_s": round(opt.analytic_s, 9),
        "opt_n_evals": opt.n_evals,
        "opt_backend": opt.backend,
        "within_frac": round(opt.exact_s / exact[best_i] - 1.0, 6),
        "knee_ports": knee,
    }


# ---------------------------------------------------------------------------
# homogeneous-equivalence probe: flat config == explicit expansion, bit
# for bit (the topology layer's correctness gate, cheap enough for CI)


def check_homogeneous_equivalence() -> bool:
    from repro.configs.gemma_2b import SMOKE
    probes = [
        ir.from_decode(SMOKE, n_tokens=16, ops_per_token=4),    # chain path
        _dnn_program(),                                         # event loop
    ]
    flats = [
        engine.EngineConfig(n_workers=4, interface="hbm", hbm_ports=2),
        engine.EngineConfig(n_workers=8, interface="acp", hbm_ports=1,
                            host_dispatch_s=1e-6, host_bw=20e9,
                            host_threads=4),
    ]
    ok = True
    for prog in probes:
        for cfg in flats:
            topo_cfg = dataclasses.replace(
                cfg, topology=SoCTopology.homogeneous(cfg.n_workers))
            a = engine.run(prog, cfg)
            b = engine.run(prog, topo_cfg)
            same = (a.makespan == b.makespan
                    and a.breakdown == b.breakdown
                    and a.roofline == b.roofline
                    and a.energy == b.energy
                    and a.timeline.events == b.timeline.events)
            if not same:
                print(f"homogeneous-equivalence FAILED: {prog.name} on "
                      f"{cfg.interface}/{cfg.n_workers}w", file=sys.stderr)
                ok = False
    return ok


def run(emit=print):
    """benchmarks.run driver entry: the sweep rows (no file writes)."""
    _, rows, _ = measure()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="sweep timing vs the BENCH_soc.json budget (2x "
                         "gate) + the homogeneous-equivalence probe")
    args = ap.parse_args()
    out, rows, sweep_s = measure()
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    if args.quick:
        failed = not check_homogeneous_equivalence()
        if not failed:
            print("perf-smoke soc: homogeneous-equivalence OK")
        if not BENCH_JSON.exists():
            print(f"no {BENCH_JSON.name}; run without --quick to record "
                  "budgets", file=sys.stderr)
            sys.exit(1)
        budgets = json.loads(BENCH_JSON.read_text()).get("budget_s", {})
        for name, measured in out["budget_s"].items():
            budget = budgets.get(name)
            if budget is None:
                continue
            verdict = "OK" if measured <= 2.0 * budget else "REGRESSION"
            print(f"perf-smoke {name}: {measured*1e3:.1f}ms vs budget "
                  f"{budget*1e3:.1f}ms (2x gate) {verdict}")
            failed |= verdict != "OK"
        if failed:
            print("bench_soc smoke failed (perf >2x budget or "
                  "equivalence broken)", file=sys.stderr)
            sys.exit(1)
        return
    if not check_homogeneous_equivalence():
        sys.exit(1)
    out["recorded"] = time.strftime("%Y-%m-%d")
    out["note"] = ("camera-SoC topology sweep (frontend x n_accels x "
                   "shared-link ports) on the composed ISP+CNN10 frame "
                   "program; budget_s feeds the tools/ci.sh --quick 2x "
                   "gate")
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
