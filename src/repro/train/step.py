"""Training step: value_and_grad + AdamW, with optional microbatch gradient
accumulation (hides the DP all-reduce behind compute and divides live
activation memory) and remat already applied inside the model scan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    weight_decay: float = 0.1
    n_microbatches: int = 1     # >1 => gradient accumulation via scan


def init_train_state(cfg: ModelConfig, rng):
    params, axes = T.init_params(cfg, rng)
    opt = adamw_init(params)
    opt_axes = {"m": axes, "v": axes, "count": ()}
    return params, opt, axes, opt_axes


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  Suitable for jax.jit with shardings."""
    from repro.optim.optimizers import cosine_schedule
    lr_fn = cosine_schedule(tc.lr, tc.warmup, tc.total_steps)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch, step):
        if tc.n_microbatches > 1:
            n = tc.n_microbatches

            def reshape(x):
                return x.reshape(n, x.shape[0] // n, *x.shape[1:])
            micro = jax.tree_util.tree_map(reshape, batch)

            def acc_body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc,
                                             (loss, grads))
                return acc, metrics
            zero = (jnp.zeros(()),
                    jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, grads), metrics = jax.lax.scan(acc_body, zero, micro)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss_sum / n
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m, axis=0),
                                             metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr_fn(step),
            weight_decay=tc.weight_decay)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr_fn(step))
        return params, opt_state, metrics

    return train_step
