from repro.train.step import (  # noqa: F401
    TrainConfig, make_train_step, init_train_state)
