"""Trace-driven serving simulation: request arrivals, batching, the engine.

SMAUG's core claim is that end-to-end behavior — queueing, data movement
and framework overhead *around* the accelerator — dominates what per-layer
kernel models predict.  This module extends that argument from a single
request to a served workload: a trace of requests (arrival time, prompt
length, output length) is replayed against a batching policy
(``repro.serve.policy``), every scheduler iteration is lowered to costed
ops via ``ir.from_serving_step``, and the chained step programs run
through the PR-1/2 event engine — so one simulation yields per-request
latency (TTFT / TPOT / p50 / p99), throughput and batch occupancy
*alongside* the existing Timeline / Breakdown / Roofline / energy views.

The pieces:

  ``Request`` / ``poisson_trace`` / ``bursty_trace``
      synthetic workload generators (seeded, fully deterministic) plus a
      loadable record format (``load_trace`` / ``save_trace`` /
      ``trace_from_records``: JSON or JSON-lines with ``arrival_s``,
      ``prompt_len``, ``output_len`` fields);
  ``simulate_serving(cfg, trace, policy, config)``
      the scheduler co-simulation (below), returning a ``ServingResult``;
  ``serving_sweep`` / ``as_serving_records``
      the policy x arrival-rate design-space grid, one ``ServingResult``
      per cell, flattened to tidy records like ``sweep.as_records``.

How the co-simulation works.  Batching decisions depend on simulated time
(arrivals race batch completions), so the scheduler advances its own clock
while it builds the program: each iteration it forms a step per the
policy, lowers it with ``ir.from_serving_step``, and advances time by the
step's cost from ``engine.chain_op_costs`` — the exact per-op terms of the
engine's chain fast path, added in the engine's addition order.  The
chained steps form a pure linear chain, so when the finished program runs
through ``sweep()`` the engine's makespan equals the scheduler's
accumulated busy time *bit-for-bit* (asserted in tests/test_serving.py);
the wall clock additionally contains the idle gaps where the server waited
for arrivals, which exist only in the scheduler's timeline
(``ServingResult.makespan_s`` vs ``EngineResult.makespan``).

Same trace + same policy + same config => bit-identical ``ServingResult``
(the scheduler is deterministic and the engine already is).

Fleet scale.  Three additions let the same co-simulation replay
million-request traces across an N-replica fleet in seconds:

  ``StepCostTable``
      memoized exact step pricing.  ``ir.from_serving_step`` reads a
      step's composition only through the signature
      ``(prefill-length tuple, decode batch, decode position sum)``
      (see ``ir.serving_step_signature``), and ``engine.chain_op_costs``
      is pure in (op fields, config) — so each distinct signature is
      priced once via ``costmodel``'s per-op chain terms and every
      repeat is an O(1) dict hit, bit-identical to the unmemoized path;
  ``replay_serving`` / ``_Replica``
      the lite fast path: the identical scheduler state machine
      re-expressed over aggregate counters (live count, position sum, a
      finish heap) with no op materialization and no engine run —
      O(1) Python work per step regardless of batch size, bit-identical
      wall/busy clocks and per-request times (asserted in
      tests/test_fleet.py);
  ``simulate_fleet`` / ``FleetResult``
      N ``_Replica`` schedulers behind a router
      (``repro.serve.policy``: round_robin / least_outstanding /
      session_affinity) and an optional queue-depth autoscaler, rolled
      up into SLO attainment, cost-per-token (energy model) and
      scale-up/down events.

``diurnal_trace`` (sinusoidal-rate arrivals), ``TraceArrays`` (columnar
traces, no per-request objects) and ``iter_trace`` (lazy ``.jsonl[.gz]``
streaming) feed the fleet path at 1M-request scale; see
benchmarks/bench_fleet.py for the headline replay-rate numbers.
"""
from __future__ import annotations

import gzip
import json
from collections import deque
from dataclasses import dataclass, field
from heapq import heappop, heappush
from itertools import chain as _chain
from typing import Deque, Dict, Iterable, Iterator, List, Optional, \
    Sequence, Tuple, Union

from repro.core.energy import EnergyModel
from repro.core.timeline import Timeline
from repro.serve.policy import BatchingPolicy, QueueDepthAutoscaler, \
    RouterPolicy, StaticBatching, get_router
from repro.sim import costmodel, engine, ir
from repro.sim.engine import EngineConfig, EngineResult
from repro.sim.ir import Program
from repro.sim.report import latency_stats_array

__all__ = [
    "Request", "RequestMetrics", "StepRecord", "ServingResult",
    "ReplayResult", "FleetResult", "ScaleEvent", "StepCostTable",
    "TraceArrays", "poisson_trace", "bursty_trace", "diurnal_trace",
    "trace_from_records", "load_trace", "save_trace", "iter_trace",
    "simulate_serving", "replay_serving", "simulate_fleet",
    "serving_sweep", "as_serving_records", "as_fleet_records",
]


# ---------------------------------------------------------------------------
# the request trace


@dataclass(frozen=True)
class Request:
    """One serving request: when it arrives and how much work it is."""
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int


_Len = Union[int, Tuple[int, int]]

# name -> generator, the ``trace_kind`` registry shared by serving_sweep
# and apps.serving.serve_trace (populated after the generators below)
TRACE_GENERATORS: Dict[str, object] = {}


def _draw_len(rng, spec: _Len, n: int):
    if isinstance(spec, int):
        return [spec] * n
    lo, hi = spec
    return [int(v) for v in rng.integers(lo, hi + 1, size=n)]


def poisson_trace(n_requests: int, rate_rps: float, *,
                  prompt_len: _Len = (16, 128), output_len: _Len = (8, 64),
                  seed: int = 0) -> List[Request]:
    """Poisson arrivals at ``rate_rps`` requests/s; prompt and output
    lengths uniform over inclusive ``(lo, hi)`` ranges (or fixed ints).
    Seeded and deterministic: the same arguments always yield the same
    trace."""
    import numpy as np
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps)
    plens = _draw_len(rng, prompt_len, n_requests)
    olens = _draw_len(rng, output_len, n_requests)
    return [Request(i, float(arrivals[i]), max(plens[i], 1),
                    max(olens[i], 1)) for i in range(n_requests)]


def bursty_trace(n_requests: int, rate_rps: float, *, burst_size: int = 8,
                 burst_factor: float = 10.0, prompt_len: _Len = (16, 128),
                 output_len: _Len = (8, 64), seed: int = 0) -> List[Request]:
    """Bursty arrivals: groups of ``burst_size`` requests arrive at
    ``burst_factor``x the base rate, separated by exponential lulls of mean
    ``burst_size / rate_rps`` — the long-run rate stays near ``rate_rps``
    but queue depth spikes, which is what separates admission policies."""
    import numpy as np
    rng = np.random.default_rng(seed)
    t = 0.0
    arrivals = []
    for i in range(n_requests):
        if i and i % burst_size == 0:
            t += float(rng.exponential(burst_size / rate_rps))
        else:
            t += float(rng.exponential(1.0 / (rate_rps * burst_factor)))
        arrivals.append(t)
    plens = _draw_len(rng, prompt_len, n_requests)
    olens = _draw_len(rng, output_len, n_requests)
    return [Request(i, arrivals[i], max(plens[i], 1), max(olens[i], 1))
            for i in range(n_requests)]


@dataclass(frozen=True)
class TraceArrays:
    """Columnar (struct-of-arrays) trace: numpy columns instead of one
    ``Request`` object per row — the allocation-free input format
    ``replay_serving`` / ``simulate_fleet`` want at 1M-request scale
    (``diurnal_trace(..., arrays=True)`` produces it).  Iterating yields
    ``Request`` objects, so it also feeds ``simulate_serving`` and
    ``save_trace`` unchanged."""
    arrival_s: object            # (n,) float64
    prompt_len: object           # (n,) int64, >= 1
    output_len: object           # (n,) int64, >= 1
    rid: object                  # (n,) int64, unique

    def __len__(self) -> int:
        return len(self.rid)

    def __iter__(self) -> Iterator[Request]:
        a, r, p, o = (self.arrival_s.tolist(), self.rid.tolist(),
                      self.prompt_len.tolist(), self.output_len.tolist())
        for i in range(len(r)):
            yield Request(r[i], a[i], p[i], o[i])

    def columns(self) -> Tuple[list, list, list, list]:
        """(arrival_s, rid, prompt_len, output_len) as plain Python
        lists, sorted by (arrival_s, rid) and duplicate-rid checked —
        the scheduler-ready form."""
        import numpy as np
        a = np.asarray(self.arrival_s, dtype=np.float64)
        r = np.asarray(self.rid, dtype=np.int64)
        p = np.asarray(self.prompt_len, dtype=np.int64)
        o = np.asarray(self.output_len, dtype=np.int64)
        if np.unique(r).size != r.size:
            raise ValueError("duplicate rid in trace; per-request metrics "
                             "are keyed on it")
        order = np.lexsort((r, a))
        a, r, p, o = a[order], r[order], p[order], o[order]
        return a.tolist(), r.tolist(), p.tolist(), o.tolist()


def diurnal_trace(n_requests: int, rate_rps: float, *,
                  period_s: Optional[float] = None, amplitude: float = 0.8,
                  prompt_len: _Len = (16, 128), output_len: _Len = (8, 64),
                  seed: int = 0, arrays: bool = False
                  ) -> Union[List[Request], TraceArrays]:
    """Diurnal (sinusoidal-rate) arrivals: an inhomogeneous Poisson
    process with ``rate(t) = rate_rps * (1 + amplitude*sin(2*pi*t /
    period_s))`` — the day/night load curve a fleet autoscaler is sized
    against.  Generated by inverting the cumulative rate function on a
    fine grid (seeded, fully deterministic); ``period_s`` defaults to the
    expected trace span ``n_requests / rate_rps`` (one full "day" per
    trace); ``amplitude`` must sit in [0, 1).  ``arrays=True`` returns
    the columnar ``TraceArrays`` view (no per-request objects — the
    fleet-replay fast input)."""
    import numpy as np
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    rng = np.random.default_rng(seed)
    # unit-rate Poisson clock, warped through the inverse cumulative rate
    u = np.cumsum(rng.exponential(1.0, size=n_requests))
    period = float(period_s) if period_s else \
        max(n_requests / rate_rps, 1e-9)
    t_max = (float(u[-1]) if n_requests else 1.0) / rate_rps + period
    grid = np.linspace(0.0, t_max, 65536)
    # Lambda(t) = integral of rate(t'); >= rate*t, so the grid covers u
    lam = rate_rps * (grid + amplitude * (period / (2.0 * np.pi))
                      * (1.0 - np.cos(2.0 * np.pi * grid / period)))
    arrivals = np.interp(u, lam, grid)
    plens = np.maximum(np.asarray(_draw_len(rng, prompt_len, n_requests),
                                  dtype=np.int64), 1)
    olens = np.maximum(np.asarray(_draw_len(rng, output_len, n_requests),
                                  dtype=np.int64), 1)
    if arrays:
        return TraceArrays(arrival_s=arrivals, prompt_len=plens,
                           output_len=olens,
                           rid=np.arange(n_requests, dtype=np.int64))
    a, p, o = arrivals.tolist(), plens.tolist(), olens.tolist()
    return [Request(i, a[i], p[i], o[i]) for i in range(n_requests)]


TRACE_GENERATORS.update(poisson=poisson_trace, bursty=bursty_trace,
                        diurnal=diurnal_trace)


def _record_request(r: Dict, i: int) -> Request:
    return Request(int(r.get("rid", i)), float(r["arrival_s"]),
                   max(int(r["prompt_len"]), 1),
                   max(int(r["output_len"]), 1))


def trace_from_records(records: Sequence[Dict]) -> List[Request]:
    """Build a trace from dict records with ``arrival_s`` / ``prompt_len``
    / ``output_len`` keys (``rid`` optional; defaults to record order).
    Raises ValueError on duplicate rids — per-request metrics are keyed on
    them."""
    trace = [_record_request(r, i) for i, r in enumerate(records)]
    if len({r.rid for r in trace}) != len(trace):
        raise ValueError("duplicate rid in trace records")
    return trace


def _trace_opener(path):
    return gzip.open if str(path).endswith(".gz") else open


def iter_trace(path) -> Iterator[Request]:
    """Lazily yield ``Request``s from a trace file — JSON-lines (plain or
    ``.gz``) streams one record at a time, so a million-request trace is
    never materialized as dicts.  JSON-array files fall back to a full
    parse (the format has no line framing).  No duplicate-rid check here
    (that needs the full id set); ``load_trace`` adds it."""
    with _trace_opener(path)(path, "rt") as f:
        head = f.read(1)
        while head and head.isspace():
            head = f.read(1)
        if not head:
            return
        if head == "[":
            for i, r in enumerate(json.loads(head + f.read())):
                yield _record_request(r, i)
            return
        i = 0
        for line in _chain([head + f.readline()], f):
            line = line.strip()
            if line:
                yield _record_request(json.loads(line), i)
                i += 1


def load_trace(path) -> List[Request]:
    """Load a trace file into a list: a JSON array of records, or
    JSON-lines (one record per line), either optionally gzipped
    (``.jsonl.gz``).  Use ``iter_trace`` to stream without the list."""
    trace = list(iter_trace(path))
    if len({r.rid for r in trace}) != len(trace):
        raise ValueError("duplicate rid in trace records")
    return trace


def save_trace(path, trace: Iterable[Request]) -> None:
    """Write a trace as JSON-lines (the ``load_trace`` record format),
    gzipped when ``path`` ends in ``.gz``.  Accepts any iterable of
    ``Request`` — a generator or ``TraceArrays`` streams straight to
    disk without an intermediate list."""
    with _trace_opener(path)(path, "wt") as f:
        for r in trace:
            f.write(json.dumps({"rid": r.rid, "arrival_s": r.arrival_s,
                                "prompt_len": r.prompt_len,
                                "output_len": r.output_len}) + "\n")


def _trace_columns(trace) -> Tuple[list, list, list, list]:
    """Any trace form -> (arrival_s, rid, prompt_len, output_len) Python
    lists in (arrival_s, rid) order — what the replica schedulers
    consume.  Lists/tuples are sorted here; streamed iterators must
    already be arrival-sorted (they are consumed in one pass)."""
    if isinstance(trace, TraceArrays):
        return trace.columns()
    arr: List[float] = []
    rids: List[int] = []
    pls: List[int] = []
    ols: List[int] = []
    if isinstance(trace, (list, tuple)):
        ordered: Iterable[Request] = sorted(
            trace, key=lambda r: (r.arrival_s, r.rid))
    else:
        ordered = trace
    last = float("-inf")
    for rq in ordered:
        if rq.arrival_s < last:
            raise ValueError(
                "streamed trace must be sorted by arrival_s (pass a list "
                "to sort on entry, or sort the file first)")
        last = rq.arrival_s
        arr.append(rq.arrival_s)
        rids.append(rq.rid)
        pls.append(rq.prompt_len)
        ols.append(rq.output_len)
    if len(set(rids)) != len(rids):
        raise ValueError("duplicate rid in trace; per-request metrics are "
                         "keyed on it")
    return arr, rids, pls, ols


# ---------------------------------------------------------------------------
# results


@dataclass
class RequestMetrics:
    """Per-request outcome; all times are absolute wall-clock seconds."""
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int
    first_token_s: float = float("nan")
    finish_s: float = float("nan")

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival -> end of the prefill step."""
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> float:
        """Time per output token over the decode phase (0 for
        single-token outputs)."""
        if self.output_len <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (self.output_len - 1)

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclass(frozen=True)
class StepRecord:
    """One scheduler iteration: where it sat in wall time and what it
    batched.  ``n_active`` counts decode slots that emitted a token;
    ``n_decode - n_active`` is padding (static batching's waste)."""
    index: int
    start_s: float
    duration_s: float
    n_prefill: int
    n_decode: int
    n_active: int

    @property
    def end_s(self) -> float:
        return self.start_s + self.duration_s


@dataclass
class ServingResult:
    """Everything one served-trace simulation produced.

    ``engine`` is the ordinary ``EngineResult`` of the chained step program
    (Timeline / Breakdown / Roofline / energy of the *work*, back-to-back);
    ``makespan_s`` is the serving wall clock, which additionally contains
    the idle gaps where the server waited for arrivals.  On any non-idle
    trace ``engine.makespan <= makespan_s``, with bit-exact equality of
    ``engine.makespan`` and ``busy_s``."""
    program: Program
    engine: EngineResult
    requests: List[RequestMetrics]
    steps: List[StepRecord]
    policy: BatchingPolicy
    config: EngineConfig
    makespan_s: float                 # wall clock: end of the last step
    busy_s: float                     # engine-order sum of step costs
    meta: Dict = field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return sum(s.n_active for s in self.steps) \
            + sum(s.n_prefill for s in self.steps)

    @property
    def throughput_tok_s(self) -> float:
        """Output tokens per wall-clock second (prefill emits the first
        token of each request; decode emits the rest)."""
        return self.total_tokens / self.makespan_s if self.makespan_s \
            else 0.0

    @property
    def throughput_req_s(self) -> float:
        done = sum(1 for r in self.requests if r.finish_s == r.finish_s)
        return done / self.makespan_s if self.makespan_s else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of the ``max_batch`` decode slots that emitted a
        token, over steps that decoded at all — the batch-utilization view
        of the policy comparison."""
        decode_steps = [s for s in self.steps if s.n_decode]
        if not decode_steps:
            return 0.0
        return sum(s.n_active for s in decode_steps) \
            / (self.policy.max_batch * len(decode_steps))

    def stats(self) -> Dict[str, float]:
        """Tidy scalar summary (the ``as_serving_records`` row body)."""
        out: Dict[str, float] = {
            "n_requests": len(self.requests),
            "n_steps": len(self.steps),
            "makespan_s": self.makespan_s,
            "busy_s": self.busy_s,
            "throughput_tok_s": self.throughput_tok_s,
            "throughput_req_s": self.throughput_req_s,
            "occupancy": self.occupancy,
        }
        # latency_stats_array is bit-identical to the pure-python
        # latency_stats on these populations (see report.py), just O(n)
        # C-speed — the BENCH_serving.json grid values are unchanged
        for nm, vals in (("ttft", [r.ttft_s for r in self.requests]),
                         ("tpot", [r.tpot_s for r in self.requests
                                   if r.output_len > 1]),
                         ("latency", [r.latency_s for r in self.requests])):
            for k, v in latency_stats_array(vals).items():
                if k != "n":
                    out[f"{nm}_{k}"] = v
        return out

    def wall_timeline(self) -> Timeline:
        """Wall-clock step timeline (arrival gaps visible as idle), one
        event per scheduler step — the serving analogue of the engine's
        per-op Timeline."""
        tl = Timeline()
        for s in self.steps:
            tl.add("serve", f"step{s.index}", s.start_s, s.duration_s,
                   "compute", phase=f"step{s.index}")
        return tl


def _population_stats(arrival, olen, first, finish) -> Dict[str, float]:
    """ttft_*/tpot_*/latency_* percentile fields from metric arrays —
    elementwise identical to the ``RequestMetrics`` properties, then
    through the same ``latency_stats_array`` summaries."""
    import numpy as np
    arrival = np.asarray(arrival, dtype=np.float64)
    olen = np.asarray(olen, dtype=np.int64)
    first = np.asarray(first, dtype=np.float64)
    finish = np.asarray(finish, dtype=np.float64)
    ttft = first - arrival
    lat = finish - arrival
    multi = olen > 1
    tpot = ((finish - first) / np.maximum(olen - 1, 1))[multi]
    out: Dict[str, float] = {}
    for nm, vals in (("ttft", ttft), ("tpot", tpot), ("latency", lat)):
        for k, v in latency_stats_array(vals).items():
            if k != "n":
                out[f"{nm}_{k}"] = v
    return out


@dataclass
class ReplayResult:
    """What the lite fast path (``replay_serving`` / one fleet replica)
    produced: per-request metric arrays plus the scalar aggregates the
    full ``ServingResult`` would derive — but no op Program and no
    ``EngineResult`` (that is where the 10x+ comes from).  The scheduling
    and clock arithmetic are bit-identical to ``simulate_serving``
    (``stats()`` returns the exact same dict); the energy roll-up mirrors
    the engine's formula on the memoized per-op aggregates, equal to the
    full path up to float summation order."""
    name: str
    policy: BatchingPolicy
    config: EngineConfig
    rid: object                    # (n,) int64, trace order
    arrival_s: object              # (n,) float64
    prompt_len: object             # (n,) int64
    output_len: object             # (n,) int64
    first_token_s: object          # (n,) float64 (NaN = never prefilled)
    finish_s: object               # (n,) float64 (NaN = never finished)
    makespan_s: float              # wall clock: end of the last step
    busy_s: float                  # engine-order sum of step costs
    n_steps: int
    decode_steps: int              # steps with a decode op
    decode_slot_steps: int         # sum of n_decode over steps
    prefill_tokens: int            # first tokens emitted (= admissions)
    active_tokens: int             # decode tokens emitted
    flops: float                   # program flops (memoized aggregate)
    transfer_j: float              # interface transfer energy (J)
    steps: Optional[List[StepRecord]] = None
    meta: Dict = field(default_factory=dict)

    @property
    def n_requests(self) -> int:
        return len(self.rid)

    @property
    def requests(self) -> List[RequestMetrics]:
        """Materialized per-request metrics (lazy — fleet-scale callers
        stay on the arrays)."""
        a, p, o = (self.arrival_s.tolist(), self.prompt_len.tolist(),
                   self.output_len.tolist())
        fi, fo, rid = (self.first_token_s.tolist(), self.finish_s.tolist(),
                       self.rid.tolist())
        return [RequestMetrics(rid[i], a[i], p[i], o[i], fi[i], fo[i])
                for i in range(len(rid))]

    @property
    def total_tokens(self) -> int:
        return self.active_tokens + self.prefill_tokens

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.makespan_s if self.makespan_s \
            else 0.0

    @property
    def throughput_req_s(self) -> float:
        import numpy as np
        done = int(np.count_nonzero(self.finish_s == self.finish_s))
        return done / self.makespan_s if self.makespan_s else 0.0

    @property
    def occupancy(self) -> float:
        if not self.decode_steps:
            return 0.0
        return self.active_tokens \
            / (self.policy.max_batch * self.decode_steps)

    def energy(self) -> Dict[str, float]:
        """The engine's energy roll-up on the memoized aggregates
        (compute + interface transfers + static leakage over the busy
        span + host floor; serving steps move no collective bytes).
        Matches ``EngineResult.energy`` of the full path to within float
        summation order."""
        em = self.config.energy
        comp = em.compute(self.flops)
        static = em.static(self.busy_s + self.config.host_floor_s, 1)
        total = comp + self.transfer_j + static
        return {"compute_j": comp, "hbm_j": self.transfer_j,
                "ici_j": 0.0, "static_j": static, "total_j": total,
                "total_j_all_chips": total * self.config.n_chips}

    def stats(self) -> Dict[str, float]:
        """Tidy scalar summary — the exact dict ``ServingResult.stats``
        returns for the same (trace, policy, config)."""
        out: Dict[str, float] = {
            "n_requests": self.n_requests,
            "n_steps": self.n_steps,
            "makespan_s": self.makespan_s,
            "busy_s": self.busy_s,
            "throughput_tok_s": self.throughput_tok_s,
            "throughput_req_s": self.throughput_req_s,
            "occupancy": self.occupancy,
        }
        out.update(_population_stats(self.arrival_s, self.output_len,
                                     self.first_token_s, self.finish_s))
        return out


# ---------------------------------------------------------------------------
# memoized step pricing


def _require_uniform_pool(config: EngineConfig) -> None:
    if not engine.uniform_class_params(config, "accel"):
        raise ValueError(
            "serving co-simulation requires a uniform accelerator pool: "
            "the topology's accel-class devices resolve to more than one "
            "cost signature/link, so chain_op_costs cannot price ops "
            "exactly as the engine would charge them")


class StepCostTable:
    """Memoized exact pricing of serving-step ops.

    ``ir.from_serving_step`` determines every op cost field from the
    signature ``(prefill-length tuple, decode batch, decode position
    sum)`` — see ``ir.serving_step_signature`` — and
    ``engine.chain_op_costs`` is pure in (op fields, config).  The table
    therefore keeps two sub-caches: prefill-op entries keyed on the
    exact prompt-length tuple (the causal-attention term is an
    order-dependent float sum over individual lengths) and decode-op
    entries keyed on ``(batch, position sum, weights-charged)`` — so the
    scheduler loop prices a repeated step with one dict hit instead of a
    lowering + two cost evaluations.

    Misses are priced at the scalar parameter point
    ``costmodel.chain_params_for(config)`` using the same formulas (and
    IEEE operation order) as ``costmodel.chain_terms`` /
    ``engine.chain_op_costs``, so memoized costs are bit-identical to
    the unmemoized path (asserted against ``chain_op_costs`` over random
    compositions in tests/test_fleet.py).  Interfaces or energy models
    outside the analytic chain model fall back to pricing each miss
    through ``engine.chain_op_costs`` itself — still memoized, still
    exact.

    Entries are ``(host_s, transfer_s, compute_s, collective_s, flops,
    transfer_j)`` per op.  One table can be shared across every replica
    and sweep cell that uses the same (model, config, bytes_per_param) —
    ``matches()`` guards the reuse."""

    def __init__(self, cfg, config: Optional[EngineConfig] = None, *,
                 bytes_per_param: float = 2.0):
        if config is None:
            config = EngineConfig()
        _require_uniform_pool(config)
        self.cfg = cfg
        self.config = config
        self.bytes_per_param = bytes_per_param
        (self.n_active, self.kv_dim, self.n_attn,
         self.weight_bytes) = ir._decode_terms(cfg, bytes_per_param)
        self.kv_entry = self.kv_dim * self.n_attn * bytes_per_param
        self._eff, self._ports = engine._class_params(config, "accel")
        try:
            self._p = costmodel.chain_params_for(config, "accel")
        except costmodel.Unsupported:
            self._p = None
        # the closed-form scalar pricer covers the hbm/ideal interfaces
        # with the stock energy model; dma/acp/custom miss through
        # chain_op_costs (identical numbers, a slower miss path)
        self._fast = (self._p is not None
                      and self._eff.interface in ("hbm", "ideal")
                      and type(config.energy) is EnergyModel)
        self._prefill: Dict[Tuple[int, ...], tuple] = {}
        self._decode: Dict[Tuple[int, int, bool], tuple] = {}
        self.hits = 0
        self.misses = 0

    def matches(self, cfg, config: EngineConfig,
                bytes_per_param: float) -> bool:
        """Whether this table prices (cfg, config, bytes_per_param) —
        reuse across replicas/cells is only exact when it does."""
        return (self.cfg is cfg and self.config == config
                and float(self.bytes_per_param) == float(bytes_per_param))

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def _price(self, flops: float, bytes_in: float,
               bytes_out: float) -> tuple:
        """One op -> (host, transfer, compute, collective, flops,
        transfer_j); serving ops always have dot_flops == flops and no
        duration/transfer overrides."""
        nb = bytes_in + bytes_out
        p = self._p
        if self._fast:
            # scalar costmodel.chain_terms, hbm/ideal branch — division
            # and max order identical to engine._transfer_base
            host = p.host_dispatch_s + (nb / p.host_bw / p.host_threads
                                        if p.host_bw else 0.0)
            expo = 0.0
            xe = 0.0
            if p.interface == "hbm" and nb:
                t = nb / p.hbm_bw
                xe = nb * p.pj_hbm * 1e-12
                t /= p.datapath_scale
                expo = (max(t - flops / p.peak_flops, 0.0)
                        if p.overlap else t)
                if expo > 0.0 and p.hbm_ports > 0:
                    expo *= max(1.0, 1 / p.hbm_ports)
            return (host, expo, flops / p.peak_flops, 0.0, flops, xe)
        op = ir.CostedOp(name="memo", flops=flops, dot_flops=flops,
                         bytes_in=bytes_in, bytes_out=bytes_out,
                         device_class="accel")
        h, x, c, l = engine.chain_op_costs(op, self.config)
        _, _, xe = engine._transfer_base(
            op, self._eff, engine.INTERFACES[self._eff.interface])
        return (h, x, c, l, flops, xe)

    def _prefill_entry(self, prefill_lens: Tuple[int, ...]) -> tuple:
        # field formulas (and float op order) of ir.from_serving_step
        n_tok = float(sum(prefill_lens))
        attn = sum(4.0 * self.n_attn * self.kv_dim * (L * (L - 1) // 2)
                   for L in prefill_lens)
        flops = 2.0 * self.n_active * n_tok + attn
        return self._price(flops, self.weight_bytes, self.kv_entry * n_tok)

    def _decode_entry(self, n_decode: int, pos_sum: int,
                      charge_weights: bool) -> tuple:
        batch = float(n_decode)
        ps = float(pos_sum)
        flops = 2.0 * self.n_active * batch \
            + 4.0 * self.n_attn * self.kv_dim * ps
        kv_read = 2.0 * self.n_attn * self.kv_dim * ps \
            * self.bytes_per_param
        bytes_in = (self.weight_bytes if charge_weights else 0.0) + kv_read
        return self._price(flops, bytes_in, self.kv_entry * batch)

    def step_entries(self, prefill_lens: Tuple[int, ...], n_decode: int,
                     pos_sum: int) -> tuple:
        """Per-op cost entries of the step with this signature, in the
        op order of ``ir.from_serving_step`` (prefill, then decode)."""
        pe = None
        if prefill_lens:
            pe = self._prefill.get(prefill_lens)
            if pe is None:
                self.misses += 1
                pe = self._prefill_entry(prefill_lens)
                self._prefill[prefill_lens] = pe
            else:
                self.hits += 1
        if n_decode:
            key = (n_decode, pos_sum, pe is None)
            de = self._decode.get(key)
            if de is None:
                self.misses += 1
                de = self._decode_entry(n_decode, pos_sum, pe is None)
                self._decode[key] = de
            else:
                self.hits += 1
            return (pe, de) if pe is not None else (de,)
        return (pe,) if pe is not None else ()


# ---------------------------------------------------------------------------
# the scheduler co-simulation


@dataclass
class _Slot:
    req: Request
    produced: int = 0     # output tokens emitted so far
    pos: int = 0          # current KV length (prompt written at prefill)

    @property
    def done(self) -> bool:
        return self.produced >= self.req.output_len


def simulate_serving(cfg, trace: Sequence[Request],
                     policy: BatchingPolicy,
                     config: Optional[EngineConfig] = None, *,
                     bytes_per_param: float = 2.0,
                     max_steps: int = 1_000_000,
                     memoize: bool = True,
                     table: Optional[StepCostTable] = None,
                     name: str = "") -> ServingResult:
    """Replay ``trace`` against ``policy`` on ``config``; see the module
    header for the co-simulation semantics.

    ``cfg`` is a ``repro.core.config.ModelConfig`` (the served model);
    ``config`` defaults to a fresh ``EngineConfig()`` (``None`` sentinel —
    no shared module-level instance); ``bytes_per_param`` matches
    ``ir.from_decode``.  Raises RuntimeError past ``max_steps`` iterations
    (a policy that stops making progress).

    ``memoize=True`` (default) prices repeated step signatures through a
    ``StepCostTable`` — bit-identical results, one dict hit instead of
    two ``chain_op_costs`` calls per repeated step; pass ``table`` to
    share a warm cache across calls, or ``memoize=False`` for the
    original per-op pricing loop (the benchmark baseline).

    Heterogeneous topologies are supported as long as the accelerator
    pool is uniform (one cost signature + link across the class's
    candidate devices): ``chain_op_costs`` prices each op at the class's
    reference device, so a mixed pool would silently break the
    busy_s == engine.makespan invariant — it is rejected instead."""
    if config is None:
        config = EngineConfig()
    _require_uniform_pool(config)
    if table is not None:
        if not table.matches(cfg, config, bytes_per_param):
            raise ValueError("StepCostTable was built for a different "
                             "(model, config, bytes_per_param)")
    elif memoize:
        table = StepCostTable(cfg, config, bytes_per_param=bytes_per_param)
    trace = sorted(trace, key=lambda r: (r.arrival_s, r.rid))
    if len({r.rid for r in trace}) != len(trace):
        raise ValueError("duplicate rid in trace; per-request metrics are "
                         "keyed on it")
    metrics = {r.rid: RequestMetrics(r.rid, r.arrival_s, r.prompt_len,
                                     r.output_len) for r in trace}
    static = isinstance(policy, StaticBatching) or policy.kind == "static"
    continuous = policy.kind == "continuous"

    all_ops: List[ir.CostedOp] = []
    prev_op: Optional[str] = None
    steps: List[StepRecord] = []
    waiting: List[Request] = []
    live: List[_Slot] = []
    i = 0                          # next un-arrived trace index
    t = 0.0                        # wall clock (includes arrival gaps)
    busy = 0.0                     # engine-order accumulation of op costs
    k = 0                          # step index
    stalled = 0                    # consecutive zero-progress idle loops

    while True:
        while i < len(trace) and trace[i].arrival_s <= t:
            waiting.append(trace[i])
            i += 1
        trace_done = i >= len(trace)

        # eviction: continuous/dynamic free slots at end-of-output; static
        # holds the formed batch (padding) until every member finishes
        if static:
            if live and all(s.done for s in live):
                live = []
        else:
            live = [s for s in live if not s.done]

        # admission
        admitted: List[Request] = []
        if continuous:
            free = policy.max_batch - len(live)
            if free > 0 and waiting:
                admitted, waiting = waiting[:free], waiting[free:]
        elif not live and waiting:
            oldest = waiting[0].arrival_s
            # the absolute-deadline comparison repeats the exact float
            # expression the idle-advance below lands on, so a batch
            # launched "at the deadline" cannot miss it to rounding
            if (policy.ready(len(waiting), t - oldest, trace_done)
                    or t >= policy.launch_deadline_s(oldest)):
                admitted = waiting[:policy.max_batch]
                waiting = waiting[policy.max_batch:]

        decode_slots = [s for s in live if s.produced >= 1
                        and (static or not s.done)]
        if not admitted and not decode_slots:
            # nothing runnable: advance to the next arrival or (dynamic)
            # the oldest waiter's launch deadline; done when neither exists
            nxt = []
            if i < len(trace):
                nxt.append(trace[i].arrival_s)
            if waiting:
                nxt.append(policy.launch_deadline_s(waiting[0].arrival_s))
            nxt = [x for x in nxt if x < float("inf")]
            if not nxt:
                break
            t_new = max(t, min(nxt))
            if t_new == t:
                stalled += 1
                if stalled > 2:
                    raise RuntimeError(
                        f"serving scheduler stalled at t={t} with "
                        f"{len(waiting)} waiting (policy {policy.kind!r})")
            else:
                stalled = 0
            t = t_new
            continue

        # lower this iteration and advance both clocks with the exact
        # chain-path costs (see engine.chain_op_costs); with a table the
        # costs come from the signature memo — same values bit-for-bit
        pf = tuple(r.prompt_len for r in admitted)
        dpos = tuple(s.pos for s in decode_slots)
        step_prog = ir.from_serving_step(
            cfg, step=k, prefill_lens=pf, decode_positions=dpos,
            bytes_per_param=bytes_per_param)
        if table is not None:
            costs = table.step_entries(pf, len(dpos), sum(dpos))
        else:
            costs = [engine.chain_op_costs(op, config)
                     for op in step_prog.ops]
        t0 = t
        for op, cost in zip(step_prog.ops, costs):
            if prev_op is not None and not op.deps:
                op = ir.replace(op, deps=(prev_op,))
            all_ops.append(op)
            prev_op = op.name
            h, x, c, l = cost[0], cost[1], cost[2], cost[3]
            t += h
            t += x
            t += c
            t += l
            busy += h
            busy += x
            busy += c
            busy += l

        n_active = 0
        for s in decode_slots:
            if not s.done:
                s.produced += 1
                n_active += 1
                if s.done:
                    metrics[s.req.rid].finish_s = t
            s.pos += 1          # padded static slots advance with the batch
        for r in admitted:
            slot = _Slot(r, produced=1, pos=r.prompt_len)
            metrics[r.rid].first_token_s = t
            if slot.done:
                metrics[r.rid].finish_s = t
            live.append(slot)
        steps.append(StepRecord(k, t0, t - t0, len(admitted),
                                len(decode_slots), n_active))
        k += 1
        if k > max_steps:
            raise RuntimeError(f"serving scheduler exceeded {max_steps} "
                               f"steps (policy {policy.kind!r})")

    program = Program(
        all_ops, name=name or f"{getattr(cfg, 'name', 'model')}"
        f"/serve-{policy.kind}x{len(trace)}", source="serving",
        meta={"policy": policy.kind, "max_batch": policy.max_batch,
              "n_requests": len(trace), "n_steps": len(steps)})
    # the chained steps are a pure linear chain -> the official run takes
    # the engine's prefix-sum fast path, through the sweep/DSE layer
    from repro.sim.sweep import sweep
    (engine_res,) = sweep(program, [config])
    return ServingResult(program=program, engine=engine_res,
                         requests=[metrics[r.rid] for r in trace],
                         steps=steps, policy=policy, config=config,
                         makespan_s=t, busy_s=busy,
                         meta={"bytes_per_param": bytes_per_param})


# ---------------------------------------------------------------------------
# the lite fast path: aggregate-counter replicas + memoized step costs


class _Replica:
    """One replica's incremental scheduler — the exact
    ``simulate_serving`` state machine re-expressed over aggregate
    counters, driven by ``push`` (a routed arrival) and ``drain_until``
    (advance the replica's clock).

    Slot-by-slot state collapses to O(1)-per-step aggregates: ``n_live``
    (batch size), ``pos_sum`` (the integer KV-position sum — all the
    decode op needs, see ``ir.serving_step_signature``), ``n_emitting``
    (live slots still producing), and a finish heap of ``(finish_step,
    idx, evict_pos)`` — a slot admitted at step k with output length o
    emits its last token at step ``k + o - 1`` because every live slot
    decodes every step, so its eviction is known at admission.  Static
    batches hold finished slots as padding (their positions keep
    advancing inside ``pos_sum``) and clear wholesale when the last
    member finishes; single-token requests never enter the live batch
    under continuous/dynamic (they finish at prefill), exactly like the
    slot loop.  Clock arithmetic (idle jumps, per-term adds in op order)
    repeats the standalone loop's float expressions, so wall/busy clocks
    and per-request times are bit-identical (tests/test_fleet.py)."""

    __slots__ = ("table", "policy", "static", "continuous", "max_batch",
                 "t", "busy", "k", "last_end", "waiting", "n_live",
                 "n_emitting", "pos_sum", "heap", "trace_done", "first",
                 "finish", "steps", "max_steps", "decode_steps",
                 "decode_slot_steps", "prefill_tokens", "active_tokens",
                 "flops", "transfer_j", "index", "spawn_s")

    def __init__(self, table: StepCostTable, policy: BatchingPolicy,
                 first: list, finish: list, *, t0: float = 0.0,
                 record_steps: bool = False,
                 max_steps: int = 100_000_000, index: int = 0):
        self.table = table
        self.policy = policy
        self.static = policy.kind == "static" \
            or isinstance(policy, StaticBatching)
        self.continuous = policy.kind == "continuous"
        self.max_batch = policy.max_batch
        self.t = t0
        self.spawn_s = t0
        self.busy = 0.0
        self.k = 0
        self.last_end = 0.0
        # (arrival_s, idx, plen, olen); deque: admission pops from the
        # left, so a deep backlog never costs O(queue) per step
        self.waiting: Deque[tuple] = deque()
        self.n_live = 0
        self.n_emitting = 0
        self.pos_sum = 0
        self.heap: List[tuple] = []      # (finish_step, idx, evict_pos)
        self.trace_done = False
        self.first = first               # shared sinks indexed by idx
        self.finish = finish
        self.steps: Optional[List[StepRecord]] = \
            [] if record_steps else None
        self.max_steps = max_steps
        self.decode_steps = 0
        self.decode_slot_steps = 0
        self.prefill_tokens = 0
        self.active_tokens = 0
        self.flops = 0.0
        self.transfer_j = 0.0
        self.index = index

    @property
    def outstanding(self) -> int:
        """Queued + still-emitting requests (what a router balances)."""
        return len(self.waiting) + self.n_emitting

    def push(self, arrival_s: float, idx: int, plen: int,
             olen: int) -> None:
        """Route one arrival here.  The caller must have drained this
        replica to ``arrival_s`` first; an idle replica's clock jumps
        forward to the arrival (the standalone loop's idle advance)."""
        if self.t < arrival_s:
            self.t = arrival_s
        self.waiting.append((arrival_s, idx, plen, olen))

    def drain_until(self, until_s: float) -> None:
        """Run every step that starts strictly before ``until_s``
        (``inf`` = drain completely).  Returns with ``t >= until_s``, or
        idle (nothing runnable before the next push)."""
        policy = self.policy
        while True:
            if self.t >= until_s:
                return
            waiting = self.waiting
            admitted: Optional[List[tuple]] = None
            if self.continuous:
                free = self.max_batch - self.n_live
                if free > 0 and waiting:
                    pop = waiting.popleft
                    admitted = [pop()
                                for _ in range(min(free, len(waiting)))]
            elif self.n_live == 0 and waiting:
                oldest = waiting[0][0]
                if (policy.ready(len(waiting), self.t - oldest,
                                 self.trace_done)
                        or self.t >= policy.launch_deadline_s(oldest)):
                    pop = waiting.popleft
                    admitted = [pop() for _ in
                                range(min(self.max_batch, len(waiting)))]
            if admitted or self.n_live:
                self._step(admitted or ())
                continue
            # idle: next arrival (if any) is >= until_s by protocol
            if not waiting:
                return
            dl = policy.launch_deadline_s(waiting[0][0])
            if dl >= until_s:
                return
            # jump to the launch deadline; the admission check above
            # repeats this exact float, so the batch launches next loop
            self.t = max(self.t, dl)

    def _step(self, admitted: Sequence[tuple]) -> None:
        pf = tuple(a[2] for a in admitted) if admitted else ()
        n_dec = self.n_live
        entries = self.table.step_entries(pf, n_dec, self.pos_sum)
        t = self.t
        t0 = t
        busy = self.busy
        for e in entries:
            t += e[0]
            t += e[1]
            t += e[2]
            t += e[3]
            busy += e[0]
            busy += e[1]
            busy += e[2]
            busy += e[3]
            self.flops += e[4]
            self.transfer_j += e[5]
        self.t = t
        self.busy = busy
        self.last_end = t
        k = self.k
        n_act = self.n_emitting
        if n_dec:
            self.pos_sum += n_dec        # every decode slot advances
            self.decode_steps += 1
            self.decode_slot_steps += n_dec
            self.active_tokens += n_act
            heap = self.heap
            while heap and heap[0][0] <= k:
                _, idx, evict_pos = heappop(heap)
                self.finish[idx] = t
                self.n_emitting -= 1
                if not self.static:
                    self.n_live -= 1
                    self.pos_sum -= evict_pos
        if admitted:
            self.prefill_tokens += len(admitted)
            first = self.first
            static = self.static
            for _, idx, plen, olen in admitted:
                first[idx] = t
                if olen <= 1:
                    self.finish[idx] = t
                    if static:               # stays as batch padding
                        self.n_live += 1
                        self.pos_sum += plen
                else:
                    self.n_live += 1
                    self.pos_sum += plen
                    self.n_emitting += 1
                    heappush(self.heap,
                             (k + olen - 1, idx, plen + olen - 1))
        if self.steps is not None:
            self.steps.append(StepRecord(k, t0, t - t0, len(admitted),
                                         n_dec, n_act))
        self.k = k + 1
        if self.k > self.max_steps:
            raise RuntimeError(
                f"serving scheduler exceeded {self.max_steps} steps "
                f"(policy {self.policy.kind!r})")
        # static: the batch drains as one (the loop-top wholesale clear)
        if self.static and self.n_live and self.n_emitting == 0:
            self.n_live = 0
            self.pos_sum = 0


def _replica_result(rep: _Replica, policy: BatchingPolicy,
                    config: EngineConfig, arrival, rid, plen, olen,
                    first, finish, *, name: str,
                    meta: Optional[Dict] = None) -> ReplayResult:
    import numpy as np
    return ReplayResult(
        name=name, policy=policy, config=config,
        rid=np.asarray(rid, dtype=np.int64),
        arrival_s=np.asarray(arrival, dtype=np.float64),
        prompt_len=np.asarray(plen, dtype=np.int64),
        output_len=np.asarray(olen, dtype=np.int64),
        first_token_s=np.asarray(first, dtype=np.float64),
        finish_s=np.asarray(finish, dtype=np.float64),
        makespan_s=rep.last_end, busy_s=rep.busy, n_steps=rep.k,
        decode_steps=rep.decode_steps,
        decode_slot_steps=rep.decode_slot_steps,
        prefill_tokens=rep.prefill_tokens,
        active_tokens=rep.active_tokens,
        flops=rep.flops, transfer_j=rep.transfer_j,
        steps=rep.steps, meta=dict(meta or {}))


def replay_serving(cfg, trace, policy: BatchingPolicy,
                   config: Optional[EngineConfig] = None, *,
                   bytes_per_param: float = 2.0,
                   record_steps: bool = False,
                   max_steps: int = 100_000_000,
                   table: Optional[StepCostTable] = None,
                   name: str = "") -> ReplayResult:
    """The memoized lite replay of ``simulate_serving``: identical
    scheduling and clock arithmetic (wall/busy clocks, step records and
    per-request times are bit-identical — asserted in
    tests/test_fleet.py), but no op materialization and no engine run,
    so the cost per step is O(1) Python work plus a dict hit.  This is
    the path that replays 1M-request traces in seconds
    (benchmarks/bench_fleet.py).

    ``trace`` may be a list/tuple of ``Request`` (sorted here), a
    ``TraceArrays`` column view, or an arrival-sorted iterator (e.g.
    ``iter_trace``).  Pass ``table`` to share a warm ``StepCostTable``
    across calls."""
    if config is None:
        config = EngineConfig()
    if table is not None:
        if not table.matches(cfg, config, bytes_per_param):
            raise ValueError("StepCostTable was built for a different "
                             "(model, config, bytes_per_param)")
    else:
        table = StepCostTable(cfg, config, bytes_per_param=bytes_per_param)
    arrival, rid, plen, olen = _trace_columns(trace)
    n = len(rid)
    nan = float("nan")
    first = [nan] * n
    finish = [nan] * n
    rep = _Replica(table, policy, first, finish,
                   record_steps=record_steps, max_steps=max_steps)
    drain = rep.drain_until
    push = rep.push
    for j in range(n):
        a = arrival[j]
        drain(a)
        push(a, j, plen[j], olen[j])
    rep.trace_done = True
    rep.drain_until(float("inf"))
    return _replica_result(
        rep, policy, config, arrival, rid, plen, olen, first, finish,
        name=name or f"{getattr(cfg, 'name', 'model')}"
        f"/replay-{policy.kind}x{n}",
        meta={"bytes_per_param": bytes_per_param,
              "memo_hits": table.hits, "memo_misses": table.misses})


# ---------------------------------------------------------------------------
# the fleet layer: N replicas behind a router (+ optional autoscaler)


@dataclass(frozen=True)
class ScaleEvent:
    """One autoscaler action: at ``t_s`` the fleet went to
    ``n_replicas`` active replicas because the mean queue depth per
    active replica was ``queue_depth``."""
    t_s: float
    action: str                  # "up" | "down"
    n_replicas: int              # active replicas AFTER the action
    queue_depth: float


@dataclass
class FleetResult:
    """An N-replica serving fleet's roll-up: per-replica
    ``ReplayResult``s plus the global request arrays, the routing
    assignment, autoscaler events, and fleet-level SLO / cost views."""
    name: str
    replicas: List[ReplayResult]
    router: RouterPolicy
    policy: BatchingPolicy
    config: EngineConfig
    rid: object                  # (n,) int64, trace order
    arrival_s: object
    prompt_len: object
    output_len: object
    first_token_s: object
    finish_s: object
    replica_of: object           # (n,) int64: replica index per request
    scale_events: List[ScaleEvent]
    makespan_s: float            # max replica wall clock
    meta: Dict = field(default_factory=dict)

    @property
    def n_requests(self) -> int:
        return len(self.rid)

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def busy_s(self) -> float:
        return sum(r.busy_s for r in self.replicas)

    @property
    def n_steps(self) -> int:
        return sum(r.n_steps for r in self.replicas)

    @property
    def total_tokens(self) -> int:
        return sum(r.total_tokens for r in self.replicas)

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.makespan_s if self.makespan_s \
            else 0.0

    @property
    def throughput_req_s(self) -> float:
        import numpy as np
        done = int(np.count_nonzero(self.finish_s == self.finish_s))
        return done / self.makespan_s if self.makespan_s else 0.0

    @property
    def occupancy(self) -> float:
        dsteps = sum(r.decode_steps for r in self.replicas)
        if not dsteps:
            return 0.0
        return sum(r.active_tokens for r in self.replicas) \
            / (self.policy.max_batch * dsteps)

    def energy(self) -> Dict[str, float]:
        """Component-wise sum of the replica energy roll-ups (each
        replica is one chip's worth of static power over its busy
        span)."""
        out: Dict[str, float] = {}
        for r in self.replicas:
            for kk, v in r.energy().items():
                out[kk] = out.get(kk, 0.0) + v
        return out

    def cost_per_token_j(self) -> float:
        """Joules per emitted token across the fleet — the energy-model
        cost the autoscaler trades against SLO attainment."""
        tok = self.total_tokens
        return self.energy()["total_j"] / tok if tok else 0.0

    def slo_attainment(self, ttft_slo_s: float = 0.5,
                       tpot_slo_s: float = 0.05) -> float:
        """Fraction of requests that finished AND met both the TTFT and
        (for multi-token outputs) the TPOT objective."""
        import numpy as np
        n = self.n_requests
        if not n:
            return 1.0
        finish = np.asarray(self.finish_s)
        first = np.asarray(self.first_token_s)
        olen = np.asarray(self.output_len)
        ok = np.isfinite(finish) \
            & ((first - np.asarray(self.arrival_s)) <= ttft_slo_s)
        tpot = np.where(olen > 1,
                        (finish - first) / np.maximum(olen - 1, 1), 0.0)
        ok &= ~(tpot > tpot_slo_s)       # NaN tpot already failed above
        return float(np.count_nonzero(ok)) / n

    def stats(self, *, ttft_slo_s: float = 0.5,
              tpot_slo_s: float = 0.05) -> Dict[str, float]:
        """Tidy scalar summary (the ``as_fleet_records`` row body)."""
        out: Dict[str, float] = {
            "n_requests": self.n_requests,
            "n_replicas": self.n_replicas,
            "n_steps": self.n_steps,
            "makespan_s": self.makespan_s,
            "busy_s": self.busy_s,
            "throughput_tok_s": self.throughput_tok_s,
            "throughput_req_s": self.throughput_req_s,
            "occupancy": self.occupancy,
            "slo_attainment": self.slo_attainment(
                ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s),
            "cost_per_token_j": self.cost_per_token_j(),
            "total_j": self.energy()["total_j"],
            "n_scale_events": len(self.scale_events),
        }
        out.update(_population_stats(self.arrival_s, self.output_len,
                                     self.first_token_s, self.finish_s))
        return out


def simulate_fleet(cfg, trace, policy: BatchingPolicy,
                   config: Optional[EngineConfig] = None, *,
                   n_replicas: int = 2,
                   router: Union[str, RouterPolicy] = "round_robin",
                   autoscaler: Optional[QueueDepthAutoscaler] = None,
                   bytes_per_param: float = 2.0,
                   record_steps: bool = False,
                   max_steps: int = 100_000_000,
                   table: Optional[StepCostTable] = None,
                   name: str = "") -> FleetResult:
    """Replay ``trace`` across an N-replica fleet: each arrival is routed
    to one ``_Replica`` scheduler (every replica runs the same batching
    ``policy`` on its own ``config``-worth of hardware), advanced
    incrementally to the arrival instant.  All replicas share one
    ``StepCostTable``, so the whole fleet prices steps out of one memo.

    ``router`` is a name or ``RouterPolicy`` (round_robin /
    least_outstanding / session_affinity).  Stateful routers (and any
    ``autoscaler``) drain every active replica to each arrival so queue
    depths are exact at routing time; stateless routers drain lazily.

    With a ``QueueDepthAutoscaler``, scale-up spawns a fresh replica at
    the arrival instant and scale-down retires the emptiest active
    replica — it finishes its queued work but receives no new requests.
    A replica that routed at least one request is never lost: retired
    and spawned replicas all report in ``FleetResult.replicas``.

    Each request is routed to exactly one replica and served exactly
    once (the conservation property asserted in tests/test_fleet.py);
    with ``n_replicas=1`` and the round-robin router the result is
    bit-identical to ``replay_serving`` (and so to
    ``simulate_serving``)."""
    if config is None:
        config = EngineConfig()
    if isinstance(router, str):
        router = get_router(router)
    if table is not None:
        if not table.matches(cfg, config, bytes_per_param):
            raise ValueError("StepCostTable was built for a different "
                             "(model, config, bytes_per_param)")
    else:
        table = StepCostTable(cfg, config, bytes_per_param=bytes_per_param)
    arrival, rid, plen, olen = _trace_columns(trace)
    n = len(rid)
    nan = float("nan")
    first = [nan] * n
    finish = [nan] * n
    replica_of = [0] * n
    replicas: List[_Replica] = []

    def spawn(t0: float) -> _Replica:
        r = _Replica(table, policy, first, finish, t0=t0,
                     record_steps=record_steps, max_steps=max_steps,
                     index=len(replicas))
        replicas.append(r)
        return r

    n0 = max(1, int(n_replicas))
    if autoscaler is not None:
        n0 = min(max(n0, autoscaler.min_replicas),
                 autoscaler.max_replicas)
    active = [spawn(0.0) for _ in range(n0)]
    stateful = router.stateful or autoscaler is not None
    events: List[ScaleEvent] = []
    last_change = float("-inf")
    route = router.route

    for j in range(n):
        a = arrival[j]
        if stateful:
            outstanding = []
            for r in active:
                r.drain_until(a)
                outstanding.append(r.outstanding)
            if autoscaler is not None:
                depth = sum(outstanding) / len(active)
                act = autoscaler.decide(len(active), depth, a,
                                        last_change)
                if act > 0:
                    active.append(spawn(a))
                    outstanding.append(0)
                    last_change = a
                    events.append(ScaleEvent(a, "up", len(active), depth))
                elif act < 0:
                    i_min = min(range(len(active)),
                                key=outstanding.__getitem__)
                    active.pop(i_min)        # retires: drains, no routes
                    outstanding.pop(i_min)
                    last_change = a
                    events.append(ScaleEvent(a, "down", len(active),
                                             depth))
        else:
            outstanding = ()
        r = active[route(rid[j], j, outstanding) % len(active)]
        if not stateful:
            r.drain_until(a)
        r.push(a, j, plen[j], olen[j])
        replica_of[j] = r.index

    inf = float("inf")
    for r in replicas:
        r.trace_done = True
    for r in replicas:
        r.drain_until(inf)

    import numpy as np
    rid_a = np.asarray(rid, dtype=np.int64)
    arr_a = np.asarray(arrival, dtype=np.float64)
    pl_a = np.asarray(plen, dtype=np.int64)
    ol_a = np.asarray(olen, dtype=np.int64)
    fi_a = np.asarray(first, dtype=np.float64)
    fo_a = np.asarray(finish, dtype=np.float64)
    ro_a = np.asarray(replica_of, dtype=np.int64)
    base = name or f"{getattr(cfg, 'name', 'model')}" \
        f"/fleet-{router.kind}x{len(replicas)}"
    per: List[ReplayResult] = []
    for r in replicas:
        sel = np.nonzero(ro_a == r.index)[0]
        per.append(ReplayResult(
            name=f"{base}/r{r.index}", policy=policy, config=config,
            rid=rid_a[sel], arrival_s=arr_a[sel], prompt_len=pl_a[sel],
            output_len=ol_a[sel], first_token_s=fi_a[sel],
            finish_s=fo_a[sel], makespan_s=r.last_end, busy_s=r.busy,
            n_steps=r.k, decode_steps=r.decode_steps,
            decode_slot_steps=r.decode_slot_steps,
            prefill_tokens=r.prefill_tokens,
            active_tokens=r.active_tokens, flops=r.flops,
            transfer_j=r.transfer_j, steps=r.steps,
            meta={"replica": r.index, "spawn_s": r.spawn_s,
                  "retired": r not in active}))
    return FleetResult(
        name=base, replicas=per, router=router, policy=policy,
        config=config, rid=rid_a, arrival_s=arr_a, prompt_len=pl_a,
        output_len=ol_a, first_token_s=fi_a, finish_s=fo_a,
        replica_of=ro_a, scale_events=events,
        makespan_s=max((r.last_end for r in replicas), default=0.0),
        meta={"bytes_per_param": bytes_per_param,
              "memo_hits": table.hits, "memo_misses": table.misses,
              "memo_hit_rate": table.hit_rate})


# ---------------------------------------------------------------------------
# the policy x arrival-rate design-space grid


def serving_sweep(cfg, policies: Sequence[BatchingPolicy],
                  rates_rps: Sequence[float], *, n_requests: int = 64,
                  config: Optional[EngineConfig] = None,
                  trace_kind: str = "poisson", seed: int = 0,
                  bytes_per_param: float = 2.0,
                  **trace_kw) -> List[ServingResult]:
    """Evaluate every (policy, arrival-rate) cell on the SAME trace per
    rate (one seeded generator call per rate, shared across policies, so
    the comparison isolates the policy).  Returns results in
    ``for rate: for policy:`` order; each carries its cell coordinates in
    ``result.meta``."""
    if config is None:
        config = EngineConfig()
    gen = TRACE_GENERATORS[trace_kind]
    out: List[ServingResult] = []
    for rate in rates_rps:
        trace = gen(n_requests, rate, seed=seed, **trace_kw)
        for policy in policies:
            res = simulate_serving(cfg, trace, policy, config,
                                   bytes_per_param=bytes_per_param)
            res.meta.update({"rate_rps": rate, "policy": policy.kind,
                             "trace_kind": trace_kind, "seed": seed})
            out.append(res)
    return out


def as_serving_records(results: Sequence[Union[ServingResult,
                                               ReplayResult]]
                       ) -> List[Dict[str, float]]:
    """Flatten ``ServingResult``/``ReplayResult``s to tidy per-cell
    dicts (the serving analogue of ``sweep.as_records``).  Every row
    carries the same columns — ``rate_rps`` and ``trace_kind`` are
    always present (``None`` when the result did not come from a sweep
    cell), so downstream tables never KeyError on mixed provenance."""
    rows = []
    for r in results:
        if isinstance(r, ReplayResult):
            # the replay runs no engine; its busy clock IS the chained
            # program's makespan (bit-identical, see tests/test_fleet.py)
            program, makespan = r.name, r.busy_s
            total_j = r.energy()["total_j"]
        else:
            program, makespan = r.program.name, r.engine.makespan
            total_j = r.engine.energy["total_j"]
        row = {"program": program, "policy": r.policy.kind,
               "max_batch": r.policy.max_batch,
               "rate_rps": r.meta.get("rate_rps"),
               "trace_kind": r.meta.get("trace_kind"),
               "interface": r.config.interface,
               "engine_makespan_s": makespan,
               "total_j": total_j}
        row.update(r.stats())
        rows.append(row)
    return rows


def as_fleet_records(results: Sequence[FleetResult], *,
                     ttft_slo_s: float = 0.5, tpot_slo_s: float = 0.05,
                     per_replica: bool = False) -> List[Dict]:
    """Flatten ``FleetResult``s to tidy rows (one per fleet, or one per
    replica with ``per_replica=True``).  Fleet rows carry the SLO /
    cost-per-token roll-up; replica rows reuse ``as_serving_records``
    columns plus the fleet coordinates."""
    rows: List[Dict] = []
    for f in results:
        if per_replica:
            for rr in f.replicas:
                row = as_serving_records([rr])[0]
                row.update({"fleet": f.name, "router": f.router.kind,
                            "replica": rr.meta.get("replica"),
                            "rate_rps": f.meta.get("rate_rps"),
                            "trace_kind": f.meta.get("trace_kind")})
                rows.append(row)
            continue
        row = {"fleet": f.name, "router": f.router.kind,
               "policy": f.policy.kind,
               "max_batch": f.policy.max_batch,
               "rate_rps": f.meta.get("rate_rps"),
               "trace_kind": f.meta.get("trace_kind"),
               "interface": f.config.interface,
               "memo_hit_rate": f.meta.get("memo_hit_rate")}
        row.update(f.stats(ttft_slo_s=ttft_slo_s,
                           tpot_slo_s=tpot_slo_s))
        rows.append(row)
    return rows
