"""Trace-driven serving simulation: request arrivals, batching, the engine.

SMAUG's core claim is that end-to-end behavior — queueing, data movement
and framework overhead *around* the accelerator — dominates what per-layer
kernel models predict.  This module extends that argument from a single
request to a served workload: a trace of requests (arrival time, prompt
length, output length) is replayed against a batching policy
(``repro.serve.policy``), every scheduler iteration is lowered to costed
ops via ``ir.from_serving_step``, and the chained step programs run
through the PR-1/2 event engine — so one simulation yields per-request
latency (TTFT / TPOT / p50 / p99), throughput and batch occupancy
*alongside* the existing Timeline / Breakdown / Roofline / energy views.

The pieces:

  ``Request`` / ``poisson_trace`` / ``bursty_trace``
      synthetic workload generators (seeded, fully deterministic) plus a
      loadable record format (``load_trace`` / ``save_trace`` /
      ``trace_from_records``: JSON or JSON-lines with ``arrival_s``,
      ``prompt_len``, ``output_len`` fields);
  ``simulate_serving(cfg, trace, policy, config)``
      the scheduler co-simulation (below), returning a ``ServingResult``;
  ``serving_sweep`` / ``as_serving_records``
      the policy x arrival-rate design-space grid, one ``ServingResult``
      per cell, flattened to tidy records like ``sweep.as_records``.

How the co-simulation works.  Batching decisions depend on simulated time
(arrivals race batch completions), so the scheduler advances its own clock
while it builds the program: each iteration it forms a step per the
policy, lowers it with ``ir.from_serving_step``, and advances time by the
step's cost from ``engine.chain_op_costs`` — the exact per-op terms of the
engine's chain fast path, added in the engine's addition order.  The
chained steps form a pure linear chain, so when the finished program runs
through ``sweep()`` the engine's makespan equals the scheduler's
accumulated busy time *bit-for-bit* (asserted in tests/test_serving.py);
the wall clock additionally contains the idle gaps where the server waited
for arrivals, which exist only in the scheduler's timeline
(``ServingResult.makespan_s`` vs ``EngineResult.makespan``).

Same trace + same policy + same config => bit-identical ``ServingResult``
(the scheduler is deterministic and the engine already is).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.timeline import Timeline
from repro.serve.policy import BatchingPolicy, StaticBatching
from repro.sim import engine, ir
from repro.sim.engine import EngineConfig, EngineResult
from repro.sim.ir import Program
from repro.sim.report import latency_stats

__all__ = [
    "Request", "RequestMetrics", "StepRecord", "ServingResult",
    "poisson_trace", "bursty_trace", "trace_from_records", "load_trace",
    "save_trace", "simulate_serving", "serving_sweep", "as_serving_records",
]


# ---------------------------------------------------------------------------
# the request trace


@dataclass(frozen=True)
class Request:
    """One serving request: when it arrives and how much work it is."""
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int


_Len = Union[int, Tuple[int, int]]

# name -> generator, the ``trace_kind`` registry shared by serving_sweep
# and apps.serving.serve_trace (populated after the generators below)
TRACE_GENERATORS: Dict[str, object] = {}


def _draw_len(rng, spec: _Len, n: int):
    if isinstance(spec, int):
        return [spec] * n
    lo, hi = spec
    return [int(v) for v in rng.integers(lo, hi + 1, size=n)]


def poisson_trace(n_requests: int, rate_rps: float, *,
                  prompt_len: _Len = (16, 128), output_len: _Len = (8, 64),
                  seed: int = 0) -> List[Request]:
    """Poisson arrivals at ``rate_rps`` requests/s; prompt and output
    lengths uniform over inclusive ``(lo, hi)`` ranges (or fixed ints).
    Seeded and deterministic: the same arguments always yield the same
    trace."""
    import numpy as np
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps)
    plens = _draw_len(rng, prompt_len, n_requests)
    olens = _draw_len(rng, output_len, n_requests)
    return [Request(i, float(arrivals[i]), max(plens[i], 1),
                    max(olens[i], 1)) for i in range(n_requests)]


def bursty_trace(n_requests: int, rate_rps: float, *, burst_size: int = 8,
                 burst_factor: float = 10.0, prompt_len: _Len = (16, 128),
                 output_len: _Len = (8, 64), seed: int = 0) -> List[Request]:
    """Bursty arrivals: groups of ``burst_size`` requests arrive at
    ``burst_factor``x the base rate, separated by exponential lulls of mean
    ``burst_size / rate_rps`` — the long-run rate stays near ``rate_rps``
    but queue depth spikes, which is what separates admission policies."""
    import numpy as np
    rng = np.random.default_rng(seed)
    t = 0.0
    arrivals = []
    for i in range(n_requests):
        if i and i % burst_size == 0:
            t += float(rng.exponential(burst_size / rate_rps))
        else:
            t += float(rng.exponential(1.0 / (rate_rps * burst_factor)))
        arrivals.append(t)
    plens = _draw_len(rng, prompt_len, n_requests)
    olens = _draw_len(rng, output_len, n_requests)
    return [Request(i, arrivals[i], max(plens[i], 1), max(olens[i], 1))
            for i in range(n_requests)]


TRACE_GENERATORS.update(poisson=poisson_trace, bursty=bursty_trace)


def trace_from_records(records: Sequence[Dict]) -> List[Request]:
    """Build a trace from dict records with ``arrival_s`` / ``prompt_len``
    / ``output_len`` keys (``rid`` optional; defaults to record order).
    Raises ValueError on duplicate rids — per-request metrics are keyed on
    them."""
    trace = [Request(int(r.get("rid", i)), float(r["arrival_s"]),
                     max(int(r["prompt_len"]), 1),
                     max(int(r["output_len"]), 1))
             for i, r in enumerate(records)]
    if len({r.rid for r in trace}) != len(trace):
        raise ValueError("duplicate rid in trace records")
    return trace


def load_trace(path) -> List[Request]:
    """Load a trace file: a JSON array of records, or JSON-lines (one
    record per line)."""
    with open(path) as f:
        text = f.read().strip()
    if not text:
        return []
    if text[0] == "[":
        return trace_from_records(json.loads(text))
    return trace_from_records([json.loads(ln) for ln in text.splitlines()
                               if ln.strip()])


def save_trace(path, trace: Sequence[Request]) -> None:
    """Write a trace as JSON-lines (the ``load_trace`` record format)."""
    with open(path, "w") as f:
        for r in trace:
            f.write(json.dumps({"rid": r.rid, "arrival_s": r.arrival_s,
                                "prompt_len": r.prompt_len,
                                "output_len": r.output_len}) + "\n")


# ---------------------------------------------------------------------------
# results


@dataclass
class RequestMetrics:
    """Per-request outcome; all times are absolute wall-clock seconds."""
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int
    first_token_s: float = float("nan")
    finish_s: float = float("nan")

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival -> end of the prefill step."""
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> float:
        """Time per output token over the decode phase (0 for
        single-token outputs)."""
        if self.output_len <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (self.output_len - 1)

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclass(frozen=True)
class StepRecord:
    """One scheduler iteration: where it sat in wall time and what it
    batched.  ``n_active`` counts decode slots that emitted a token;
    ``n_decode - n_active`` is padding (static batching's waste)."""
    index: int
    start_s: float
    duration_s: float
    n_prefill: int
    n_decode: int
    n_active: int

    @property
    def end_s(self) -> float:
        return self.start_s + self.duration_s


@dataclass
class ServingResult:
    """Everything one served-trace simulation produced.

    ``engine`` is the ordinary ``EngineResult`` of the chained step program
    (Timeline / Breakdown / Roofline / energy of the *work*, back-to-back);
    ``makespan_s`` is the serving wall clock, which additionally contains
    the idle gaps where the server waited for arrivals.  On any non-idle
    trace ``engine.makespan <= makespan_s``, with bit-exact equality of
    ``engine.makespan`` and ``busy_s``."""
    program: Program
    engine: EngineResult
    requests: List[RequestMetrics]
    steps: List[StepRecord]
    policy: BatchingPolicy
    config: EngineConfig
    makespan_s: float                 # wall clock: end of the last step
    busy_s: float                     # engine-order sum of step costs
    meta: Dict = field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return sum(s.n_active for s in self.steps) \
            + sum(s.n_prefill for s in self.steps)

    @property
    def throughput_tok_s(self) -> float:
        """Output tokens per wall-clock second (prefill emits the first
        token of each request; decode emits the rest)."""
        return self.total_tokens / self.makespan_s if self.makespan_s \
            else 0.0

    @property
    def throughput_req_s(self) -> float:
        done = sum(1 for r in self.requests if r.finish_s == r.finish_s)
        return done / self.makespan_s if self.makespan_s else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of the ``max_batch`` decode slots that emitted a
        token, over steps that decoded at all — the batch-utilization view
        of the policy comparison."""
        decode_steps = [s for s in self.steps if s.n_decode]
        if not decode_steps:
            return 0.0
        return sum(s.n_active for s in decode_steps) \
            / (self.policy.max_batch * len(decode_steps))

    def stats(self) -> Dict[str, float]:
        """Tidy scalar summary (the ``as_serving_records`` row body)."""
        out: Dict[str, float] = {
            "n_requests": len(self.requests),
            "n_steps": len(self.steps),
            "makespan_s": self.makespan_s,
            "busy_s": self.busy_s,
            "throughput_tok_s": self.throughput_tok_s,
            "throughput_req_s": self.throughput_req_s,
            "occupancy": self.occupancy,
        }
        for nm, vals in (("ttft", [r.ttft_s for r in self.requests]),
                         ("tpot", [r.tpot_s for r in self.requests
                                   if r.output_len > 1]),
                         ("latency", [r.latency_s for r in self.requests])):
            for k, v in latency_stats(vals).items():
                if k != "n":
                    out[f"{nm}_{k}"] = v
        return out

    def wall_timeline(self) -> Timeline:
        """Wall-clock step timeline (arrival gaps visible as idle), one
        event per scheduler step — the serving analogue of the engine's
        per-op Timeline."""
        tl = Timeline()
        for s in self.steps:
            tl.add("serve", f"step{s.index}", s.start_s, s.duration_s,
                   "compute", phase=f"step{s.index}")
        return tl


# ---------------------------------------------------------------------------
# the scheduler co-simulation


@dataclass
class _Slot:
    req: Request
    produced: int = 0     # output tokens emitted so far
    pos: int = 0          # current KV length (prompt written at prefill)

    @property
    def done(self) -> bool:
        return self.produced >= self.req.output_len


def simulate_serving(cfg, trace: Sequence[Request],
                     policy: BatchingPolicy,
                     config: Optional[EngineConfig] = None, *,
                     bytes_per_param: float = 2.0,
                     max_steps: int = 1_000_000,
                     name: str = "") -> ServingResult:
    """Replay ``trace`` against ``policy`` on ``config``; see the module
    header for the co-simulation semantics.

    ``cfg`` is a ``repro.core.config.ModelConfig`` (the served model);
    ``config`` defaults to a fresh ``EngineConfig()`` (``None`` sentinel —
    no shared module-level instance); ``bytes_per_param`` matches
    ``ir.from_decode``.  Raises RuntimeError past ``max_steps`` iterations
    (a policy that stops making progress).

    Heterogeneous topologies are supported as long as the accelerator
    pool is uniform (one cost signature + link across the class's
    candidate devices): ``chain_op_costs`` prices each op at the class's
    reference device, so a mixed pool would silently break the
    busy_s == engine.makespan invariant — it is rejected instead."""
    if config is None:
        config = EngineConfig()
    if not engine.uniform_class_params(config, "accel"):
        raise ValueError(
            "serving co-simulation requires a uniform accelerator pool: "
            "the topology's accel-class devices resolve to more than one "
            "cost signature/link, so chain_op_costs cannot price ops "
            "exactly as the engine would charge them")
    trace = sorted(trace, key=lambda r: (r.arrival_s, r.rid))
    if len({r.rid for r in trace}) != len(trace):
        raise ValueError("duplicate rid in trace; per-request metrics are "
                         "keyed on it")
    metrics = {r.rid: RequestMetrics(r.rid, r.arrival_s, r.prompt_len,
                                     r.output_len) for r in trace}
    static = isinstance(policy, StaticBatching) or policy.kind == "static"
    continuous = policy.kind == "continuous"

    all_ops: List[ir.CostedOp] = []
    prev_op: Optional[str] = None
    steps: List[StepRecord] = []
    waiting: List[Request] = []
    live: List[_Slot] = []
    i = 0                          # next un-arrived trace index
    t = 0.0                        # wall clock (includes arrival gaps)
    busy = 0.0                     # engine-order accumulation of op costs
    k = 0                          # step index
    stalled = 0                    # consecutive zero-progress idle loops

    while True:
        while i < len(trace) and trace[i].arrival_s <= t:
            waiting.append(trace[i])
            i += 1
        trace_done = i >= len(trace)

        # eviction: continuous/dynamic free slots at end-of-output; static
        # holds the formed batch (padding) until every member finishes
        if static:
            if live and all(s.done for s in live):
                live = []
        else:
            live = [s for s in live if not s.done]

        # admission
        admitted: List[Request] = []
        if continuous:
            free = policy.max_batch - len(live)
            if free > 0 and waiting:
                admitted, waiting = waiting[:free], waiting[free:]
        elif not live and waiting:
            oldest = waiting[0].arrival_s
            # the absolute-deadline comparison repeats the exact float
            # expression the idle-advance below lands on, so a batch
            # launched "at the deadline" cannot miss it to rounding
            if (policy.ready(len(waiting), t - oldest, trace_done)
                    or t >= policy.launch_deadline_s(oldest)):
                admitted = waiting[:policy.max_batch]
                waiting = waiting[policy.max_batch:]

        decode_slots = [s for s in live if s.produced >= 1
                        and (static or not s.done)]
        if not admitted and not decode_slots:
            # nothing runnable: advance to the next arrival or (dynamic)
            # the oldest waiter's launch deadline; done when neither exists
            nxt = []
            if i < len(trace):
                nxt.append(trace[i].arrival_s)
            if waiting:
                nxt.append(policy.launch_deadline_s(waiting[0].arrival_s))
            nxt = [x for x in nxt if x < float("inf")]
            if not nxt:
                break
            t_new = max(t, min(nxt))
            if t_new == t:
                stalled += 1
                if stalled > 2:
                    raise RuntimeError(
                        f"serving scheduler stalled at t={t} with "
                        f"{len(waiting)} waiting (policy {policy.kind!r})")
            else:
                stalled = 0
            t = t_new
            continue

        # lower this iteration and advance both clocks with the exact
        # chain-path costs (see engine.chain_op_costs)
        step_prog = ir.from_serving_step(
            cfg, step=k,
            prefill_lens=tuple(r.prompt_len for r in admitted),
            decode_positions=tuple(s.pos for s in decode_slots),
            bytes_per_param=bytes_per_param)
        t0 = t
        for op in step_prog.ops:
            if prev_op is not None and not op.deps:
                op = ir.replace(op, deps=(prev_op,))
            all_ops.append(op)
            prev_op = op.name
            h, x, c, l = engine.chain_op_costs(op, config)
            t += h
            t += x
            t += c
            t += l
            busy += h
            busy += x
            busy += c
            busy += l

        n_active = 0
        for s in decode_slots:
            if not s.done:
                s.produced += 1
                n_active += 1
                if s.done:
                    metrics[s.req.rid].finish_s = t
            s.pos += 1          # padded static slots advance with the batch
        for r in admitted:
            slot = _Slot(r, produced=1, pos=r.prompt_len)
            metrics[r.rid].first_token_s = t
            if slot.done:
                metrics[r.rid].finish_s = t
            live.append(slot)
        steps.append(StepRecord(k, t0, t - t0, len(admitted),
                                len(decode_slots), n_active))
        k += 1
        if k > max_steps:
            raise RuntimeError(f"serving scheduler exceeded {max_steps} "
                               f"steps (policy {policy.kind!r})")

    program = Program(
        all_ops, name=name or f"{getattr(cfg, 'name', 'model')}"
        f"/serve-{policy.kind}x{len(trace)}", source="serving",
        meta={"policy": policy.kind, "max_batch": policy.max_batch,
              "n_requests": len(trace), "n_steps": len(steps)})
    # the chained steps are a pure linear chain -> the official run takes
    # the engine's prefix-sum fast path, through the sweep/DSE layer
    from repro.sim.sweep import sweep
    (engine_res,) = sweep(program, [config])
    return ServingResult(program=program, engine=engine_res,
                         requests=[metrics[r.rid] for r in trace],
                         steps=steps, policy=policy, config=config,
                         makespan_s=t, busy_s=busy,
                         meta={"bytes_per_param": bytes_per_param})


# ---------------------------------------------------------------------------
# the policy x arrival-rate design-space grid


def serving_sweep(cfg, policies: Sequence[BatchingPolicy],
                  rates_rps: Sequence[float], *, n_requests: int = 64,
                  config: Optional[EngineConfig] = None,
                  trace_kind: str = "poisson", seed: int = 0,
                  bytes_per_param: float = 2.0,
                  **trace_kw) -> List[ServingResult]:
    """Evaluate every (policy, arrival-rate) cell on the SAME trace per
    rate (one seeded generator call per rate, shared across policies, so
    the comparison isolates the policy).  Returns results in
    ``for rate: for policy:`` order; each carries its cell coordinates in
    ``result.meta``."""
    if config is None:
        config = EngineConfig()
    gen = TRACE_GENERATORS[trace_kind]
    out: List[ServingResult] = []
    for rate in rates_rps:
        trace = gen(n_requests, rate, seed=seed, **trace_kw)
        for policy in policies:
            res = simulate_serving(cfg, trace, policy, config,
                                   bytes_per_param=bytes_per_param)
            res.meta.update({"rate_rps": rate, "policy": policy.kind,
                             "trace_kind": trace_kind, "seed": seed})
            out.append(res)
    return out


def as_serving_records(results: Sequence[ServingResult]
                       ) -> List[Dict[str, float]]:
    """Flatten ``ServingResult``s to tidy per-cell dicts (the serving
    analogue of ``sweep.as_records``)."""
    rows = []
    for r in results:
        row = {"program": r.program.name, "policy": r.policy.kind,
               "max_batch": r.policy.max_batch,
               "rate_rps": r.meta.get("rate_rps"),
               "interface": r.config.interface,
               "engine_makespan_s": r.engine.makespan,
               "total_j": r.engine.energy["total_j"]}
        row.update(r.stats())
        rows.append(row)
    return rows
