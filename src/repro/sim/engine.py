"""Event-driven executor: one simulated execution -> every paper metric.

``run(program, config)`` schedules ``CostedOp``s over N accelerator workers:

  * every producer->consumer tensor is staged through a pluggable interface
    model ("hbm" bare round-trip, "dma" software-managed staging,
    "acp" fused/VMEM-resident, "ideal" free) — the Fig 11 study is just two
    runs of the same program;
  * concurrent transfers contend for a fixed number of HBM ports (effective
    bandwidth divides once active transfers exceed ports — this replaces
    the old ad-hoc ``shared_bw_penalty`` scaling);
  * each dispatch charges serial host/framework time (per-op launch cost
    plus a host-bandwidth tiling term divided over host threads — the
    Fig 15/16 multithreading study);
  * reduction-affinity ops pin to one worker queue (Fig 14);
  * collective traffic serializes on the ICI lane.

The result carries the Timeline, the Fig-1 Breakdown, the Roofline terms and
the energy estimate of the *same* run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.energy import DEFAULT_ENERGY, EnergyModel
from repro.core.timeline import Timeline
from repro.sim import hw, report
from repro.sim.ir import CostedOp, Program


# ---------------------------------------------------------------------------
# interface models (seconds, joules) for staging ``nbytes`` between ops


def _iface_hbm(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    """Bare HBM traffic at full bandwidth — the roofline memory model."""
    return nbytes / cfg.hbm_bw, cfg.energy.hbm(nbytes)


def _iface_dma(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    from repro.core.interfaces import dma_transfer
    n = max(1, int(nbytes // cfg.dma_transfer_bytes))
    c = dma_transfer(nbytes, n_transfers=n, em=cfg.energy,
                     hbm_bw=cfg.hbm_bw)
    return c.seconds, c.energy_j


def _iface_acp(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    from repro.core.interfaces import acp_transfer
    resident = 1.0 if nbytes < cfg.vmem_resident_bytes else 0.5
    c = acp_transfer(nbytes, resident_fraction=resident, em=cfg.energy,
                     hbm_bw=cfg.hbm_bw, vmem_bw=cfg.vmem_bw)
    return c.seconds, c.energy_j


def _iface_ideal(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    return 0.0, 0.0


INTERFACES: Dict[str, Callable] = {
    "hbm": _iface_hbm, "dma": _iface_dma, "acp": _iface_acp,
    "ideal": _iface_ideal,
}


@dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 1
    interface: str = "hbm"            # hbm | dma | acp | ideal
    peak_flops: float = hw.PEAK_FLOPS
    hbm_bw: float = hw.HBM_BW
    vmem_bw: float = hw.VMEM_BW
    ici_bw: float = hw.ICI_BW
    # HBM-port contention: active transfers beyond this many share bandwidth
    # (0 = one port per worker, i.e. no contention; fractional values allow
    # exact translation of the legacy shared_bw_penalty)
    hbm_ports: float = 0
    # host/framework model: serial per-dispatch launch cost + a tiling term
    # (bytes over host_bw) divided across host worker threads
    host_dispatch_s: float = 0.0
    host_bw: float = 0.0              # 0 = no per-byte host cost
    host_threads: int = 1
    host_floor_s: float = 0.0         # per-run framework floor (Fig 1 host)
    # transfer/compute overlap: the MXU double-buffers its operand traffic,
    # so only memory time beyond the dot compute is exposed; the DMA path
    # serializes (SW-managed staging completes before compute starts)
    overlap_transfers: Optional[bool] = None   # None -> interface != "dma"
    # scales the accelerator's local datapath (scratchpad/VMEM port width):
    # a half-size PE array also halves its feed bandwidth (Fig 20 sweep)
    datapath_scale: float = 1.0
    vmem_resident_bytes: float = 32 * 1024 * 1024
    dma_transfer_bytes: float = 64 * 1024
    energy: EnergyModel = DEFAULT_ENERGY
    n_chips: int = 1

    @property
    def overlap(self) -> bool:
        if self.overlap_transfers is None:
            return self.interface != "dma"
        return self.overlap_transfers


@dataclass
class EngineResult:
    timeline: Timeline
    program: Program
    config: EngineConfig
    breakdown: report.Breakdown
    roofline: report.Roofline
    energy: Dict[str, float]
    makespan: float

    @property
    def per_kind(self) -> Dict[str, float]:
        return report.aggregate(self.timeline.events, "kind")

    @property
    def per_phase(self) -> Dict[str, float]:
        return report.aggregate(self.timeline.events, "phase")

    def utilization(self, worker: Optional[str] = None) -> float:
        """Accelerator-worker utilization (the host and ICI lanes are
        resources, not workers — they don't dilute the denominator)."""
        if worker is not None:
            return self.timeline.utilization(worker)
        evs = [e for e in self.timeline.events
               if e.worker.startswith("acc") and e.kind != "idle"]
        workers = {e.worker for e in evs}
        total = self.timeline.makespan * max(len(workers), 1)
        return sum(e.duration for e in evs) / total if total else 0.0


# ---------------------------------------------------------------------------
# the executor


def run(program: Program, config: EngineConfig = EngineConfig(), *,
        model_flops: float = 0.0, host_s: Optional[float] = None
        ) -> EngineResult:
    """Simulate ``program`` on ``config``; returns every metric of the run.

    ``host_s``: roofline host floor (defaults to ``config.host_floor_s``).
    """
    if config.interface not in INTERFACES:
        raise ValueError(f"unknown interface {config.interface!r}; "
                         f"one of {sorted(INTERFACES)}")
    iface = INTERFACES[config.interface]
    tl = Timeline()
    n = max(config.n_workers, 1)
    avail = [0.0] * n
    affinity_worker: Dict[str, int] = {}
    done: Dict[str, float] = {}
    host_free = 0.0
    ici_free = 0.0
    transfers: List[Tuple[float, float]] = []   # active (start, end) windows
    transfer_energy = 0.0
    iface_time_total = [0.0]    # full interface seconds charged this run

    # dependency bookkeeping
    ops = {op.name: op for op in program.ops}
    n_waiting = {op.name: sum(1 for d in op.deps if d in ops)
                 for op in program.ops}
    consumers: Dict[str, List[str]] = {}
    for op in program.ops:
        for d in op.deps:
            if d in ops:
                consumers.setdefault(d, []).append(op.name)
    ready = [op.name for op in program.ops if n_waiting[op.name] == 0]
    if not ready and program.ops:
        raise ValueError("dependency cycle in program")
    scheduled = 0

    def op_compute_s(op: CostedOp) -> float:
        if op.duration_s is not None:
            return op.duration_s
        return op.flops / config.peak_flops

    def op_transfer_base(op: CostedOp) -> Tuple[float, float, float]:
        """(full seconds, exposed seconds, energy) for this op's staging.

        ``full`` is the interface time at nominal bandwidth; ``exposed`` is
        what the worker actually stalls on — in overlap mode the MXU stream
        hides operand traffic behind the op's dot compute."""
        if op.transfer_s is not None:
            return op.transfer_s, op.transfer_s, config.energy.hbm(
                op.transfer_s * config.hbm_bw)
        if not op.bytes:
            return 0.0, 0.0, 0.0
        t, e = iface(op.bytes, config)
        t /= config.datapath_scale
        exposed = (max(t - op.dot_flops / config.peak_flops, 0.0)
                   if config.overlap else t)
        return t, exposed, e

    def contention_factor(start: float) -> float:
        if config.hbm_ports <= 0:
            return 1.0
        live = 1 + sum(1 for (s, e) in transfers if s <= start < e)
        return max(1.0, live / config.hbm_ports)

    while ready:
        # LPT among currently-ready ops (the legacy scheduler heuristic)
        ready.sort(key=lambda nm: -op_compute_s(ops[nm]))
        batch, ready = ready, []
        for nm in batch:
            op = ops[nm]
            if op.affinity is not None and op.affinity in affinity_worker:
                w = affinity_worker[op.affinity]
            else:
                w = min(range(n), key=lambda i: avail[i])
                if op.affinity is not None:
                    affinity_worker[op.affinity] = w
            dep_ready = max((done[d] for d in op.deps if d in done),
                            default=0.0)
            t = max(avail[w], dep_ready)
            # serial host dispatch (framework time) gates the launch
            host_cost = (config.host_dispatch_s
                         + (op.bytes / config.host_bw / config.host_threads
                            if config.host_bw else 0.0))
            if host_cost > 0.0:
                h0 = max(host_free, dep_ready)
                tl.add("host", f"{op.name}:dispatch", h0, host_cost, "host",
                       phase=op.phase)
                host_free = h0 + host_cost
                t = max(t, host_free)
            # staged input transfer, with HBM-port contention
            full, xfer, xe = op_transfer_base(op)
            transfer_energy += xe
            if xfer > 0.0:
                factor = contention_factor(t)
                xfer *= factor
                tl.add(f"acc{w}", f"{op.name}:xfer", t, xfer, "transfer",
                       phase=op.phase)
                transfers.append((t, t + xfer))
                iface_time_total[0] += full * factor
                t += xfer
            else:
                iface_time_total[0] += full
            comp = op_compute_s(op)
            tl.add(f"acc{w}", op.name, t, comp, "compute", phase=op.phase)
            t += comp
            avail[w] = t
            # collective traffic serializes on the ICI lane (operand-sum
            # metric, matching the closed-form breakdown; the ring-model
            # wire bytes feed the roofline collective term instead)
            if op.collective_bytes > 0.0:
                c0 = max(ici_free, t)
                cdur = op.collective_bytes / config.ici_bw
                tl.add("ici", f"{op.name}:coll", c0, cdur, "collective",
                       phase=op.phase)
                ici_free = c0 + cdur
                t = c0 + cdur
            done[nm] = t
            scheduled += 1
            for cn in consumers.get(nm, ()):
                n_waiting[cn] -= 1
                if n_waiting[cn] == 0:
                    ready.append(cn)
    if scheduled != len(program.ops):
        raise ValueError("dependency cycle in program")

    host_floor = config.host_floor_s if host_s is None else host_s
    makespan = tl.makespan
    totals = program.totals()
    bd = report.breakdown_from_events(tl.events, host_floor_s=host_floor)
    if config.overlap:
        # the Fig-1 transfer phase applies the dot-hiding budget at the
        # aggregate level (like the closed form): memory time beyond the
        # program's total MXU time is exposed.  The timeline keeps the
        # per-op view; per-op exposure can only exceed this (Jensen).
        bd.transfer_s = max(
            iface_time_total[0] - totals["dot_flops"] / config.peak_flops,
            0.0)
    rl = report.roofline_from_totals(
        totals, host_s=host_floor, n_chips=config.n_chips,
        model_flops=model_flops, peak_flops=config.peak_flops,
        hbm_bw=config.hbm_bw, ici_bw=config.ici_bw)
    e_comp = config.energy.compute(totals["flops"])
    e_ici = config.energy.ici(totals["collective_bytes"])
    e_static = config.energy.static(makespan + host_floor, 1)
    energy = {
        "compute_j": e_comp, "hbm_j": transfer_energy, "ici_j": e_ici,
        "static_j": e_static,
        "total_j": e_comp + transfer_energy + e_ici + e_static,
        "total_j_all_chips": (e_comp + transfer_energy + e_ici + e_static)
        * config.n_chips,
    }
    return EngineResult(timeline=tl, program=program, config=config,
                        breakdown=bd, roofline=rl, energy=energy,
                        makespan=makespan)
