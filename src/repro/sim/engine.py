"""Event-driven executor: one simulated execution -> every paper metric.

``run(program, config)`` schedules ``CostedOp``s over N accelerator workers:

  * every producer->consumer tensor is staged through a pluggable interface
    model ("hbm" bare round-trip, "dma" software-managed staging,
    "acp" fused/VMEM-resident, "ideal" free) — the Fig 11 study is just two
    runs of the same program;
  * concurrent transfers contend for a fixed number of HBM ports (effective
    bandwidth divides once active transfers exceed ports — this replaces
    the old ad-hoc ``shared_bw_penalty`` scaling);
  * each dispatch charges serial host/framework time (per-op launch cost
    plus a host-bandwidth tiling term divided over host threads — the
    Fig 15/16 multithreading study);
  * reduction-affinity ops pin to one worker queue (Fig 14);
  * collective traffic serializes on the ICI lane.

The result carries the Timeline, the Fig-1 Breakdown, the Roofline terms and
the energy estimate of the *same* run.

Performance.  The core is O(E log E) in the number of ops/events: the
per-wave LPT sort is a max-heap ready queue, and HBM-port contention is
answered from an incrementally maintained active-transfer structure
(finished windows are heap-expired once no future transfer can start before
their end, so memory stays bounded by the live concurrency instead of the
whole history).  Per-op interface/compute costs are schedule-independent
and are computed once, outside the loop.  Linear-chain programs (the
``from_hlo`` macro-op shape and token-by-token decode) take a prefix-sum
fast path that reproduces the event loop bit-for-bit.  ``prepare()`` lets
callers (``repro.sim.sweep``) share the dependency bookkeeping across many
configs of the same program.

Contention sampling semantics.  ``contention_factor`` is evaluated once, at
a transfer's *start instant*: the factor counts the transfers already in
flight at that moment and is locked in for the whole window.  A long
transfer that later overlaps newly issued ones is NOT retroactively slowed
— only the newcomers see the congestion.  This start-instant convention is
deliberate: it keeps single-chain programs exactly equal to the closed-form
interface sums (each transfer starts alone, factor 1), and it mirrors a
bandwidth reservation made at issue time.  Studies that need time-resolved
sharing can shrink op granularity (smaller tiles -> shorter windows) until
the sampling error vanishes.
"""
from __future__ import annotations

from bisect import bisect_right, insort
from dataclasses import dataclass, field
from heapq import heapify, heappop, heappush
from itertools import accumulate
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.energy import DEFAULT_ENERGY, EnergyModel
from repro.core.timeline import Event, Timeline
from repro.sim import hw, report
from repro.sim.ir import CostedOp, Program


# ---------------------------------------------------------------------------
# interface models (seconds, joules) for staging ``nbytes`` between ops


def _iface_hbm(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    """Bare HBM traffic at full bandwidth — the roofline memory model."""
    return nbytes / cfg.hbm_bw, cfg.energy.hbm(nbytes)


def _iface_dma(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    from repro.core.interfaces import dma_transfer
    n = max(1, int(nbytes // cfg.dma_transfer_bytes))
    c = dma_transfer(nbytes, n_transfers=n, em=cfg.energy,
                     hbm_bw=cfg.hbm_bw)
    return c.seconds, c.energy_j


def _iface_acp(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    from repro.core.interfaces import acp_transfer
    resident = 1.0 if nbytes < cfg.vmem_resident_bytes else 0.5
    c = acp_transfer(nbytes, resident_fraction=resident, em=cfg.energy,
                     hbm_bw=cfg.hbm_bw, vmem_bw=cfg.vmem_bw)
    return c.seconds, c.energy_j


def _iface_ideal(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    return 0.0, 0.0


INTERFACES: Dict[str, Callable] = {
    "hbm": _iface_hbm, "dma": _iface_dma, "acp": _iface_acp,
    "ideal": _iface_ideal,
}


@dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 1
    interface: str = "hbm"            # hbm | dma | acp | ideal
    peak_flops: float = hw.PEAK_FLOPS
    hbm_bw: float = hw.HBM_BW
    vmem_bw: float = hw.VMEM_BW
    ici_bw: float = hw.ICI_BW
    # HBM-port contention: active transfers beyond this many share bandwidth
    # (0 = one port per worker, i.e. no contention; fractional values allow
    # exact translation of the legacy shared_bw_penalty)
    hbm_ports: float = 0
    # host/framework model: serial per-dispatch launch cost + a tiling term
    # (bytes over host_bw) divided across host worker threads
    host_dispatch_s: float = 0.0
    host_bw: float = 0.0              # 0 = no per-byte host cost
    host_threads: int = 1
    host_floor_s: float = 0.0         # per-run framework floor (Fig 1 host)
    # transfer/compute overlap: the MXU double-buffers its operand traffic,
    # so only memory time beyond the dot compute is exposed; the DMA path
    # serializes (SW-managed staging completes before compute starts)
    overlap_transfers: Optional[bool] = None   # None -> interface != "dma"
    # scales the accelerator's local datapath (scratchpad/VMEM port width):
    # a half-size PE array also halves its feed bandwidth (Fig 20 sweep)
    datapath_scale: float = 1.0
    vmem_resident_bytes: float = 32 * 1024 * 1024
    dma_transfer_bytes: float = 64 * 1024
    energy: EnergyModel = DEFAULT_ENERGY
    n_chips: int = 1

    @property
    def overlap(self) -> bool:
        if self.overlap_transfers is None:
            return self.interface != "dma"
        return self.overlap_transfers


@dataclass
class EngineResult:
    timeline: Timeline
    program: Program
    config: EngineConfig
    breakdown: report.Breakdown
    roofline: report.Roofline
    energy: Dict[str, float]
    makespan: float

    @property
    def per_kind(self) -> Dict[str, float]:
        return report.aggregate(self.timeline.events, "kind")

    @property
    def per_phase(self) -> Dict[str, float]:
        return report.aggregate(self.timeline.events, "phase")

    def utilization(self, worker: Optional[str] = None) -> float:
        """Accelerator-worker utilization (the host and ICI lanes are
        resources, not workers — they don't dilute the denominator).

        The denominator is ``config.n_workers``: a provisioned worker that
        never receives an op is idle capacity and must count, otherwise a
        run that strands workers overstates its utilization."""
        if worker is not None:
            return self.timeline.utilization(worker)
        busy = sum(e.duration for e in self.timeline.events
                   if e.worker.startswith("acc") and e.kind != "idle")
        total = self.timeline.makespan * max(self.config.n_workers, 1)
        return busy / total if total else 0.0


# ---------------------------------------------------------------------------
# shared dependency bookkeeping (computed once per program, reused per run)


@dataclass
class Plan:
    """Schedule-independent structure of a ``Program``.

    ``prepare()`` derives it once; ``run(..., plan=...)`` and the sweep
    layer then reuse it across every config instead of rebuilding the
    ops/consumers/n_waiting dicts per run."""
    ops: Dict[str, CostedOp]
    n_waiting: Dict[str, int]
    consumers: Dict[str, Tuple[str, ...]]
    roots: List[str]
    is_chain: bool
    totals: Dict[str, float] = field(default_factory=dict)


def prepare(program: Program) -> Plan:
    ops = {op.name: op for op in program.ops}
    n_waiting = {op.name: sum(1 for d in op.deps if d in ops)
                 for op in program.ops}
    consumers_l: Dict[str, List[str]] = {}
    for op in program.ops:
        for d in op.deps:
            if d in ops:
                consumers_l.setdefault(d, []).append(op.name)
    roots = [op.name for op in program.ops if n_waiting[op.name] == 0]
    return Plan(ops=ops, n_waiting=n_waiting,
                consumers={k: tuple(v) for k, v in consumers_l.items()},
                roots=roots, is_chain=_is_chain(program, ops),
                totals=program.totals())


def _is_chain(program: Program, ops: Dict[str, CostedOp]) -> bool:
    """True when the program is a pure linear chain the fast path handles:
    op i depends exactly on op i-1, unique names, no affinity pinning."""
    if len(ops) != len(program.ops):
        return False
    prev = None
    for op in program.ops:
        if op.affinity is not None:
            return False
        want = () if prev is None else (prev,)
        if tuple(op.deps) != want:
            return False
        prev = op.name
    return True


# ---------------------------------------------------------------------------
# per-op costs (schedule-independent; hoisted out of the event loop)


def _transfer_base(op: CostedOp, config: EngineConfig,
                   iface: Callable) -> Tuple[float, float, float]:
    """(full seconds, exposed seconds, energy) for this op's staging.

    ``full`` is the interface time at nominal bandwidth; ``exposed`` is
    what the worker actually stalls on — in overlap mode the MXU stream
    hides operand traffic behind the op's dot compute."""
    if op.transfer_s is not None:
        return op.transfer_s, op.transfer_s, config.energy.hbm(
            op.transfer_s * config.hbm_bw)
    if not op.bytes:
        return 0.0, 0.0, 0.0
    t, e = iface(op.bytes, config)
    t /= config.datapath_scale
    exposed = (max(t - op.dot_flops / config.peak_flops, 0.0)
               if config.overlap else t)
    return t, exposed, e


def chain_op_costs(op: CostedOp, config: EngineConfig
                   ) -> Tuple[float, float, float, float]:
    """(host, transfer, compute, collective) seconds ``op`` adds to a pure
    linear chain under ``config`` — the exact per-op terms of the chain
    fast path (every transfer starts alone, so the contention factor is 1
    unless ``hbm_ports`` is fractional).

    Adding the four terms left-to-right per op, in op order, reproduces the
    engine's chain prefix sum bit-for-bit; the serving scheduler
    (``repro.sim.serving``) uses this to advance its simulated clock with
    precisely the costs ``run()`` will charge for the same ops.
    """
    host = config.host_dispatch_s + (
        op.bytes / config.host_bw / config.host_threads
        if config.host_bw else 0.0)
    _, exposed, _ = _transfer_base(op, config, INTERFACES[config.interface])
    if exposed > 0.0 and config.hbm_ports > 0:
        exposed *= max(1.0, 1 / config.hbm_ports)
    comp = (op.duration_s if op.duration_s is not None
            else op.flops / config.peak_flops)
    coll = (op.collective_bytes / config.ici_bw
            if op.collective_bytes > 0.0 else 0.0)
    return host, exposed, comp, coll


# ---------------------------------------------------------------------------
# the executor


def run(program: Program, config: EngineConfig = EngineConfig(), *,
        model_flops: float = 0.0, host_s: Optional[float] = None,
        plan: Optional[Plan] = None, fast: Optional[bool] = None
        ) -> EngineResult:
    """Simulate ``program`` on ``config``; returns every metric of the run.

    ``host_s``: roofline host floor (defaults to ``config.host_floor_s``).
    ``plan``: precomputed ``prepare(program)`` (sweep layer shares it).
    ``fast``: force (True) or forbid (False) the linear-chain prefix-sum
    path; default auto-detects.  Both paths are bit-identical.
    """
    if config.interface not in INTERFACES:
        raise ValueError(f"unknown interface {config.interface!r}; "
                         f"one of {sorted(INTERFACES)}")
    if plan is None:
        plan = prepare(program)
    if not plan.roots and program.ops:
        raise ValueError("dependency cycle in program")
    host_floor = config.host_floor_s if host_s is None else host_s
    if fast is None:
        fast = plan.is_chain
    if (fast and plan.is_chain and program.ops
            and type(config.energy) is EnergyModel):
        out = _run_chain(program, config)
        if out is not None:
            tl, iface_time_total, transfer_energy, makespan, kinds = out
            return _finalize(tl, program, config, plan, iface_time_total,
                             transfer_energy, model_flops, host_floor,
                             makespan=makespan, kinds=kinds)
    tl, iface_time_total, transfer_energy = _run_events(
        program, config, plan)
    return _finalize(tl, program, config, plan, iface_time_total,
                     transfer_energy, model_flops, host_floor)


def _run_events(program: Program, config: EngineConfig,
                plan: Plan) -> Tuple[Timeline, float, float]:
    """General DAG executor: heap ready queue + incremental contention."""
    iface = INTERFACES[config.interface]
    tl = Timeline()
    events = tl.events
    n = max(config.n_workers, 1)
    avail = [0.0] * n
    worker_names = [f"acc{i}" for i in range(n)]
    affinity_worker: Dict[str, int] = {}
    done: Dict[str, float] = {}
    host_free = 0.0
    ici_free = 0.0
    transfer_energy = 0.0
    iface_time_total = 0.0      # full interface seconds charged this run

    ops = plan.ops
    consumers = plan.consumers
    n_waiting = dict(plan.n_waiting)

    # hoisted per-op costs (schedule-independent)
    peak = config.peak_flops
    comp_s = {nm: (op.duration_s if op.duration_s is not None
                   else op.flops / peak) for nm, op in ops.items()}
    xfer_base = {nm: _transfer_base(op, config, iface)
                 for nm, op in ops.items()}
    host_dispatch = config.host_dispatch_s
    host_bw = config.host_bw
    host_threads = config.host_threads

    # active-transfer structure for HBM-port contention: two sorted arrays
    # answer "how many windows are live at t" in O(log k); a heap keyed on
    # window end expires history once no future transfer can start before
    # it (every future start >= min(avail), which only grows), so the
    # structure tracks live concurrency instead of the whole run history.
    # NOTE: contention is sampled once, at the transfer's START INSTANT,
    # and locked in for the window (see module header for the semantics).
    ports = config.hbm_ports
    xfer_starts: List[float] = []
    xfer_ends: List[float] = []
    window_heap: List[Tuple[float, float]] = []     # (end, start)
    compact_at = 64
    # expiry bookkeeping: a future transfer can start no earlier than the
    # avail of the worker it lands on.  While any remaining op is
    # "unrestricted" (no affinity, or an affinity key not yet pinned) it
    # may land on the globally least-loaded worker, so the safe expiry
    # bound is min(avail); once every remaining op is pinned, only the
    # pinned workers' avail matters — idle provisioned workers no longer
    # freeze the bound at 0 and the history stays compactable.
    aff_remaining: Dict[str, int] = {}
    n_unrestricted = 0
    for p_op in program.ops:
        if p_op.affinity is None:
            n_unrestricted += 1
        else:
            aff_remaining[p_op.affinity] = \
                aff_remaining.get(p_op.affinity, 0) + 1
    n_unrestricted += sum(aff_remaining.values())

    def _expiry_bound() -> float:
        if n_unrestricted > 0:
            return min(avail)
        live_workers = set()
        for k, c in aff_remaining.items():
            if c > 0:
                pinned = affinity_worker.get(k)
                if pinned is None:          # outstanding unpinned key:
                    return min(avail)       # it may land anywhere
                live_workers.add(pinned)
        if not live_workers:
            return float("inf")             # no transfer can query again
        return min(avail[w] for w in live_workers)

    # max-heap ready queue keyed on compute time: replicates the legacy
    # per-wave LPT sort exactly — ``seq`` reproduces the stable-sort tie
    # order (insertion order within a wave), and newly readied ops wait in
    # ``next_wave`` until the current wave drains, like the old list swap.
    heap = [(-comp_s[nm], i, nm) for i, nm in enumerate(plan.roots)]
    heapify(heap)
    seq = len(heap)
    next_wave: List[Tuple[float, int, str]] = []
    scheduled = 0

    while heap:
        _, _, nm = heappop(heap)
        op = ops[nm]
        aff = op.affinity
        if aff is not None and aff in affinity_worker:
            w = affinity_worker[aff]
            aff_remaining[aff] -= 1
        else:
            w = min(range(n), key=avail.__getitem__)
            if aff is not None:
                affinity_worker[aff] = w
                # this key's ops are henceforth restricted to worker w
                n_unrestricted -= aff_remaining[aff]
                aff_remaining[aff] -= 1
            else:
                n_unrestricted -= 1
        dep_ready = max((done[d] for d in op.deps if d in done),
                        default=0.0)
        t = avail[w] if avail[w] > dep_ready else dep_ready
        # serial host dispatch (framework time) gates the launch
        host_cost = (host_dispatch
                     + (op.bytes / host_bw / host_threads
                        if host_bw else 0.0))
        if host_cost > 0.0:
            h0 = host_free if host_free > dep_ready else dep_ready
            events.append(Event("host", f"{nm}:dispatch", h0, host_cost,
                                "host", op.phase))
            host_free = h0 + host_cost
            if host_free > t:
                t = host_free
        # staged input transfer, with HBM-port contention
        full, xfer, xe = xfer_base[nm]
        transfer_energy += xe
        if xfer > 0.0:
            if ports <= 0:
                factor = 1.0
            else:
                live = (1 + bisect_right(xfer_starts, t)
                        - bisect_right(xfer_ends, t))
                factor = max(1.0, live / ports)
            xfer *= factor
            events.append(Event(worker_names[w], f"{nm}:xfer", t, xfer,
                                "transfer", op.phase))
            end = t + xfer
            insort(xfer_starts, t)
            insort(xfer_ends, end)
            heappush(window_heap, (end, t))
            if len(window_heap) >= compact_at:
                # expire windows no future transfer can overlap: every
                # future start is >= the expiry bound, and avail only grows
                bound = _expiry_bound()
                while window_heap and window_heap[0][0] <= bound:
                    heappop(window_heap)
                xfer_starts = sorted(s for (_, s) in window_heap)
                xfer_ends = sorted(e for (e, _) in window_heap)
                compact_at = max(64, 2 * len(window_heap))
            iface_time_total += full * factor
            t = end
        else:
            iface_time_total += full
        comp = comp_s[nm]
        events.append(Event(worker_names[w], nm, t, comp, "compute",
                            op.phase))
        t += comp
        avail[w] = t
        # collective traffic serializes on the ICI lane (operand-sum
        # metric, matching the closed-form breakdown; the ring-model
        # wire bytes feed the roofline collective term instead)
        if op.collective_bytes > 0.0:
            c0 = ici_free if ici_free > t else t
            cdur = op.collective_bytes / config.ici_bw
            events.append(Event("ici", f"{nm}:coll", c0, cdur, "collective",
                                op.phase))
            ici_free = c0 + cdur
            t = c0 + cdur
        done[nm] = t
        scheduled += 1
        for cn in consumers.get(nm, ()):
            n_waiting[cn] -= 1
            if n_waiting[cn] == 0:
                next_wave.append((-comp_s[cn], seq, cn))
                seq += 1
        if not heap and next_wave:
            heap = next_wave
            heapify(heap)
            next_wave = []
    if scheduled != len(program.ops):
        raise ValueError("dependency cycle in program")
    return tl, iface_time_total, transfer_energy


# ---------------------------------------------------------------------------
# linear-chain fast path: the whole schedule is one prefix sum


def _run_chain(program: Program,
               config: EngineConfig
               ) -> Optional[Tuple[Timeline, float, float, float,
                                   Dict[str, float]]]:
    """Vectorized executor for pure chains — bit-identical to the event
    loop.  On a chain every op starts exactly when its predecessor's chain
    time ends (worker/host/ICI lanes can never push it later), so the
    schedule is the prefix sum of the interleaved per-op
    (host, transfer, compute, collective) durations, in the exact addition
    order of the loop.  Costs are computed with the same IEEE operations
    as the scalar interface models.  Returns None to fall back when an op
    carries a cost the vectorized model can't mirror (negative/non-finite).
    """
    import numpy as np

    ops = program.ops
    m = len(ops)
    em = config.energy
    peak = config.peak_flops

    flops = np.array([op.flops for op in ops], dtype=np.float64)
    dot = np.array([op.dot_flops for op in ops], dtype=np.float64)
    nb = np.array([op.bytes_in + op.bytes_out for op in ops],
                  dtype=np.float64)
    coll = np.array([op.collective_bytes for op in ops], dtype=np.float64)
    has_dur = np.array([op.duration_s is not None for op in ops], dtype=bool)
    dur = np.array([op.duration_s or 0.0 for op in ops], dtype=np.float64)
    has_tov = np.array([op.transfer_s is not None for op in ops], dtype=bool)
    tov = np.array([op.transfer_s or 0.0 for op in ops], dtype=np.float64)

    with np.errstate(divide="ignore", invalid="ignore"):
        comp = np.where(has_dur, dur, flops / peak)

        # interface time/energy for the bytes path — same formulas, same
        # operation order as core.interfaces / EnergyModel, elementwise
        iface = config.interface
        if iface == "hbm":
            t_if = nb / config.hbm_bw
            e_if = (nb * em.pj_per_byte_hbm) * 1e-12
        elif iface == "ideal":
            t_if = np.zeros(m)
            e_if = np.zeros(m)
        elif iface == "dma":
            from repro.core.interfaces import DMA_LAUNCH_S, FLUSH_PER_BYTE
            n_tr = np.maximum(1.0,
                              np.floor_divide(nb, config.dma_transfer_bytes))
            t_if = (2 * nb / config.hbm_bw + n_tr * DMA_LAUNCH_S
                    + nb * FLUSH_PER_BYTE)
            e_if = ((2 * nb) * em.pj_per_byte_hbm) * 1e-12 \
                + ((nb * 0.05) * em.pj_per_byte_host) * 1e-12
        elif iface == "acp":
            res_frac = np.where(nb < config.vmem_resident_bytes, 1.0, 0.5)
            spill = nb * (1.0 - res_frac)
            t_if = (nb * res_frac) / config.vmem_bw \
                + 2 * spill / config.hbm_bw
            e_if = ((2 * nb * res_frac) * em.pj_per_byte_vmem) * 1e-12 \
                + ((2 * spill) * em.pj_per_byte_hbm) * 1e-12
        else:                               # registered custom interface
            return None
        t_if = t_if / config.datapath_scale
        if config.overlap:
            expo_if = np.maximum(t_if - dot / peak, 0.0)
        else:
            expo_if = t_if

        zero_b = nb == 0.0
        full = np.where(has_tov, tov, np.where(zero_b, 0.0, t_if))
        expo = np.where(has_tov, tov, np.where(zero_b, 0.0, expo_if))
        xe = np.where(has_tov, ((tov * config.hbm_bw) * em.pj_per_byte_hbm)
                      * 1e-12, np.where(zero_b, 0.0, e_if))

        # chain transfers never overlap -> every window sees live == 1
        if config.hbm_ports <= 0:
            factor = 1.0
        else:
            factor = max(1.0, 1 / config.hbm_ports)
        has_x = expo > 0.0
        xfer = np.where(has_x, expo * factor, 0.0)

        if config.host_bw:
            hc = config.host_dispatch_s + (nb / config.host_bw) \
                / config.host_threads
        else:
            hc = np.full(m, config.host_dispatch_s)
    has_h = hc > 0.0
    has_c = coll > 0.0
    cdur = np.where(has_c, coll / config.ici_bw, 0.0)

    flat = np.empty(4 * m, dtype=np.float64)
    flat[0::4] = np.where(has_h, hc, 0.0)
    flat[1::4] = xfer
    flat[2::4] = comp
    flat[3::4] = cdur
    if not np.isfinite(flat).all() or (m and flat.min() < 0.0):
        return None                         # event loop handles the exotic
    # itertools.accumulate guarantees the loop's strict left-to-right float
    # addition order (numpy reductions may re-associate)
    cum = list(accumulate(flat.tolist()))

    # worker labels: timing is worker-independent on a chain, but the
    # argmin assignment (ties -> lowest index) must be replayed for
    # bit-identical event rows
    n = max(config.n_workers, 1)
    if n == 1:
        widx = [0] * m
    else:
        avail = [0.0] * n
        rng = range(n)
        widx = []
        for i in range(m):
            w = min(rng, key=avail.__getitem__)
            avail[w] = cum[4 * i + 2]       # end of this op's compute
            widx.append(w)
    worker_names = [f"acc{i}" for i in range(n)]

    tl = Timeline()
    events = tl.events
    hc_l, xfer_l, comp_l, cdur_l = (hc.tolist(), xfer.tolist(),
                                    comp.tolist(), cdur.tolist())
    hh, hx, hcoll = has_h.tolist(), has_x.tolist(), has_c.tolist()
    for i in range(m):
        op = ops[i]
        b = 4 * i
        wname = worker_names[widx[i]]
        if hh[i]:
            events.append(Event("host", f"{op.name}:dispatch",
                                cum[b - 1] if i else 0.0, hc_l[i], "host",
                                op.phase))
        if hx[i]:
            events.append(Event(wname, f"{op.name}:xfer", cum[b], xfer_l[i],
                                "transfer", op.phase))
        events.append(Event(wname, op.name, cum[b + 1], comp_l[i],
                            "compute", op.phase))
        if hcoll[i]:
            events.append(Event("ici", f"{op.name}:coll", cum[b + 2],
                                cdur_l[i], "collective", op.phase))

    # sequential accumulations (match the loop's += order exactly: within
    # each kind, event order == op order, so per-kind running sums are the
    # same float additions ``report.aggregate`` would perform)
    iface_time_total = 0.0
    for v in np.where(has_x, full * factor, full).tolist():
        iface_time_total += v
    transfer_energy = 0.0
    for v in xe.tolist():
        transfer_energy += v
    kinds: Dict[str, float] = {}
    acc = 0.0
    for v in comp_l:
        acc += v
    kinds["compute"] = acc
    if any(hx):
        acc = 0.0
        for i, v in enumerate(xfer_l):
            if hx[i]:
                acc += v
        kinds["transfer"] = acc
    if any(hh):
        acc = 0.0
        for i, v in enumerate(hc_l):
            if hh[i]:
                acc += v
        kinds["host"] = acc
    if any(hcoll):
        acc = 0.0
        for i, v in enumerate(cdur_l):
            if hcoll[i]:
                acc += v
        kinds["collective"] = acc
    # every event boundary is a prefix-sum entry and the chain is monotone,
    # so the last entry IS max(event.end) — no O(E) rescan needed
    makespan = cum[-1] if cum else 0.0
    return tl, iface_time_total, transfer_energy, makespan, kinds


# ---------------------------------------------------------------------------
# shared result assembly


def _finalize(tl: Timeline, program: Program, config: EngineConfig,
              plan: Plan, iface_time_total: float, transfer_energy: float,
              model_flops: float, host_floor: float, *,
              makespan: Optional[float] = None,
              kinds: Optional[Dict[str, float]] = None) -> EngineResult:
    if makespan is None:
        makespan = tl.makespan
    totals = plan.totals if plan.totals else program.totals()
    if kinds is None:
        bd = report.breakdown_from_events(tl.events, host_floor_s=host_floor)
    else:
        bd = report.Breakdown(
            accelerator_s=kinds.get("compute", 0.0),
            transfer_s=kinds.get("transfer", 0.0),
            host_s=kinds.get("host", 0.0) + host_floor,
            collective_s=kinds.get("collective", 0.0))
    if config.overlap:
        # the Fig-1 transfer phase applies the dot-hiding budget at the
        # aggregate level (like the closed form): memory time beyond the
        # program's total MXU time is exposed.  The timeline keeps the
        # per-op view; per-op exposure can only exceed this (Jensen).
        bd.transfer_s = max(
            iface_time_total - totals["dot_flops"] / config.peak_flops,
            0.0)
    rl = report.roofline_from_totals(
        totals, host_s=host_floor, n_chips=config.n_chips,
        model_flops=model_flops, peak_flops=config.peak_flops,
        hbm_bw=config.hbm_bw, ici_bw=config.ici_bw)
    e_comp = config.energy.compute(totals["flops"])
    e_ici = config.energy.ici(totals["collective_bytes"])
    e_static = config.energy.static(makespan + host_floor, 1)
    energy = {
        "compute_j": e_comp, "hbm_j": transfer_energy, "ici_j": e_ici,
        "static_j": e_static,
        "total_j": e_comp + transfer_energy + e_ici + e_static,
        "total_j_all_chips": (e_comp + transfer_energy + e_ici + e_static)
        * config.n_chips,
    }
    return EngineResult(timeline=tl, program=program, config=config,
                        breakdown=bd, roofline=rl, energy=energy,
                        makespan=makespan)
