"""Event-driven executor: one simulated execution -> every paper metric.

``run(program, config)`` schedules ``CostedOp``s over the devices of an
``SoCTopology`` (``config.topology``; ``None`` means the homogeneous
expansion of the flat fields — ``n_workers`` identical accelerators on one
shared link, bit-identical to the pre-topology engine):

  * every op is placed on a device whose ``kind`` matches the op's
    ``device_class`` (host preprocessing on the CPU device, NN ops on the
    accelerators; a class with no matching device falls back to the
    accelerators) — least-loaded-first within the class;
  * every producer->consumer tensor is staged through the placed device's
    interface model ("hbm" bare round-trip, "dma" software-managed
    staging, "acp" fused/VMEM-resident, "ideal" free) — the Fig 11 study
    is just two runs of the same program;
  * concurrent transfers contend per **link**: active transfers on a link
    beyond its port count share bandwidth (the shared HBM port pool of
    the multi-accelerator studies; independent links don't contend);
  * each dispatch charges serial host/framework time (per-op launch cost
    plus a host-bandwidth tiling term divided over host threads — the
    Fig 15/16 multithreading study);
  * reduction-affinity ops pin to one device queue (Fig 14);
  * collective traffic serializes on the ICI lane.

The result carries the Timeline, the Fig-1 Breakdown, the Roofline terms,
the per-device breakdown and the energy estimate of the *same* run.

Performance.  The core is O(E log E) in the number of ops/events: the
per-wave LPT sort is a max-heap ready queue, and link contention is
answered from incrementally maintained per-link active-transfer
structures (finished windows are heap-expired once no future transfer on
that link can start before their end, so memory stays bounded by the live
concurrency instead of the whole history).  Per-op interface/compute
costs are schedule-independent and are computed once per device cost
signature, outside the loop.  Linear-chain programs (the ``from_hlo``
macro-op shape and token-by-token decode) take a prefix-sum fast path
that reproduces the event loop bit-for-bit whenever the chain resolves to
one device cost signature and one link.  ``prepare()`` lets callers
(``repro.sim.sweep``) share the dependency bookkeeping across many
configs of the same program.

Contention sampling semantics.  ``contention_factor`` is evaluated once,
at a transfer's *start instant*: the factor counts the transfers already
in flight on the same link at that moment and is locked in for the whole
window.  A long transfer that later overlaps newly issued ones is NOT
retroactively slowed — only the newcomers see the congestion.  This
start-instant convention is deliberate: it keeps single-chain programs
exactly equal to the closed-form interface sums (each transfer starts
alone, factor 1), and it mirrors a bandwidth reservation made at issue
time.  Studies that need time-resolved sharing can shrink op granularity
(smaller tiles -> shorter windows) until the sampling error vanishes.
"""
from __future__ import annotations

from bisect import bisect_right, insort
from dataclasses import dataclass, field, replace
from functools import lru_cache
from heapq import heapify, heappop, heappush
from itertools import accumulate
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

from repro.core.energy import DEFAULT_ENERGY, EnergyModel
from repro.core.timeline import Event, Timeline
from repro.sim import backends, hw, report
from repro.sim.hw import Device, Link, SoCTopology
from repro.sim.ir import CostedOp, Program


# ---------------------------------------------------------------------------
# interface models (seconds, joules) for staging ``nbytes`` between ops


def _iface_hbm(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    """Bare HBM traffic at full bandwidth — the roofline memory model."""
    return nbytes / cfg.hbm_bw, cfg.energy.hbm(nbytes)


def _iface_dma(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    from repro.core.interfaces import dma_transfer
    n = max(1, int(nbytes // cfg.dma_transfer_bytes))
    c = dma_transfer(nbytes, n_transfers=n, em=cfg.energy,
                     hbm_bw=cfg.hbm_bw)
    return c.seconds, c.energy_j


def _iface_acp(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    from repro.core.interfaces import acp_transfer
    resident = 1.0 if nbytes < cfg.vmem_resident_bytes else 0.5
    c = acp_transfer(nbytes, resident_fraction=resident, em=cfg.energy,
                     hbm_bw=cfg.hbm_bw, vmem_bw=cfg.vmem_bw)
    return c.seconds, c.energy_j


def _iface_ideal(nbytes: float, cfg: "EngineConfig") -> Tuple[float, float]:
    return 0.0, 0.0


INTERFACES: Dict[str, Callable] = {
    "hbm": _iface_hbm, "dma": _iface_dma, "acp": _iface_acp,
    "ideal": _iface_ideal,
}


@dataclass(frozen=True)
class EngineConfig:
    # flat SoC description; ``topology`` below supersedes ``n_workers`` /
    # ``hbm_ports`` when set (the flat fields remain the inheritance
    # defaults for Device/Link fields left as None)
    n_workers: int = 1
    interface: str = "hbm"            # hbm | dma | acp | ideal
    peak_flops: float = hw.PEAK_FLOPS
    hbm_bw: float = hw.HBM_BW
    vmem_bw: float = hw.VMEM_BW
    ici_bw: float = hw.ICI_BW
    # HBM-port contention: active transfers beyond this many share bandwidth
    # (0 = one port per worker, i.e. no contention; fractional values allow
    # exact translation of the legacy shared_bw_penalty)
    hbm_ports: float = 0
    # host/framework model: serial per-dispatch launch cost + a tiling term
    # (bytes over host_bw) divided across host worker threads
    host_dispatch_s: float = 0.0
    host_bw: float = 0.0              # 0 = no per-byte host cost
    host_threads: int = 1
    host_floor_s: float = 0.0         # per-run framework floor (Fig 1 host)
    # transfer/compute overlap: the MXU double-buffers its operand traffic,
    # so only memory time beyond the dot compute is exposed; the DMA path
    # serializes (SW-managed staging completes before compute starts)
    overlap_transfers: Optional[bool] = None   # None -> interface != "dma"
    # scales the accelerator's local datapath (scratchpad/VMEM port width):
    # a half-size PE array also halves its feed bandwidth (Fig 20 sweep)
    datapath_scale: float = 1.0
    vmem_resident_bytes: float = 32 * 1024 * 1024
    dma_transfer_bytes: float = 64 * 1024
    energy: EnergyModel = DEFAULT_ENERGY
    n_chips: int = 1
    # heterogeneous SoC: per-device/per-link model (None = the homogeneous
    # expansion of the fields above; see hw.SoCTopology)
    topology: Optional[SoCTopology] = None
    # cluster fabric: per-hop rates of the three canonical tiers (ops with
    # ``tier`` set are priced ``hops * lat + bytes / bw`` on their lane).
    # ``ici_lat_s`` defaults to 0 so the legacy single-lane collective
    # charge is a zero-latency single-tier fabric, bit for bit.  ``fabric``
    # carries the tier structure; explicit per-tier rates on it override
    # these flat fields (same inheritance convention as Device/Link).
    ici_lat_s: float = hw.ICI_LAT_S
    node_bw: float = hw.NODE_BW
    node_lat_s: float = hw.NODE_LAT_S
    inter_bw: float = hw.INTER_BW
    inter_lat_s: float = hw.INTER_LAT_S
    fabric: Optional[hw.Fabric] = None
    # per-op compute-cost backend (repro.sim.backends): None = the native
    # roofline math (every hot path keeps its original inline expression,
    # so the default is bit-identical to the pre-backend engine); a
    # CostBackend instance or registered name ("systolic") prices compute
    # through ``backend.op_time(op, effective_config)``.  Backends are
    # frozen dataclasses, so configs stay hashable/cacheable.
    cost_backend: Optional[object] = None

    @property
    def overlap(self) -> bool:
        if self.overlap_transfers is None:
            return self.interface != "dma"
        return self.overlap_transfers

    def resolved_topology(self) -> SoCTopology:
        """The topology this config simulates: ``topology`` as given, or
        the homogeneous expansion of the flat fields."""
        if self.topology is not None:
            return self.topology
        return SoCTopology.homogeneous(self.n_workers)

    def resolved_backend(self) -> "backends.CostBackend":
        """The compute-cost backend instance this config prices with
        (``None`` resolves to the shared roofline backend)."""
        return backends.get_backend(self.cost_backend)


# ---------------------------------------------------------------------------
# device/link resolution (None fields inherit the flat config)


def _device_config(config: EngineConfig, topo: SoCTopology,
                   dev: Device) -> EngineConfig:
    """Effective cost parameters for ``dev``: every ``None`` field falls
    back to the flat config (device hbm_bw > link bandwidth > config).
    Returns ``config`` itself when nothing differs, so the homogeneous
    expansion charges literally the same floats as the flat engine."""
    link = topo.link_for(dev)
    iface = dev.interface if dev.interface is not None else config.interface
    peak = dev.peak_flops if dev.peak_flops is not None \
        else config.peak_flops
    scale = dev.datapath_scale if dev.datapath_scale is not None \
        else config.datapath_scale
    bw = dev.hbm_bw if dev.hbm_bw is not None else (
        link.bandwidth if link.bandwidth is not None else config.hbm_bw)
    vmem = dev.vmem_bw if dev.vmem_bw is not None else config.vmem_bw
    cb = dev.cost_backend if dev.cost_backend is not None \
        else config.cost_backend
    if (iface == config.interface and peak == config.peak_flops
            and scale == config.datapath_scale and bw == config.hbm_bw
            and vmem == config.vmem_bw and cb == config.cost_backend):
        return config
    return replace(config, interface=iface, peak_flops=peak,
                   datapath_scale=scale, hbm_bw=bw, vmem_bw=vmem,
                   cost_backend=cb)


def _link_ports(config: EngineConfig, link: Link) -> float:
    return link.ports if link.ports is not None else config.hbm_ports


class _Resolved(NamedTuple):
    """Schedule-independent device/link resolution of one config: worker
    names, per-device cost-signature indices, the signature configs, and
    the link partition.  A pure function of the (frozen) config, so it is
    memoized — benchmark loops re-running one config skip the rebuild."""
    worker_names: Tuple[str, ...]
    dev_sig: Tuple[int, ...]
    sig_cfgs: Tuple[EngineConfig, ...]
    link_of_dev: Tuple[int, ...]
    ports_l: Tuple[float, ...]
    devs_on_link: Tuple[Tuple[int, ...], ...]


def _resolve_build(config: EngineConfig, topo: SoCTopology) -> _Resolved:
    devices = topo.devices
    sig_cfgs: List[EngineConfig] = []
    sig_key: Dict[tuple, int] = {}
    dev_sig: List[int] = []
    for d in devices:
        eff = _device_config(config, topo, d)
        key = (eff.interface, eff.peak_flops, eff.datapath_scale,
               eff.hbm_bw, eff.vmem_bw, eff.cost_backend)
        si = sig_key.get(key)
        if si is None:
            si = sig_key[key] = len(sig_cfgs)
            sig_cfgs.append(eff)
        dev_sig.append(si)
    link_objs: List[Link] = []
    link_idx: Dict[str, int] = {}
    link_of_dev: List[int] = []
    for d in devices:
        l = topo.link_for(d)
        li = link_idx.get(l.name)
        if li is None:
            li = link_idx[l.name] = len(link_objs)
            link_objs.append(l)
        link_of_dev.append(li)
    n = len(devices)
    return _Resolved(
        worker_names=tuple(d.name for d in devices),
        dev_sig=tuple(dev_sig),
        sig_cfgs=tuple(sig_cfgs),
        link_of_dev=tuple(link_of_dev),
        ports_l=tuple(_link_ports(config, l) for l in link_objs),
        devs_on_link=tuple(
            tuple(w for w in range(n) if link_of_dev[w] == li)
            for li in range(len(link_objs))))


@lru_cache(maxsize=256)
def _resolve_cached(config: EngineConfig) -> _Resolved:
    return _resolve_build(config, config.resolved_topology())


def _resolve(config: EngineConfig, topo: SoCTopology) -> _Resolved:
    try:
        return _resolve_cached(config)
    except TypeError:       # unhashable field (e.g. a custom EnergyModel)
        return _resolve_build(config, topo)


@lru_cache(maxsize=1024)
def _cand_cached(topo: SoCTopology, device_class: str) -> Tuple[int, ...]:
    return topo.candidate_indices(device_class)


def _ref_accel_config(config: EngineConfig,
                      topo: SoCTopology) -> EngineConfig:
    """The aggregate-reporting device: the first accelerator (else the
    first device).  The Fig-1 dot-hiding budget and the closed-form
    roofline terms are evaluated with its parameters."""
    for d in topo.devices:
        if d.kind == "accel":
            return _device_config(config, topo, d)
    return _device_config(config, topo, topo.devices[0])


def _class_params(config: EngineConfig, device_class: str
                  ) -> Tuple[EngineConfig, float]:
    """(effective config, link ports) of ``device_class``'s reference
    device — what ``chain_op_costs`` charges an op of that class."""
    if config.topology is None:
        return config, config.hbm_ports
    try:
        return _class_params_cached(config, device_class)
    except TypeError:       # unhashable field (e.g. a custom EnergyModel)
        return _class_params_build(config, device_class)


def _class_params_build(config: EngineConfig, device_class: str
                        ) -> Tuple[EngineConfig, float]:
    topo = config.topology
    dev = topo.devices[topo.candidate_indices(device_class)[0]]
    return (_device_config(config, topo, dev),
            _link_ports(config, topo.link_for(dev)))


@lru_cache(maxsize=512)
def _class_params_cached(config: EngineConfig, device_class: str
                         ) -> Tuple[EngineConfig, float]:
    return _class_params_build(config, device_class)


def uniform_class_params(config: EngineConfig, device_class: str) -> bool:
    """True when every candidate device of ``device_class`` shares one
    cost signature and link — the precondition for ``chain_op_costs`` to
    price an op exactly as the engine will charge it regardless of which
    device of the class the op lands on (``simulate_serving`` requires
    this of the accelerator pool)."""
    topo = config.resolved_topology()
    sigs = set()
    for i in topo.candidate_indices(device_class):
        d = topo.devices[i]
        e = _device_config(config, topo, d)
        sigs.add((e.interface, e.peak_flops, e.datapath_scale, e.hbm_bw,
                  e.vmem_bw, e.cost_backend, topo.link_for(d).name))
    return len(sigs) <= 1


@dataclass
class EngineResult:
    timeline: Timeline
    program: Program
    config: EngineConfig
    breakdown: report.Breakdown
    roofline: report.Roofline
    energy: Dict[str, float]
    makespan: float

    @property
    def per_kind(self) -> Dict[str, float]:
        return report.aggregate(self.timeline.events, "kind")

    @property
    def per_phase(self) -> Dict[str, float]:
        return report.aggregate(self.timeline.events, "phase")

    @property
    def per_device(self) -> Dict[str, Dict[str, float]]:
        """kind -> seconds per device (host and ICI lanes included as
        pseudo-devices) — the per-device view of the breakdown."""
        return report.per_device(self.timeline.events)

    def device_breakdowns(self) -> Dict[str, report.Breakdown]:
        """Fig-1 style Breakdown per device (the run-level host floor is
        not attributed to any single device)."""
        return report.device_breakdowns(self.timeline.events)

    def device_utilization(self) -> Dict[str, float]:
        """Busy fraction of the makespan per topology device (provisioned
        devices that never ran an op report 0.0)."""
        mk = self.timeline.makespan
        busy: Dict[str, float] = {}
        for e in self.timeline.events:
            if e.kind != "idle":
                busy[e.worker] = busy.get(e.worker, 0.0) + e.duration
        return {d.name: (busy.get(d.name, 0.0) / mk if mk else 0.0)
                for d in self.config.resolved_topology().devices}

    def utilization(self, worker: Optional[str] = None) -> float:
        """Accelerator-device utilization (the host, ICI lanes and the
        CPU/DSP frontend devices are resources, not accelerators — they
        don't dilute the denominator).

        The denominator is the topology's accelerator count: a
        provisioned accelerator that never receives an op is idle
        capacity and must count, otherwise a run that strands devices
        overstates its utilization."""
        if worker is not None:
            return self.timeline.utilization(worker)
        topo = self.config.resolved_topology()
        accel = {d.name for d in topo.devices if d.kind == "accel"}
        if not accel:
            accel = {d.name for d in topo.devices}
        busy = sum(e.duration for e in self.timeline.events
                   if e.worker in accel and e.kind != "idle")
        total = self.timeline.makespan * len(accel)
        return busy / total if total else 0.0


# ---------------------------------------------------------------------------
# shared dependency bookkeeping (computed once per program, reused per run)


@dataclass
class CompiledPlan:
    """Typed-array (structure-of-arrays) compilation of a ``Plan``.

    Everything schedule- and config-independent the event loop needs,
    resolved to integer indices and flat Python/numpy arrays once per
    program: dependency/consumer index lists, per-op static columns,
    precomputed event-name strings, and the **linear-run tables** of the
    fusion layer — ``run_next[i] = j`` marks a contractible hop link
    (op ``i`` is a fabric hop whose sole consumer ``j`` is a fabric hop
    depending only on ``i``, both LPT-neutral), so a ready wave made
    entirely of run heads can be advanced many rounds at a time without
    touching the heap (see ``_run_events_fused``).  Built lazily by
    ``Plan.compiled()`` and reused across every config of a sweep."""
    names: List[str]
    op_list: List[CostedOp]
    deps_idx: List[Tuple[int, ...]]
    consumers_idx: List[Tuple[int, ...]]
    n_waiting0: List[int]
    roots_idx: List[int]
    is_tier: List[bool]
    lane_code: List[int]            # -1 when the op never touches a lane
    lane_names: List[str]
    phase_l: List[str]
    affinity_l: List[Optional[str]]
    dclass_l: List[str]
    coll_l: List[float]
    run_next: List[int]             # -1 = not a contractible link
    run_len: List[int]
    n_run_interior: int
    any_tier: bool
    # ops that need the compute/transfer/host price tables: every
    # non-tier op, plus any hop op with an explicit flops/duration (its
    # heap priority).  Plain hops (the overwhelming bulk of cluster
    # programs) price as exact zeros, so the per-config hoist only
    # touches the priced subset and numpy-scatters into full columns.
    priced_idx: object              # np.int64 indices into op_list
    # per-tier (np indices, hops, collective_bytes) for vectorized cdur
    tier_groups: Dict[str, tuple]
    aff_counts: Dict[str, int]
    n_unrestricted0: int
    _hoist: Optional[object] = field(default=None, repr=False)
    _evnames: Optional[tuple] = field(default=None, repr=False)

    def event_names(self) -> tuple:
        """Precompiled ``:coll``/``:dispatch``/``:xfer`` event-name
        columns (one string concat per op per run otherwise)."""
        ev = self._evnames
        if ev is None:
            nm = self.names
            ev = self._evnames = ([s + ":coll" for s in nm],
                                  [s + ":dispatch" for s in nm],
                                  [s + ":xfer" for s in nm])
        return ev

    def hoist_arrays(self):
        """Columnar cost inputs of the **priced** ops (``costmodel.
        OpArrays`` without the tier gating — the fused loop prices hops
        separately), built on first use and shared by every config's
        vectorized cost hoist."""
        a = self._hoist
        if a is None:
            import numpy as np

            from repro.sim import costmodel
            ops = [self.op_list[i] for i in self.priced_idx.tolist()]
            m = len(ops)
            a = costmodel.OpArrays(
                m=m,
                flops=np.fromiter((op.flops for op in ops),
                                  np.float64, m),
                dot=np.fromiter((op.dot_flops for op in ops),
                                np.float64, m),
                nb=np.fromiter((op.bytes_in + op.bytes_out for op in ops),
                               np.float64, m),
                coll=np.zeros(m, dtype=np.float64),
                has_dur=np.fromiter((op.duration_s is not None
                                     for op in ops), bool, m),
                dur=np.fromiter((op.duration_s or 0.0 for op in ops),
                                np.float64, m),
                has_tov=np.fromiter((op.transfer_s is not None
                                     for op in ops), bool, m),
                tov=np.fromiter((op.transfer_s or 0.0 for op in ops),
                                np.float64, m))
            self._hoist = a
        return a


@dataclass
class Plan:
    """Schedule-independent structure of a ``Program``.

    ``prepare()`` derives it once; ``run(..., plan=...)`` and the sweep
    layer then reuse it across every config instead of rebuilding the
    ops/consumers/n_waiting dicts per run.  ``compiled()`` lazily lowers
    it to the typed-array form the fused event core executes."""
    ops: Dict[str, CostedOp]
    roots: List[str]
    is_chain: bool
    totals: Dict[str, float] = field(default_factory=dict)
    _compiled: Optional[CompiledPlan] = field(default=None, repr=False,
                                              compare=False)
    # name-keyed dependency maps, built lazily: only the legacy dict
    # event loop walks them — the fused core uses the integer-indexed
    # ``CompiledPlan`` columns instead
    _n_waiting: Optional[Dict[str, int]] = field(default=None, repr=False,
                                                 compare=False)
    _consumers: Optional[Dict[str, Tuple[str, ...]]] = field(
        default=None, repr=False, compare=False)

    def _dep_maps(self) -> None:
        ops = self.ops
        n_waiting: Dict[str, int] = {}
        consumers_l: Dict[str, List[str]] = {}
        for nm, op in ops.items():
            nw = 0
            for d in op.deps:
                if d in ops:
                    nw += 1
                    lst = consumers_l.get(d)
                    if lst is None:
                        consumers_l[d] = [nm]
                    else:
                        lst.append(nm)
            n_waiting[nm] = nw
        self._n_waiting = n_waiting
        self._consumers = {k: tuple(v) for k, v in consumers_l.items()}

    @property
    def n_waiting(self) -> Dict[str, int]:
        if self._n_waiting is None:
            self._dep_maps()
        return self._n_waiting

    @property
    def consumers(self) -> Dict[str, Tuple[str, ...]]:
        if self._consumers is None:
            self._dep_maps()
        return self._consumers

    def compiled(self) -> CompiledPlan:
        cp = self._compiled
        if cp is None:
            cp = self._compiled = _compile_plan(self)
        return cp


def prepare(program: Program) -> Plan:
    ops: Dict[str, CostedOp] = {}
    for op in program.ops:
        ops[op.name] = op
    # a root has no dep that resolves in-program; the full name-keyed
    # dependency maps are built lazily on the Plan (dict-loop only)
    roots = []
    for op in program.ops:
        for d in op.deps:
            if d in ops:
                break
        else:
            roots.append(op.name)
    return Plan(ops=ops, roots=roots, is_chain=_is_chain(program, ops),
                totals=program.totals())


def _compile_plan(plan: Plan) -> CompiledPlan:
    import numpy as np

    names = list(plan.ops)
    op_list = list(plan.ops.values())
    n = len(op_list)
    index = dict(zip(names, range(n)))
    ig = index.get
    empty = ()
    # pass 1: dependency + consumer index lists in one sweep (single-dep
    # ops — the overwhelmingly common case — take the scalar fast path)
    deps_idx: List[Tuple[int, ...]] = [empty] * n
    cons_lists: List[Optional[List[int]]] = [None] * n
    for i, op in enumerate(op_list):
        ds = op.deps
        if not ds:
            continue
        if len(ds) == 1:
            j = ig(ds[0])
            if j is None:
                continue
            deps_idx[i] = (j,)
            lst = cons_lists[j]
            if lst is None:
                cons_lists[j] = [i]
            else:
                lst.append(i)
        else:
            t = tuple(j for j in map(ig, ds) if j is not None)
            deps_idx[i] = t
            for j in t:
                lst = cons_lists[j]
                if lst is None:
                    cons_lists[j] = [i]
                else:
                    lst.append(i)
    consumers_idx: List[Tuple[int, ...]] = [
        lst if lst is not None else empty for lst in cons_lists]
    n_waiting0 = [len(ds) for ds in deps_idx]
    roots_idx = [index[nm] for nm in plan.roots]

    tier_l = [op.tier for op in op_list]
    is_tier = [t is not None for t in tier_l]
    any_tier = True in is_tier
    coll_l = [op.collective_bytes for op in op_list]
    affinity_l = [op.affinity for op in op_list]

    # pass 2: per-op static columns (tier groups / lanes / priced subset /
    # affinity counts) fused with the linear-run link detection — a link
    # i -> j is contractible when finishing i readies exactly j (sole
    # consumer, j's only in-program dep) and both ends are LPT-neutral
    # fabric hops (flops == 0, no duration override: their heap priority
    # is exactly 0.0 under every config, so a wave of run heads drains in
    # pure seq — i.e. round-robin — order)
    lane_code = [-1] * n
    lane_names: List[str] = []
    lane_idx: Dict[str, int] = {}
    tier_groups_l: Dict[str, tuple] = {}
    priced: List[int] = []
    priced_append = priced.append
    aff_counts: Dict[str, int] = {}
    for a in affinity_l:
        if a is not None:
            aff_counts[a] = aff_counts.get(a, 0) + 1
    run_next = [-1] * n
    run_len = [1] * n
    has_prev = [False] * n
    n_run_interior = 0
    for i, op in enumerate(op_list):
        t = tier_l[i]
        if t is not None:
            g = tier_groups_l.get(t)
            if g is None:
                g = tier_groups_l[t] = ([], [], [])
            g[0].append(i)
            g[1].append(op.hops)
            g[2].append(coll_l[i])
            if op.flops != 0.0 or op.duration_s is not None:
                priced_append(i)
            else:
                cons = consumers_idx[i]
                if len(cons) == 1:
                    j = cons[0]
                    oj = op_list[j]
                    if (tier_l[j] is not None and oj.flops == 0.0
                            and oj.duration_s is None
                            and len(deps_idx[j]) == 1):
                        run_next[i] = j
                        has_prev[j] = True
        else:
            priced_append(i)
            if coll_l[i] <= 0.0:
                continue
        lane = op.lane
        lc = lane_idx.get(lane)
        if lc is None:
            lc = lane_idx[lane] = len(lane_names)
            lane_names.append(lane)
        lane_code[i] = lc
    if any_tier:
        for i in range(n):
            if has_prev[i] or run_next[i] < 0:
                continue
            chain = [i]
            j = run_next[i]
            while j >= 0:
                chain.append(j)
                j = run_next[j]
            L = len(chain)
            n_run_interior += L - 1
            for k, ci in enumerate(chain):
                run_len[ci] = L - k
    tier_groups = {
        t: (np.array(idxs, dtype=np.int64),
            np.array(hops, dtype=np.float64),
            np.array(cb, dtype=np.float64))
        for t, (idxs, hops, cb) in tier_groups_l.items()}
    return CompiledPlan(
        names=names, op_list=op_list, deps_idx=deps_idx,
        consumers_idx=consumers_idx, n_waiting0=n_waiting0,
        roots_idx=roots_idx, is_tier=is_tier, lane_code=lane_code,
        lane_names=lane_names, phase_l=[op.phase for op in op_list],
        affinity_l=affinity_l,
        dclass_l=[op.device_class for op in op_list], coll_l=coll_l,
        run_next=run_next, run_len=run_len,
        n_run_interior=n_run_interior, any_tier=any_tier,
        priced_idx=np.array(priced, dtype=np.int64),
        tier_groups=tier_groups, aff_counts=aff_counts,
        n_unrestricted0=n)


def fusion_resolvable(plan: Plan, max_segments: int = 512) -> bool:
    """True when linear-run fusion contracts ``plan`` into a small
    segment graph: the program is a DAG with at least one contractible
    hop run, and the surviving inter-segment structure (ops minus run
    interiors) stays under ``max_segments`` events.  For such programs
    ``sweep.batched`` prices the grid with the exact fused engine —
    the DAG relaxation bracket collapses to zero width."""
    cp = plan.compiled()
    if plan.is_chain or cp.n_run_interior == 0:
        return False
    return len(cp.op_list) - cp.n_run_interior <= max_segments


def _is_chain(program: Program, ops: Dict[str, CostedOp]) -> bool:
    """True when the program is a pure linear chain the fast path handles:
    op i depends exactly on op i-1, unique names, no affinity pinning."""
    if len(ops) != len(program.ops):
        return False
    prev = None
    for op in program.ops:
        if op.affinity is not None:
            return False
        want = () if prev is None else (prev,)
        if tuple(op.deps) != want:
            return False
        prev = op.name
    return True


# ---------------------------------------------------------------------------
# per-op costs (schedule-independent; hoisted out of the event loop)


def _transfer_base(op: CostedOp, config: EngineConfig,
                   iface: Callable) -> Tuple[float, float, float]:
    """(full seconds, exposed seconds, energy) for this op's staging.

    ``full`` is the interface time at nominal bandwidth; ``exposed`` is
    what the worker actually stalls on — in overlap mode the MXU stream
    hides operand traffic behind the op's dot compute."""
    if op.transfer_s is not None:
        return op.transfer_s, op.transfer_s, config.energy.hbm(
            op.transfer_s * config.hbm_bw)
    if not op.bytes:
        return 0.0, 0.0, 0.0
    t, e = iface(op.bytes, config)
    t /= config.datapath_scale
    exposed = (max(t - op.dot_flops / config.peak_flops, 0.0)
               if config.overlap else t)
    return t, exposed, e


def chain_op_costs(op: CostedOp, config: EngineConfig
                   ) -> Tuple[float, float, float, float]:
    """(host, transfer, compute, collective) seconds ``op`` adds to a pure
    linear chain under ``config`` — the exact per-op terms of the chain
    fast path (every transfer starts alone, so the contention factor is 1
    unless the op's link has fractional ports).

    Device-aware: the transfer/compute terms are charged at the
    parameters of the op's ``device_class`` reference device in
    ``config.topology`` (flat configs resolve to the config itself).
    Adding the four terms left-to-right per op, in op order, reproduces
    the engine's chain prefix sum bit-for-bit; the serving scheduler
    (``repro.sim.serving``) uses this to advance its simulated clock with
    precisely the costs ``run()`` will charge for the same ops.
    """
    if op.tier is not None:
        # fabric hop: lane-only occupancy — no placement, host dispatch,
        # transfer or compute
        lat, bw = hw.resolve_tier_params(config, op.tier)
        return 0.0, 0.0, 0.0, op.hops * lat + op.collective_bytes / bw
    eff, ports = _class_params(config, op.device_class)
    host = config.host_dispatch_s + (
        op.bytes / config.host_bw / config.host_threads
        if config.host_bw else 0.0)
    _, exposed, _ = _transfer_base(op, eff, INTERFACES[eff.interface])
    if exposed > 0.0 and ports > 0:
        exposed *= max(1.0, 1 / ports)
    if eff.cost_backend is None:
        comp = (op.duration_s if op.duration_s is not None
                else op.flops / eff.peak_flops)
    else:
        comp = backends.get_backend(eff.cost_backend).op_time(op, eff)
    coll = (op.collective_bytes / config.ici_bw
            if op.collective_bytes > 0.0 else 0.0)
    return host, exposed, comp, coll


# ---------------------------------------------------------------------------
# the executor


def run(program: Program, config: Optional[EngineConfig] = None, *,
        model_flops: float = 0.0, host_s: Optional[float] = None,
        plan: Optional[Plan] = None, fast: Optional[bool] = None,
        fuse: Optional[bool] = None) -> EngineResult:
    """Simulate ``program`` on ``config``; returns every metric of the run.

    ``config``: ``None`` means a fresh default ``EngineConfig()`` (a
    ``None`` sentinel, so no module-level instance is shared between
    callers).
    ``host_s``: roofline host floor (defaults to ``config.host_floor_s``).
    ``plan``: precomputed ``prepare(program)`` (sweep layer shares it).
    ``fast``: force (True) or forbid (False) the linear-chain prefix-sum
    path; default auto-detects.  Both paths are bit-identical.
    ``fuse``: force (False) the legacy dict-based event loop instead of
    the compiled typed-array core with linear-run fusion; the default
    (None/True) uses the compiled core.  Both are bit-identical — the
    dict loop is kept as the anchor the fused core is asserted against.
    """
    if config is None:
        config = EngineConfig()
    if config.interface not in INTERFACES:
        raise ValueError(f"unknown interface {config.interface!r}; "
                         f"one of {sorted(INTERFACES)}")
    topo = config.resolved_topology()
    if config.topology is not None:
        for d in topo.devices:
            if d.interface is not None and d.interface not in INTERFACES:
                raise ValueError(
                    f"device {d.name!r}: unknown interface "
                    f"{d.interface!r}; one of {sorted(INTERFACES)}")
    if plan is None:
        plan = prepare(program)
    if not plan.roots and program.ops:
        raise ValueError("dependency cycle in program")
    host_floor = config.host_floor_s if host_s is None else host_s
    if fast is None:
        fast = plan.is_chain
    if (fast and plan.is_chain and program.ops
            and type(config.energy) is EnergyModel):
        out = _run_chain(program, config, topo)
        if out is not None:
            tl, iface_time_total, transfer_energy, makespan, kinds = out
            return _finalize(tl, program, config, topo, plan,
                             iface_time_total, transfer_energy, model_flops,
                             host_floor, makespan=makespan, kinds=kinds)
    if fuse is None:
        # the compiled core carries ~50us of per-run setup (local array
        # binds, column views); below a few dozen ops the dict loop wins.
        # Both paths are bit-identical, so this is purely a perf choice.
        fuse = len(program.ops) >= 32
    if fuse:
        tl, iface_time_total, transfer_energy = _run_events_fused(
            program, config, plan, topo)
    else:
        tl, iface_time_total, transfer_energy = _run_events(
            program, config, plan, topo)
    return _finalize(tl, program, config, topo, plan, iface_time_total,
                     transfer_energy, model_flops, host_floor)


def _run_events(program: Program, config: EngineConfig, plan: Plan,
                topo: SoCTopology) -> Tuple[Timeline, float, float]:
    """General DAG executor: heap ready queue, per-device placement,
    per-link incremental contention."""
    tl = Timeline()
    events = tl.events
    n = len(topo.devices)
    avail = [0.0] * n
    affinity_worker: Dict[str, int] = {}
    done: Dict[str, float] = {}
    host_free = 0.0
    # serial collective lanes: the legacy single ICI lane generalizes to
    # one lane per contended fabric link set (lane "ici" = the old lane,
    # same floats); fabric-tier hop ops occupy only their lane
    lane_free: Dict[str, float] = {}
    transfer_energy = 0.0
    iface_time_total = 0.0      # full interface seconds charged this run

    ops = plan.ops
    consumers = plan.consumers
    n_waiting = dict(plan.n_waiting)

    # per-tier (latency, bandwidth) for fabric hop ops, resolved once
    tier_rates: Dict[str, Tuple[float, float]] = {}
    for p_op in program.ops:
        if p_op.tier is not None and p_op.tier not in tier_rates:
            tier_rates[p_op.tier] = hw.resolve_tier_params(config, p_op.tier)

    # per-device cost signatures + link partition (memoized per config;
    # the homogeneous expansion has exactly one signature: the flat
    # config itself, and one shared link)
    worker_names, dev_sig, sig_cfgs, link_of_dev, ports_l, devs_on_link \
        = _resolve(config, topo)
    nlinks = len(ports_l)

    # placement classes -> candidate device indices (least-loaded within)
    cand: Dict[str, Tuple[int, ...]] = {}
    for p_op in program.ops:
        c = p_op.device_class
        if c not in cand:
            cand[c] = _cand_cached(topo, c)
    ref_sig = {c: dev_sig[idxs[0]] for c, idxs in cand.items()}

    # hoisted per-op costs (schedule-independent), one table per
    # signature.  The single-signature case (every homogeneous run, and
    # any topology whose devices share one cost profile) keeps the flat
    # engine's two dict comprehensions; the general case fills each
    # signature's table only with the ops that can actually land on a
    # device of that signature — an op reaches its own class's candidate
    # devices, plus (when an affinity key is shared across classes) the
    # devices the key's other classes can pin it to.
    if len(sig_cfgs) == 1:
        eff0 = sig_cfgs[0]
        iface0 = INTERFACES[eff0.interface]
        peak0 = eff0.peak_flops
        if eff0.cost_backend is None:
            comp_sig: List[Optional[Dict[str, float]]] = [
                {nm: (op.duration_s if op.duration_s is not None
                      else op.flops / peak0) for nm, op in ops.items()}]
        else:
            bk0 = backends.get_backend(eff0.cost_backend)
            comp_sig = [{nm: bk0.op_time(op, eff0)
                         for nm, op in ops.items()}]
        xfer_sig: List[Optional[Dict[str, tuple]]] = [
            {nm: _transfer_base(op, eff0, iface0)
             for nm, op in ops.items()}]
    else:
        class_sigs = {c: frozenset(dev_sig[w] for w in idxs)
                      for c, idxs in cand.items()}
        aff_classes: Dict[str, set] = {}
        for p_op in program.ops:
            if p_op.affinity is not None:
                aff_classes.setdefault(p_op.affinity, set()).add(
                    p_op.device_class)
        comp_sig = [None] * len(sig_cfgs)
        xfer_sig = [None] * len(sig_cfgs)
        sig_iface = [INTERFACES[c.interface] for c in sig_cfgs]
        sig_peak = [c.peak_flops for c in sig_cfgs]
        sig_bk = [None if c.cost_backend is None
                  else backends.get_backend(c.cost_backend)
                  for c in sig_cfgs]
        for nm, op in ops.items():
            op_sigs = class_sigs[op.device_class]
            if (op.affinity is not None
                    and len(aff_classes[op.affinity]) > 1):
                op_sigs = frozenset().union(
                    *(class_sigs[c] for c in aff_classes[op.affinity]))
            dur = op.duration_s
            for si in op_sigs:
                if comp_sig[si] is None:
                    comp_sig[si] = {}
                    xfer_sig[si] = {}
                comp_sig[si][nm] = (dur if dur is not None
                                    else op.flops / sig_peak[si]) \
                    if sig_bk[si] is None \
                    else sig_bk[si].op_time(op, sig_cfgs[si])
                xfer_sig[si][nm] = _transfer_base(op, sig_cfgs[si],
                                                  sig_iface[si])
    host_dispatch = config.host_dispatch_s
    host_bw = config.host_bw
    host_threads = config.host_threads

    # per-link active-transfer structure for port contention: two sorted
    # arrays answer "how many windows are live at t" in O(log k); a heap
    # keyed on window end expires history once no future transfer on the
    # link can start before it (every future start >= the expiry bound of
    # the link's devices, which only grows), so each structure tracks live
    # concurrency instead of the whole run history.
    # NOTE: contention is sampled once, at the transfer's START INSTANT,
    # and locked in for the window (see module header for the semantics).
    xfer_starts: List[List[float]] = [[] for _ in range(nlinks)]
    xfer_ends: List[List[float]] = [[] for _ in range(nlinks)]
    window_heap: List[List[Tuple[float, float]]] = [[] for _ in
                                                    range(nlinks)]
    compact_at = [64] * nlinks
    # expiry bookkeeping: a future transfer can start no earlier than the
    # avail of the device it lands on.  While any remaining op is
    # "unrestricted" (no affinity, or an affinity key not yet pinned) it
    # may land on the least-loaded device of its class, so the safe expiry
    # bound for a link is min(avail) over the link's devices; once every
    # remaining op is pinned, only the pinned devices' avail matters —
    # idle provisioned devices no longer freeze the bound at 0 and the
    # history stays compactable.
    aff_remaining: Dict[str, int] = {}
    n_unrestricted = 0
    for p_op in program.ops:
        if p_op.affinity is None:
            n_unrestricted += 1
        else:
            aff_remaining[p_op.affinity] = \
                aff_remaining.get(p_op.affinity, 0) + 1
    n_unrestricted += sum(aff_remaining.values())

    def _expiry_bound(li: int) -> float:
        dl = devs_on_link[li]
        if n_unrestricted > 0:
            return min(avail[w] for w in dl)
        live_workers = set()
        for k, c in aff_remaining.items():
            if c > 0:
                pinned = affinity_worker.get(k)
                if pinned is None:          # outstanding unpinned key:
                    return min(avail[w] for w in dl)   # may land anywhere
                if link_of_dev[pinned] == li:
                    live_workers.add(pinned)
        if not live_workers:
            return float("inf")             # no transfer can query again
        return min(avail[w] for w in live_workers)

    # heap priority: compute time at the op's class reference device
    # (schedule-independent; exact LPT on uniform classes) — a bare
    # table lookup when there is only one signature
    if len(sig_cfgs) == 1:
        _prio = comp_sig[0].__getitem__
    else:
        def _prio(nm: str) -> float:
            return comp_sig[ref_sig[ops[nm].device_class]][nm]

    # max-heap ready queue keyed on compute time: replicates the legacy
    # per-wave LPT sort exactly — ``seq`` reproduces the stable-sort tie
    # order (insertion order within a wave), and newly readied ops wait in
    # ``next_wave`` until the current wave drains, like the old list swap.
    heap = [(-_prio(nm), i, nm) for i, nm in enumerate(plan.roots)]
    heapify(heap)
    seq = len(heap)
    next_wave: List[Tuple[float, int, str]] = []
    scheduled = 0

    while heap:
        _, _, nm = heappop(heap)
        op = ops[nm]
        if op.tier is not None:
            # fabric hop: occupies only its lane — no worker placement,
            # host dispatch, transfer or compute
            dep_ready = max((done[d] for d in op.deps if d in done),
                            default=0.0)
            lat, bw = tier_rates[op.tier]
            cdur = op.hops * lat + op.collective_bytes / bw
            lf = lane_free.get(op.lane, 0.0)
            c0 = lf if lf > dep_ready else dep_ready
            events.append(Event(op.lane, f"{nm}:coll", c0, cdur,
                                "collective", op.phase))
            end = c0 + cdur
            lane_free[op.lane] = end
            done[nm] = end
            n_unrestricted -= 1
            scheduled += 1
            for cn in consumers.get(nm, ()):
                n_waiting[cn] -= 1
                if n_waiting[cn] == 0:
                    next_wave.append((-_prio(cn), seq, cn))
                    seq += 1
            if not heap and next_wave:
                heap = next_wave
                heapify(heap)
                next_wave = []
            continue
        aff = op.affinity
        cds = cand[op.device_class]
        if aff is not None and aff in affinity_worker:
            w = affinity_worker[aff]
            aff_remaining[aff] -= 1
        else:
            w = cds[0] if len(cds) == 1 else min(cds,
                                                 key=avail.__getitem__)
            if aff is not None:
                affinity_worker[aff] = w
                # this key's ops are henceforth restricted to worker w
                n_unrestricted -= aff_remaining[aff]
                aff_remaining[aff] -= 1
            else:
                n_unrestricted -= 1
        si = dev_sig[w]
        dep_ready = max((done[d] for d in op.deps if d in done),
                        default=0.0)
        t = avail[w] if avail[w] > dep_ready else dep_ready
        # serial host dispatch (framework time) gates the launch
        host_cost = (host_dispatch
                     + (op.bytes / host_bw / host_threads
                        if host_bw else 0.0))
        if host_cost > 0.0:
            h0 = host_free if host_free > dep_ready else dep_ready
            events.append(Event("host", f"{nm}:dispatch", h0, host_cost,
                                "host", op.phase))
            host_free = h0 + host_cost
            if host_free > t:
                t = host_free
        # staged input transfer, with per-link port contention
        full, xfer, xe = xfer_sig[si][nm]
        transfer_energy += xe
        if xfer > 0.0:
            li = link_of_dev[w]
            ports = ports_l[li]
            if ports <= 0:
                factor = 1.0
            else:
                live = (1 + bisect_right(xfer_starts[li], t)
                        - bisect_right(xfer_ends[li], t))
                factor = max(1.0, live / ports)
            xfer *= factor
            events.append(Event(worker_names[w], f"{nm}:xfer", t, xfer,
                                "transfer", op.phase))
            end = t + xfer
            insort(xfer_starts[li], t)
            insort(xfer_ends[li], end)
            heappush(window_heap[li], (end, t))
            if len(window_heap[li]) >= compact_at[li]:
                # expire windows no future transfer can overlap: every
                # future start on this link is >= its expiry bound, and
                # avail only grows
                bound = _expiry_bound(li)
                wh = window_heap[li]
                while wh and wh[0][0] <= bound:
                    heappop(wh)
                xfer_starts[li] = sorted(s for (_, s) in wh)
                xfer_ends[li] = sorted(e for (e, _) in wh)
                compact_at[li] = max(64, 2 * len(wh))
            iface_time_total += full * factor
            t = end
        else:
            iface_time_total += full
        comp = comp_sig[si][nm]
        events.append(Event(worker_names[w], nm, t, comp, "compute",
                            op.phase))
        t += comp
        avail[w] = t
        # collective traffic serializes on the ICI lane (operand-sum
        # metric, matching the closed-form breakdown; the ring-model
        # wire bytes feed the roofline collective term instead)
        if op.collective_bytes > 0.0:
            lf = lane_free.get(op.lane, 0.0)
            c0 = lf if lf > t else t
            cdur = op.collective_bytes / config.ici_bw
            events.append(Event(op.lane, f"{nm}:coll", c0, cdur,
                                "collective", op.phase))
            lane_free[op.lane] = c0 + cdur
            t = c0 + cdur
        done[nm] = t
        scheduled += 1
        for cn in consumers.get(nm, ()):
            n_waiting[cn] -= 1
            if n_waiting[cn] == 0:
                next_wave.append((-_prio(cn), seq, cn))
                seq += 1
        if not heap and next_wave:
            heap = next_wave
            heapify(heap)
            next_wave = []
    if scheduled != len(program.ops):
        raise ValueError("dependency cycle in program")
    return tl, iface_time_total, transfer_energy


def _run_events_fused(program: Program, config: EngineConfig, plan: Plan,
                      topo: SoCTopology) -> Tuple[Timeline, float, float]:
    """Compiled event core: the same schedule as ``_run_events``, executed
    over the typed-array ``CompiledPlan`` instead of per-op dicts.

    Two throughput layers, both bit-identical by construction:

    * **typed-array core** — all per-op structure is integer-indexed
      (``deps_idx``/``consumers_idx``/static columns), per-op costs are
      hoisted as vectors (``costmodel.chain_terms`` on the compiled
      columnar arrays when the single-signature analytic model applies,
      a scalar sweep otherwise), and event-name strings are precompiled,
      so the heap loop touches flat lists only;

    * **linear-run fusion** — whenever a ready wave consists entirely of
      linear-run heads (LPT-neutral fabric hops, priority exactly 0.0,
      each readying exactly its chain successor), heap pops provably
      drain in seq order — round-robin across the chains in wave entry
      order.  The blast replays ``min(run length) - 1`` full rounds in a
      tight loop (no heap traffic, no consumer bookkeeping), emitting
      events in exactly the order the heap would have, then re-enters the
      surviving chain suffixes as an already-valid heap.  Ring/tree/
      hierarchical collective ladders — the bulk of cluster programs —
      collapse from O(E log E) heap churn to a linear event append.
    """
    cp = plan.compiled()
    op_list = cp.op_list
    n = len(op_list)
    tl = Timeline()
    events_append = tl.events.append

    worker_names, dev_sig, sig_cfgs, link_of_dev, ports_l, devs_on_link \
        = _resolve(config, topo)
    avail = [0.0] * len(topo.devices)
    affinity_worker: Dict[str, int] = {}
    done_l = [0.0] * n
    host_free = 0.0
    lane_free_l = [0.0] * len(cp.lane_names)
    transfer_energy = 0.0
    iface_time_total = 0.0
    nlinks = len(ports_l)

    # per-op fabric hop durations, vectorized per tier (lat/bw are Python
    # floats, so the elementwise float64 math is the scalar math; the
    # per-tier results numpy-scatter into one full column)
    cdur_l: List[float] = []
    if cp.any_tier:
        import numpy as np
        with np.errstate(divide="ignore", invalid="ignore"):
            cdur_a = np.zeros(n, dtype=np.float64)
            for tname, (idxs, hops_a, coll_a) in cp.tier_groups.items():
                lat, bw = hw.resolve_tier_params(config, tname)
                cdur_a[idxs] = hops_a * lat + coll_a / bw
        cdur_l = cdur_a.tolist()

    cand: Dict[str, Tuple[int, ...]] = {}
    for c in cp.dclass_l:
        if c not in cand:
            cand[c] = _cand_cached(topo, c)
    ref_sig = {c: dev_sig[idxs[0]] for c, idxs in cand.items()}

    # hoisted per-op cost tables as flat full-size lists keyed by op
    # index; only the priced subset is ever computed (plain hops price as
    # exact zeros — the scatter default).  Single signature + analytic
    # interface + stock energy model: one vectorized ``chain_terms``
    # evaluation over the priced columns replaces the per-op scalar sweep
    # (same formulas, operation order and IEEE semantics).
    host_dispatch = config.host_dispatch_s
    host_bw = config.host_bw
    host_threads = config.host_threads
    multi = len(sig_cfgs) > 1
    comp_sig: List[Optional[list]] = []
    xfer_sig: List[Optional[list]] = []
    if not multi:
        eff0 = sig_cfgs[0]
        from repro.sim import costmodel
        if (n and eff0.interface in costmodel.CHAIN_INTERFACES
                and eff0.cost_backend is None
                and type(config.energy) is EnergyModel
                and type(eff0.energy) is EnergyModel):
            import numpy as np
            terms = costmodel.chain_terms(
                cp.hoist_arrays(),
                costmodel.ChainParams.from_engine(config, eff0, ports_l[0]))
            pidx = cp.priced_idx
            comp_a = np.zeros(n, dtype=np.float64)
            comp_a[pidx] = terms.comp
            comp_l: List[float] = comp_a.tolist()
            full_a = np.zeros(n, dtype=np.float64)
            full_a[pidx] = terms.full
            full_l: List[float] = full_a.tolist()
            expo_a = np.zeros(n, dtype=np.float64)
            expo_a[pidx] = terms.expo
            expo_l: List[float] = expo_a.tolist()
            xe_a = np.zeros(n, dtype=np.float64)
            xe_a[pidx] = terms.xe
            xe_l: List[float] = xe_a.tolist()
            hc_a = np.zeros(n, dtype=np.float64)
            hc_a[pidx] = terms.hc
            hc_l: List[float] = hc_a.tolist()
        else:
            iface0 = INTERFACES[eff0.interface]
            peak0 = eff0.peak_flops
            bk0 = (None if eff0.cost_backend is None
                   else backends.get_backend(eff0.cost_backend))
            comp_l = [0.0] * n
            full_l = [0.0] * n
            expo_l = [0.0] * n
            xe_l = [0.0] * n
            hc_l = [0.0] * n
            for i in cp.priced_idx.tolist():
                op = op_list[i]
                comp_l[i] = ((op.duration_s if op.duration_s is not None
                              else op.flops / peak0) if bk0 is None
                             else bk0.op_time(op, eff0))
                full_l[i], expo_l[i], xe_l[i] = _transfer_base(op, eff0,
                                                               iface0)
                hc_l[i] = host_dispatch + (
                    op.bytes / host_bw / host_threads if host_bw else 0.0)
    else:
        class_sigs = {c: frozenset(dev_sig[w] for w in idxs)
                      for c, idxs in cand.items()}
        aff_classes: Dict[str, set] = {}
        for i, a in enumerate(cp.affinity_l):
            if a is not None:
                aff_classes.setdefault(a, set()).add(cp.dclass_l[i])
        comp_sig = [None] * len(sig_cfgs)
        xfer_sig = [None] * len(sig_cfgs)
        sig_iface = [INTERFACES[c.interface] for c in sig_cfgs]
        sig_peak = [c.peak_flops for c in sig_cfgs]
        sig_bk = [None if c.cost_backend is None
                  else backends.get_backend(c.cost_backend)
                  for c in sig_cfgs]
        for i, op in enumerate(op_list):
            op_sigs = class_sigs[op.device_class]
            if (op.affinity is not None
                    and len(aff_classes[op.affinity]) > 1):
                op_sigs = frozenset().union(
                    *(class_sigs[c] for c in aff_classes[op.affinity]))
            dur = op.duration_s
            for si in op_sigs:
                if comp_sig[si] is None:
                    comp_sig[si] = [0.0] * n
                    xfer_sig[si] = [None] * n
                comp_sig[si][i] = (dur if dur is not None
                                   else op.flops / sig_peak[si]) \
                    if sig_bk[si] is None \
                    else sig_bk[si].op_time(op, sig_cfgs[si])
                xfer_sig[si][i] = _transfer_base(op, sig_cfgs[si],
                                                 sig_iface[si])
        hc_l = [host_dispatch
                + (op.bytes / host_bw / host_threads
                   if host_bw else 0.0) for op in op_list]

    # contention structures + expiry bookkeeping: identical to the dict
    # loop (see its comments for the semantics)
    xfer_starts: List[List[float]] = [[] for _ in range(nlinks)]
    xfer_ends: List[List[float]] = [[] for _ in range(nlinks)]
    window_heap: List[List[Tuple[float, float]]] = [[] for _ in
                                                    range(nlinks)]
    compact_at = [64] * nlinks
    aff_remaining = dict(cp.aff_counts)
    n_unrestricted = cp.n_unrestricted0

    def _expiry_bound(li: int) -> float:
        dl = devs_on_link[li]
        if n_unrestricted > 0:
            return min(avail[w] for w in dl)
        live_workers = set()
        for k, c in aff_remaining.items():
            if c > 0:
                pinned = affinity_worker.get(k)
                if pinned is None:
                    return min(avail[w] for w in dl)
                if link_of_dev[pinned] == li:
                    live_workers.add(pinned)
        if not live_workers:
            return float("inf")
        return min(avail[w] for w in live_workers)

    if not multi:
        _prio = comp_l.__getitem__
    else:
        def _prio(i: int) -> float:
            return comp_sig[ref_sig[cp.dclass_l[i]]][i]

    names = cp.names
    coll_nm, disp_nm, xfer_nm = cp.event_names()
    _E = Event
    _new = object.__new__
    deps_idx = cp.deps_idx
    consumers_idx = cp.consumers_idx
    n_waiting = list(cp.n_waiting0)
    is_tier = cp.is_tier
    lane_code = cp.lane_code
    lane_names = cp.lane_names
    phase_l = cp.phase_l
    affinity_l = cp.affinity_l
    dclass_l = cp.dclass_l
    coll_l = cp.coll_l
    run_next = cp.run_next
    run_len = cp.run_len
    any_tier = cp.any_tier
    ici_bw = config.ici_bw

    # same wave semantics as the dict loop, restructured so the swap (and
    # the blast check) happens once, at the top — the initial root wave
    # enters through the same gate
    heap: List[Tuple[float, int, int]] = []
    next_wave = [(-_prio(i), k, i) for k, i in enumerate(cp.roots_idx)]
    next_wave_append = next_wave.append
    seq = len(next_wave)
    scheduled = 0

    while True:
        if not heap:
            if not next_wave:
                break
            heap = next_wave
            next_wave = []
            next_wave_append = next_wave.append
            heapify(heap)
            if any_tier:
                # linear-run blast: every wave entry a run head with the
                # same (necessarily 0.0) priority -> pops drain in pure
                # seq order, round-robin across the chains.  Replay
                # min(runlen)-1 full rounds without touching the heap.
                base = heap[0][0]
                min_rl = n
                ok = True
                for e in heap:
                    rl = run_len[e[2]]
                    if rl < 2 or e[0] != base:
                        ok = False
                        break
                    if rl < min_rl:
                        min_rl = rl
                if ok:
                    entries = sorted(heap)
                    k = len(entries)
                    rounds = min_rl - 1
                    heads = [e[2] for e in entries]
                    cready = []
                    for i in heads:
                        ds = deps_idx[i]
                        dr = 0.0
                        if ds:
                            dr = done_l[ds[0]]
                            for di in range(1, len(ds)):
                                v = done_l[ds[di]]
                                if v > dr:
                                    dr = v
                        cready.append(dr)
                    for _ in range(rounds):
                        for j in range(k):
                            i = heads[j]
                            lc = lane_code[i]
                            cdur = cdur_l[i]
                            lf = lane_free_l[lc]
                            dr = cready[j]
                            c0 = lf if lf > dr else dr
                            ev = _new(_E)
                            ev.__dict__ = {
                                "worker": lane_names[lc],
                                "name": coll_nm[i], "start": c0,
                                "duration": cdur,
                                "kind": "collective",
                                "phase": phase_l[i]}
                            events_append(ev)
                            end = c0 + cdur
                            lane_free_l[lc] = end
                            cready[j] = end
                            heads[j] = run_next[i]
                    n_unrestricted -= rounds * k
                    scheduled += rounds * k
                    heap = []
                    for j in range(k):
                        i = heads[j]
                        # the new head's sole dep is its chain's last
                        # blasted op; equal priorities + ascending seq
                        # make the rebuilt list an already-valid heap
                        done_l[deps_idx[i][0]] = cready[j]
                        heap.append((base, seq, i))
                        seq += 1
        _, _, i = heappop(heap)
        ds = deps_idx[i]
        dep_ready = 0.0
        if ds:
            dep_ready = done_l[ds[0]]
            for di in range(1, len(ds)):
                v = done_l[ds[di]]
                if v > dep_ready:
                    dep_ready = v
        if is_tier[i]:
            cdur = cdur_l[i]
            lc = lane_code[i]
            lf = lane_free_l[lc]
            c0 = lf if lf > dep_ready else dep_ready
            ev = _new(_E)
            ev.__dict__ = {"worker": lane_names[lc], "name": coll_nm[i],
                           "start": c0, "duration": cdur,
                           "kind": "collective", "phase": phase_l[i]}
            events_append(ev)
            end = c0 + cdur
            lane_free_l[lc] = end
            done_l[i] = end
            n_unrestricted -= 1
            scheduled += 1
            for ci in consumers_idx[i]:
                nw = n_waiting[ci] - 1
                n_waiting[ci] = nw
                if not nw:
                    next_wave_append((-_prio(ci), seq, ci))
                    seq += 1
            continue
        aff = affinity_l[i]
        cds = cand[dclass_l[i]]
        if aff is not None and aff in affinity_worker:
            w = affinity_worker[aff]
            aff_remaining[aff] -= 1
        else:
            w = cds[0] if len(cds) == 1 else min(cds,
                                                 key=avail.__getitem__)
            if aff is not None:
                affinity_worker[aff] = w
                n_unrestricted -= aff_remaining[aff]
                aff_remaining[aff] -= 1
            else:
                n_unrestricted -= 1
        si = dev_sig[w]
        aw = avail[w]
        t = aw if aw > dep_ready else dep_ready
        host_cost = hc_l[i]
        if host_cost > 0.0:
            h0 = host_free if host_free > dep_ready else dep_ready
            ev = _new(_E)
            ev.__dict__ = {"worker": "host", "name": disp_nm[i],
                           "start": h0, "duration": host_cost,
                           "kind": "host", "phase": phase_l[i]}
            events_append(ev)
            host_free = h0 + host_cost
            if host_free > t:
                t = host_free
        if multi:
            full, xfer, xe = xfer_sig[si][i]
        else:
            full = full_l[i]
            xfer = expo_l[i]
            xe = xe_l[i]
        transfer_energy += xe
        if xfer > 0.0:
            li = link_of_dev[w]
            ports = ports_l[li]
            if ports <= 0:
                factor = 1.0
            else:
                live = (1 + bisect_right(xfer_starts[li], t)
                        - bisect_right(xfer_ends[li], t))
                factor = max(1.0, live / ports)
            xfer *= factor
            ev = _new(_E)
            ev.__dict__ = {"worker": worker_names[w], "name": xfer_nm[i],
                           "start": t, "duration": xfer,
                           "kind": "transfer", "phase": phase_l[i]}
            events_append(ev)
            end = t + xfer
            insort(xfer_starts[li], t)
            insort(xfer_ends[li], end)
            heappush(window_heap[li], (end, t))
            if len(window_heap[li]) >= compact_at[li]:
                bound = _expiry_bound(li)
                wh = window_heap[li]
                while wh and wh[0][0] <= bound:
                    heappop(wh)
                xfer_starts[li] = sorted(s for (_, s) in wh)
                xfer_ends[li] = sorted(e for (e, _) in wh)
                compact_at[li] = max(64, 2 * len(wh))
            iface_time_total += full * factor
            t = end
        else:
            iface_time_total += full
        comp = comp_sig[si][i] if multi else comp_l[i]
        ev = _new(_E)
        ev.__dict__ = {"worker": worker_names[w], "name": names[i],
                       "start": t, "duration": comp,
                       "kind": "compute", "phase": phase_l[i]}
        events_append(ev)
        t += comp
        avail[w] = t
        if coll_l[i] > 0.0:
            lc = lane_code[i]
            lf = lane_free_l[lc]
            c0 = lf if lf > t else t
            cdur = coll_l[i] / ici_bw
            ev = _new(_E)
            ev.__dict__ = {"worker": lane_names[lc], "name": coll_nm[i],
                           "start": c0, "duration": cdur,
                           "kind": "collective", "phase": phase_l[i]}
            events_append(ev)
            lane_free_l[lc] = c0 + cdur
            t = c0 + cdur
        done_l[i] = t
        scheduled += 1
        for ci in consumers_idx[i]:
            nw = n_waiting[ci] - 1
            n_waiting[ci] = nw
            if not nw:
                next_wave_append((-_prio(ci), seq, ci))
                seq += 1
    if scheduled != len(program.ops):
        raise ValueError("dependency cycle in program")
    return tl, iface_time_total, transfer_energy


# ---------------------------------------------------------------------------
# linear-chain fast path: the whole schedule is one prefix sum


def _run_chain(program: Program, config: EngineConfig, topo: SoCTopology
               ) -> Optional[Tuple[Timeline, float, float, float,
                                   Dict[str, float]]]:
    """Vectorized executor for pure chains — bit-identical to the event
    loop.  On a chain every op starts exactly when its predecessor's chain
    time ends (worker/host/ICI lanes can never push it later), so the
    schedule is the prefix sum of the interleaved per-op
    (host, transfer, compute, collective) durations, in the exact addition
    order of the loop.  Costs are computed with the same IEEE operations
    as the scalar interface models.  Returns None to fall back when an op
    carries a cost the vectorized model can't mirror (negative/non-finite)
    or when the chain's placement classes resolve to more than one device
    cost signature or link (the event loop handles those heterogeneous
    chains).
    """
    import numpy as np

    ops = program.ops
    m = len(ops)

    # resolve the chain's placement: the vectorized model mirrors exactly
    # one device cost signature on one link
    cand: Dict[str, Tuple[int, ...]] = {}
    for op in ops:
        c = op.device_class
        if c not in cand:
            cand[c] = topo.candidate_indices(c)
    used = sorted({w for idxs in cand.values() for w in idxs})
    eff = link = None
    for w in used:
        d = topo.devices[w]
        e = _device_config(config, topo, d)
        l = topo.link_for(d)
        if eff is None:
            eff, link = e, l
        elif (e.interface != eff.interface
              or e.peak_flops != eff.peak_flops
              or e.datapath_scale != eff.datapath_scale
              or e.hbm_bw != eff.hbm_bw or e.vmem_bw != eff.vmem_bw
              or e.cost_backend != eff.cost_backend
              or l.name != link.name):
            return None
    ports = _link_ports(config, link)

    # the per-op terms live in repro.sim.costmodel (shared verbatim with
    # the batched analytic model / DSE layer); called with this config's
    # scalar parameters they are the exact IEEE operations this fast path
    # always performed
    from repro.sim import costmodel
    if eff.interface not in costmodel.CHAIN_INTERFACES:
        return None                         # registered custom interface
    if (config.fabric is not None and config.fabric.has_overrides()
            and any(op.tier is not None for op in ops)):
        return None     # explicit per-tier rates: event loop resolves them
    # non-roofline cost backend: the analytic comp column
    # ``flops / peak`` is replaced by the backend's per-op pricing —
    # exactly the values the event loop's hoisted tables would charge,
    # so the chain fast path stays bit-identical to the slow path
    comp_over = None
    if eff.cost_backend is not None:
        bk = backends.get_backend(eff.cost_backend)
        comp_over = np.array(
            [0.0 if op.tier is not None else bk.op_time(op, eff)
             for op in ops], dtype=np.float64)
    t = costmodel.chain_terms(
        costmodel.op_arrays(ops),
        costmodel.ChainParams.from_engine(config, eff, ports),
        comp=comp_over)
    comp, full, xe, factor = t.comp, t.full, t.xe, t.factor
    hc, xfer, cdur = t.hc, t.xfer, t.cdur
    has_h, has_x, has_c = t.has_h, t.has_x, t.has_c

    flat = costmodel.interleave(t)
    if not np.isfinite(flat).all() or (m and flat.min() < 0.0):
        return None                         # event loop handles the exotic
    # itertools.accumulate guarantees the loop's strict left-to-right float
    # addition order (numpy reductions may re-associate)
    cum = list(accumulate(flat.tolist()))

    # worker labels: timing is device-independent on a uniform chain, but
    # the least-loaded assignment within each op's class (ties -> lowest
    # index) must be replayed for bit-identical event rows
    n = len(topo.devices)
    if n == 1:
        widx = [0] * m
    else:
        avail = [0.0] * n
        widx = []
        for i in range(m):
            if ops[i].tier is not None:     # lane-only: never placed
                widx.append(0)
                continue
            cs = cand[ops[i].device_class]
            w = cs[0] if len(cs) == 1 else min(cs, key=avail.__getitem__)
            avail[w] = cum[4 * i + 2]       # end of this op's compute
            widx.append(w)
    worker_names = [d.name for d in topo.devices]

    tl = Timeline()
    events = tl.events
    hc_l, xfer_l, comp_l, cdur_l = (hc.tolist(), xfer.tolist(),
                                    comp.tolist(), cdur.tolist())
    hh, hx, hcoll = has_h.tolist(), has_x.tolist(), has_c.tolist()
    for i in range(m):
        op = ops[i]
        b = 4 * i
        if op.tier is not None:
            # fabric hop: lane event only (matches the event-loop branch)
            events.append(Event(op.lane, f"{op.name}:coll", cum[b + 2],
                                cdur_l[i], "collective", op.phase))
            continue
        wname = worker_names[widx[i]]
        if hh[i]:
            events.append(Event("host", f"{op.name}:dispatch",
                                cum[b - 1] if i else 0.0, hc_l[i], "host",
                                op.phase))
        if hx[i]:
            events.append(Event(wname, f"{op.name}:xfer", cum[b], xfer_l[i],
                                "transfer", op.phase))
        events.append(Event(wname, op.name, cum[b + 1], comp_l[i],
                            "compute", op.phase))
        if hcoll[i]:
            events.append(Event(op.lane, f"{op.name}:coll", cum[b + 2],
                                cdur_l[i], "collective", op.phase))

    # sequential accumulations (match the loop's += order exactly: within
    # each kind, event order == op order, so per-kind running sums are the
    # same float additions ``report.aggregate`` would perform)
    iface_time_total = 0.0
    for v in np.where(has_x, full * factor, full).tolist():
        iface_time_total += v
    transfer_energy = 0.0
    for v in xe.tolist():
        transfer_energy += v
    kinds: Dict[str, float] = {}
    acc = 0.0
    for v in comp_l:
        acc += v
    kinds["compute"] = acc
    if any(hx):
        acc = 0.0
        for i, v in enumerate(xfer_l):
            if hx[i]:
                acc += v
        kinds["transfer"] = acc
    if any(hh):
        acc = 0.0
        for i, v in enumerate(hc_l):
            if hh[i]:
                acc += v
        kinds["host"] = acc
    if any(hcoll):
        acc = 0.0
        for i, v in enumerate(cdur_l):
            if hcoll[i]:
                acc += v
        kinds["collective"] = acc
    # every event boundary is a prefix-sum entry and the chain is monotone,
    # so the last entry IS max(event.end) — no O(E) rescan needed
    makespan = cum[-1] if cum else 0.0
    return tl, iface_time_total, transfer_energy, makespan, kinds


# ---------------------------------------------------------------------------
# shared result assembly


def _finalize(tl: Timeline, program: Program, config: EngineConfig,
              topo: SoCTopology, plan: Plan, iface_time_total: float,
              transfer_energy: float, model_flops: float,
              host_floor: float, *, makespan: Optional[float] = None,
              kinds: Optional[Dict[str, float]] = None) -> EngineResult:
    totals = plan.totals if plan.totals else program.totals()
    if kinds is None:
        # one fused pass: the per-kind fold (== report.aggregate(events,
        # "kind"): same left-to-right addition order) and the makespan
        # max share the event iteration; the makespan is cached on the
        # timeline so post-run metrics don't re-fold
        kinds = {}
        kget = kinds.get
        mk = None
        for e in tl.events:
            k = e.kind
            kinds[k] = kget(k, 0.0) + e.duration
            end = e.start + e.duration
            if mk is None or end > mk:
                mk = end
        if makespan is None:
            makespan = mk if mk is not None else 0.0
            tl._mk_cache = makespan
    elif makespan is None:
        makespan = tl.makespan
    bd = report.Breakdown(
        accelerator_s=kinds.get("compute", 0.0),
        transfer_s=kinds.get("transfer", 0.0),
        host_s=kinds.get("host", 0.0) + host_floor,
        collective_s=kinds.get("collective", 0.0))
    # the aggregate-report device: Fig-1 dot-hiding budget and the closed
    # form roofline are charged at the first accelerator's parameters
    # (== the flat config on a homogeneous topology)
    ref = _ref_accel_config(config, topo)
    if ref.overlap:
        # the Fig-1 transfer phase applies the dot-hiding budget at the
        # aggregate level (like the closed form): memory time beyond the
        # program's total MXU time is exposed.  The timeline keeps the
        # per-op view; per-op exposure can only exceed this (Jensen).
        bd.transfer_s = max(
            iface_time_total - totals["dot_flops"] / ref.peak_flops,
            0.0)
    rl = report.roofline_from_totals(
        totals, host_s=host_floor, n_chips=config.n_chips,
        model_flops=model_flops, peak_flops=ref.peak_flops,
        hbm_bw=ref.hbm_bw, ici_bw=config.ici_bw)
    e_comp = config.energy.compute(totals["flops"])
    e_ici = config.energy.ici(totals["collective_bytes"])
    e_static = config.energy.static(makespan + host_floor, 1)
    energy = {
        "compute_j": e_comp, "hbm_j": transfer_energy, "ici_j": e_ici,
        "static_j": e_static,
        "total_j": e_comp + transfer_energy + e_ici + e_static,
        "total_j_all_chips": (e_comp + transfer_energy + e_ici + e_static)
        * config.n_chips,
    }
    return EngineResult(timeline=tl, program=program, config=config,
                        breakdown=bd, roofline=rl, energy=energy,
                        makespan=makespan)
