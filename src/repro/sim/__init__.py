"""Unified end-to-end simulation engine.

One IR (``repro.sim.ir.CostedOp``), one executor (``repro.sim.engine``), one
reporting layer (``repro.sim.report``).  Every paper figure — the Fig-1
breakdown, the roofline terms, the DMA-vs-ACP interface study (Fig 11), the
multi-accelerator scaling (Fig 12/13/14), the combined optimizations
(Fig 18), and energy — falls out of a single simulated execution instead of
the three disconnected cost paths the seed carried (closed-form
``core.simulator``, tile-scheduler ``core.scheduler.simulate``, and ad-hoc
interface sums in the benchmarks).

Lowerings:
  ir.from_graph(Graph)         tile-level program from the declarative graph
  ir.from_hlo(analyze_hlo())   macro-op program from a compiled XLA module
  ir.from_decode(ModelConfig)  token-by-token autoregressive decode chain
  ir.from_serving_step(...)    one batched serving iteration (prefill +
                               continuous-batch decode)
  ir.from_training_step(...)   one training optimizer step (fwd, 2x-flop
                               bwd with activation re-reads, DP gradient
                               all-reduce, optimizer update) — whole-model
                               or one pipeline stage's layer share
  ir.from_tasks([TileTask])    legacy scheduler tasks (compat path)

``core.simulator.roofline``/``breakdown`` and ``core.scheduler.simulate``
remain as thin wrappers over this engine for API stability.

The SoC itself is a first-class object (``repro.sim.hw``): ``Device``
(cpu / accel / dsp, per-device peak flops, datapath scale, interface,
bandwidths) and ``Link`` (shared port pool) compose into an
``SoCTopology`` carried by ``EngineConfig.topology``.  Ops are placed by
their ``device_class`` tag and transfers contend per link; a homogeneous
topology is bit-identical to the flat config it expands.

Design-space exploration goes through ``repro.sim.sweep``:
  sweep(program, configs)     one lowering + shared dependency plan, many
                              configs (serial / threads / processes)
  batched(program, configs)   the analytic cost model prices the whole
                              grid as one vectorized parameter matrix
                              (bit-identical to the engine on chains, a
                              certified lower/upper bracket on DAGs) and
                              exact-verifies the top-k winners
  optimize(program, space)    multi-start gradient descent over
                              continuous hardware parameters (jax
                              analytic gradients when available, batched
                              finite differences otherwise); the event
                              engine verifies the returned design
  topology_sweep(program, topologies, base_config)
                              the same, over an SoC-topology grid
  lower_graph / lower_hlo     memoized lowerings keyed on
                              (graph identity, batch, tile params)
The executor core is O(E log E) (heap ready queue, incremental HBM-port
contention) with a prefix-sum fast path for linear-chain programs that is
bit-identical to the event loop; the fast path's per-op terms are the
pure functions of ``repro.sim.costmodel`` (``hw.PARAM_FIELDS`` vector ->
cost terms), which is what makes the batched/differentiable DSE layer
exact where it matters.

Served workloads go through ``repro.sim.serving``: a request trace
(Poisson / bursty / loaded records) replayed against a batching policy
(static / dynamic / continuous, from ``repro.serve.policy``), reporting
TTFT / TPOT percentiles, throughput and batch occupancy alongside the
engine's usual views.

Training steps go through ``repro.sim.training``: microbatched
pipeline-parallel schedules (GPipe / 1F1B) co-simulated over an
``SoCTopology`` — each stage pinned to a device, inter-stage
activation/gradient transfers contending on links — reporting step time,
per-stage utilization and the measured pipeline bubble fraction against
the analytic ``(p-1)/(m+p-1)`` bound.

Cluster-scale networks go through the ``hw.Fabric`` tier hierarchy
(intra-chip ici / intra-node / inter-node latency+bandwidth tiers) and
``ir.from_collective``: ring / tree / hierarchical all-reduce,
reduce-scatter, all-gather and all-to-all lower to explicit per-hop
transfers that contend on per-tier fabric lanes in the engine and match
the closed-form collective bounds exactly on uncontended fabrics
(``ir.collective_time``).  ``simulate_training`` places DP x TP x PP
over a fabric, and ``sweep.cluster_sweep`` / ``as_cluster_records``
price whole placement grids with per-step energy and TCO
(``hw.tco_per_step``).
"""
from repro.sim.backends import (CostBackend, RooflineBackend,  # noqa: F401
                                SystolicBackend, TableBackend, get_backend)
from repro.sim.costmodel import (CostModel, Unsupported,  # noqa: F401
                                 relaxation_err)
from repro.sim.engine import (EngineConfig, EngineResult, Plan,  # noqa: F401
                              chain_op_costs, prepare, run)
from repro.sim.hw import (Device, Fabric, FabricTier,  # noqa: F401
                          Link, PARAM_FIELDS, SoCTopology, apply_params,
                          params_from_config, resolve_tier_params,
                          tco_per_step)
from repro.sim.ir import (CostedOp, Program,  # noqa: F401
                          collective_time, from_collective, from_decode,
                          from_graph, from_hlo, from_serving_step,
                          from_training_step, partition_stages)
from repro.sim.serving import (Request, ServingResult,  # noqa: F401
                               as_serving_records, bursty_trace, load_trace,
                               poisson_trace, save_trace, simulate_serving,
                               serving_sweep, trace_from_records)
from repro.sim.sweep import (BatchedSweep, OptimizeResult,  # noqa: F401
                             as_cluster_records, as_records,
                             as_training_records, batched, cluster_sweep,
                             lower_graph, lower_hlo, optimize,
                             placements_for, sweep, topology_sweep,
                             training_sweep)
from repro.sim.training import (TrainingResult, bubble_bound,  # noqa: F401
                                schedule_order, simulate_training)
