"""Batched design-space exploration: one program, many SoC configs.

SMAUG's case studies are *sweeps* — the same workload evaluated over a grid
of interface choices, worker counts, host-threading levels and datapath
sizes (Fig 11/14/15/16/20).  ``sweep(program, configs)`` runs that grid
without re-paying per-config costs:

  * the program is lowered once and its dependency bookkeeping
    (``engine.prepare``: ops / consumers / n_waiting / totals) is shared by
    every run instead of being rebuilt per config;
  * ``lower_graph`` / ``lower_hlo`` memoize the ``from_graph`` /
    ``from_hlo`` lowerings keyed on (graph identity, batch, tile params),
    so benchmark loops that re-lower the same network hit a cache;
  * configs can be evaluated serially (fast engine + shared plan), across
    threads, or across processes (the program ships once per worker via
    the pool initializer, not once per config).

Results come back as a tidy list of ``EngineResult`` records, one per
config, in config order — the same objects ``engine.run`` returns, so every
downstream consumer (benchmarks, reports, figures) is unchanged.

On top of the exact grid sits the **analytic DSE layer**
(``repro.sim.costmodel``): ``batched(program, configs)`` prices the whole
grid as one vectorized parameter matrix (bit-identical to the engine on
chain programs, a certified lower/upper bracket on DAGs) and re-runs only
the top-k winners through the exact engine; ``optimize(program, space)``
descends the same model with multi-start gradient descent (jax analytic
gradients when available, batched finite differences otherwise) and
returns an exact-engine-verified design — "the cheapest config meeting a
latency target" is one call.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.sim import costmodel, engine, hw, ir
from repro.sim.costmodel import CostModel, Unsupported
from repro.sim.engine import EngineConfig, EngineResult
from repro.sim.hw import PARAM_FIELDS, SoCTopology
from repro.sim.ir import Program

__all__ = ["sweep", "batched", "optimize", "topology_sweep",
           "training_sweep", "fleet_sweep", "cluster_sweep",
           "placements_for", "lower_graph", "lower_hlo", "graph_digest",
           "as_records", "as_training_records", "as_cluster_records",
           "BatchedSweep", "OptimizeResult"]

_CACHE_MAX = 64

# digest-keyed program cache, true LRU (a hit refreshes recency via
# move_to_end, eviction pops the least-recently-used entry).  Keying on a
# structural digest — not object identity — lets independently-built but
# identical graphs (fresh ``build_paper_graph`` calls in different
# benchmark cells) share one lowering.
_graph_cache: "OrderedDict[tuple, Program]" = OrderedDict()
_hlo_cache: "OrderedDict[tuple, Program]" = OrderedDict()

# id -> (graph object, digest): ``from_graph`` backfills weight-derived
# attrs in place, so a graph's byte content changes after its first
# lowering; the digest is therefore computed once per *object* (the graph
# is retained so a recycled id can never alias) and reused verbatim.
_digest_memo: "OrderedDict[int, tuple]" = OrderedDict()


def graph_digest(g) -> str:
    """Stable structural digest of a ``repro.core.graph.Graph``: name,
    backend, and every node's (name, op, inputs, shape, sorted attrs) in
    topological order.  Graphs built by the same recipe digest equal even
    when they are distinct objects."""
    key = id(g)
    hit = _digest_memo.get(key)
    if hit is not None and hit[0] is g:
        _digest_memo.move_to_end(key)
        return hit[1]
    import hashlib
    h = hashlib.sha256()
    h.update(f"{g.name}|{getattr(g, 'backend', '')}\n".encode())
    for name in g.order:
        n = g.nodes[name]
        attrs = ";".join(f"{k}={n.attrs[k]!r}" for k in sorted(n.attrs))
        h.update(f"{n.name}|{n.op}|{','.join(n.inputs)}|"
                 f"{tuple(n.shape)}|{attrs}\n".encode())
    d = h.hexdigest()
    if len(_digest_memo) >= _CACHE_MAX:
        _digest_memo.popitem(last=False)
    _digest_memo[key] = (g, d)
    return d


def lower_graph(g, batch: int = 1, max_tile_elems: int = 16384) -> Program:
    """Memoized ``ir.from_graph`` keyed on (structural digest, batch,
    tile params) — equal graphs hit the cache even across distinct
    objects."""
    key = (graph_digest(g), int(batch), int(max_tile_elems))
    prog = _graph_cache.get(key)
    if prog is not None:
        _graph_cache.move_to_end(key)
        return prog
    prog = ir.from_graph(g, batch=batch, max_tile_elems=max_tile_elems)
    if len(_graph_cache) >= _CACHE_MAX:
        _graph_cache.popitem(last=False)
    _graph_cache[key] = prog
    return prog


def lower_hlo(hlo: Dict, n_ops: int = 8, name: str = "") -> Program:
    """Memoized ``ir.from_hlo`` keyed on the dict's numeric content."""
    key = (tuple(sorted((k, float(v)) for k, v in hlo.items()
                        if isinstance(v, (int, float)))),
           int(n_ops), name or str(hlo.get("entry", "hlo")))
    prog = _hlo_cache.get(key)
    if prog is not None:
        _hlo_cache.move_to_end(key)
    else:
        prog = ir.from_hlo(hlo, n_ops=n_ops, name=name)
        if len(_hlo_cache) >= _CACHE_MAX:
            _hlo_cache.popitem(last=False)
        _hlo_cache[key] = prog
    return prog


def clear_caches() -> None:
    """Drop the memoized lowerings (tests and long-lived sessions that
    churn through many graphs; the LRU-ish eviction above bounds memory
    for everyone else)."""
    _graph_cache.clear()
    _hlo_cache.clear()
    _digest_memo.clear()


# ---------------------------------------------------------------------------
# process-pool plumbing: the program crosses the fork/pickle boundary once
# per worker (initializer), then each task ships only its EngineConfig.

_proc_state: dict = {}


def _proc_init(program: Program, model_flops: float,
               host_s: Optional[float]) -> None:
    _proc_state["program"] = program
    _proc_state["plan"] = engine.prepare(program)
    _proc_state["model_flops"] = model_flops
    _proc_state["host_s"] = host_s


def _proc_run(config: EngineConfig) -> EngineResult:
    return engine.run(_proc_state["program"], config,
                      model_flops=_proc_state["model_flops"],
                      host_s=_proc_state["host_s"],
                      plan=_proc_state["plan"])


def sweep(program: Program, configs: Sequence[EngineConfig], *,
          model_flops: float = 0.0, host_s: Optional[float] = None,
          executor: str = "auto", max_workers: Optional[int] = None
          ) -> List[EngineResult]:
    """Run ``program`` under every config; one ``EngineResult`` per config.

    ``executor``:
      ``"serial"``   one process, shared ``Plan`` (default choice of auto —
                     the O(E log E) engine makes fan-out overhead the
                     bottleneck for all but the largest grids);
      ``"thread"``   ``ThreadPoolExecutor`` (the engine is pure — no shared
                     mutable state — so threads are safe; useful when the
                     numpy chain path dominates and releases the GIL);
      ``"process"``  ``ProcessPoolExecutor``; the program is shipped once
                     per worker, configs are the only per-task payload.
                     Falls back to serial if the platform refuses a pool;
      ``"auto"``     serial for small grids and chain programs, processes
                     for large DAG grids.

    Results are bit-identical across executors (each run is independent).
    """
    configs = list(configs)
    if not configs:
        return []
    plan = engine.prepare(program)
    if executor == "auto":
        big = len(program.ops) * len(configs) >= 400_000
        executor = "process" if (big and not plan.is_chain
                                 and len(configs) > 1) else "serial"
    if executor == "serial":
        return [engine.run(program, cfg, model_flops=model_flops,
                           host_s=host_s, plan=plan) for cfg in configs]
    if executor == "thread":
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=max_workers) as ex:
            return list(ex.map(
                lambda cfg: engine.run(program, cfg,
                                       model_flops=model_flops,
                                       host_s=host_s, plan=plan),
                configs))
    if executor == "process":
        import concurrent.futures
        from concurrent.futures.process import BrokenProcessPool
        import os
        nw = max_workers or min(len(configs), os.cpu_count() or 1)
        try:
            with concurrent.futures.ProcessPoolExecutor(
                    max_workers=nw, initializer=_proc_init,
                    initargs=(program, model_flops, host_s)) as ex:
                return list(ex.map(_proc_run, configs))
        except (BrokenProcessPool, OSError, ImportError,
                NotImplementedError):
            # pool-creation / platform failures only (sandboxed or
            # forkless hosts, a worker that died before running a task):
            # degrade to the serial path — results are identical, only
            # wall-clock differs.  A genuine error raised by engine.run
            # inside a worker is NOT swallowed: it propagates out of
            # ex.map with its own type.
            return [engine.run(program, cfg, model_flops=model_flops,
                               host_s=host_s, plan=plan) for cfg in configs]
    raise ValueError(f"unknown executor {executor!r}; "
                     "one of serial|thread|process|auto")


# ---------------------------------------------------------------------------
# analytic DSE layer: vectorized grid pricing + gradient-based search,
# with the exact event engine as the verifier of record


def _check_batchable(configs: Sequence[EngineConfig]) -> None:
    """The analytic batch varies only the continuous ``hw.PARAM_FIELDS``;
    every categorical/static knob must agree across the grid."""
    base = configs[0]
    for c in configs:
        if c.topology is not None:
            raise Unsupported(
                "batched() takes flat configs (topology=None); price "
                "explicit topologies with sweep()/topology_sweep()")
        if (c.interface != base.interface or c.overlap != base.overlap
                or c.energy != base.energy
                or type(c.energy) is not type(base.energy)
                or c.vmem_resident_bytes != base.vmem_resident_bytes
                or c.dma_transfer_bytes != base.dma_transfer_bytes
                or c.cost_backend != base.cost_backend):
            raise Unsupported(
                "batched() grids vary only the continuous PARAM_FIELDS; "
                "interface/energy/backend/tile statics must agree across "
                "configs (split the grid per interface instead)")


@dataclasses.dataclass
class BatchedSweep:
    """A grid priced by the analytic model, with exact spot checks.

    ``makespans`` is exact (bit-identical to ``engine.run``) when
    ``exact`` — chain programs priced by the analytic model, and
    fusion-resolvable DAGs priced by the engine itself over the whole
    grid — else the certified lower bound; ``lower <= exact <= upper``
    always.  ``verified`` holds the exact-engine cross-checks of the
    analytically best ``top_k`` points (``relaxation_err == 0`` whenever
    ``exact``)."""
    program: Program
    configs: List[EngineConfig]
    makespans: np.ndarray
    lower: np.ndarray
    upper: np.ndarray
    is_chain: bool
    backend: str
    verified: List[Dict]
    exact: bool = False

    def top(self, k: int = 1) -> List[int]:
        """Indices of the k analytically-fastest configs (stable order)."""
        return [int(i) for i in
                np.argsort(self.makespans, kind="stable")[:k]]

    def best(self) -> Dict:
        """The exact-engine-verified winner (first verified entry)."""
        if not self.verified:
            raise ValueError("batched() ran with top_k=0; no verified "
                             "winner to return")
        return self.verified[0]

    def records(self) -> List[Dict]:
        """Tidy per-config rows (exact columns filled for verified
        points, None elsewhere)."""
        by_idx = {v["index"]: v for v in self.verified}
        rows = []
        for i, c in enumerate(self.configs):
            v = by_idx.get(i)
            rows.append({
                "index": i, "program": self.program.name,
                "interface": c.interface, "n_workers": c.n_workers,
                **{f: float(getattr(c, f)) for f in PARAM_FIELDS},
                "analytic_s": float(self.makespans[i]),
                "lower_s": float(self.lower[i]),
                "upper_s": float(self.upper[i]),
                "exact_s": (None if v is None else v["exact_s"]),
                "relaxation_err": (None if v is None
                                   else v["relaxation_err"]),
            })
        return rows


def batched(program: Program, configs: Sequence[EngineConfig], *,
            top_k: int = 3, backend: str = "numpy",
            model_flops: float = 0.0, host_s: Optional[float] = None
            ) -> BatchedSweep:
    """Price a whole config grid through the analytic cost model at once.

    One (B, 9) ``hw.PARAM_FIELDS`` matrix evaluates vectorized —
    thousands of design points per second instead of one engine run per
    config — then the analytically best ``top_k`` points are re-run
    through the exact engine (``verified``), so the winner you act on is
    never an artifact of the relaxation.  Chain programs price exactly:
    on the default numpy backend the values are **bit-identical** to
    ``engine.run`` (``backend="jax"``/"auto" trade that for float32
    jit+vmap, allclose only); DAGs get the certified lower/upper
    bracket.  Raises ``costmodel.Unsupported`` for grids the model can't
    mirror (heterogeneous topologies, custom interfaces/energy models) —
    ``sweep()`` remains the universal path.

    DAG programs that linear-run fusion collapses to a small segment
    graph (``engine.fusion_resolvable``) skip the relaxation entirely:
    the fused engine prices every grid point exactly over one shared
    compiled plan, so ``lower == upper == makespans`` and every verified
    row reports ``relaxation_err == 0`` — the bracket only remains for
    DAGs fusion cannot resolve.
    """
    configs = list(configs)
    if not configs:
        return BatchedSweep(program=program, configs=[],
                            makespans=np.zeros(0), lower=np.zeros(0),
                            upper=np.zeros(0), is_chain=True,
                            backend="numpy", verified=[], exact=True)
    _check_batchable(configs)
    plan = engine.prepare(program)
    if not plan.is_chain and engine.fusion_resolvable(plan):
        # exact DAG pricing: fusion resolved the program to a segment
        # graph small enough that the event engine beats the relaxation
        # at its own game — run the whole grid on one compiled plan.
        results = [engine.run(program, c, model_flops=model_flops,
                              host_s=host_s, plan=plan) for c in configs]
        mk = np.array([r.makespan for r in results])
        verified: List[Dict] = []
        if top_k > 0:
            for i in np.argsort(mk, kind="stable")[:top_k]:
                i = int(i)
                verified.append({
                    "index": i, "config": configs[i],
                    "result": results[i], "analytic_s": float(mk[i]),
                    "exact_s": results[i].makespan,
                    "relaxation_err": 0.0})
            verified.sort(key=lambda v: v["exact_s"])
        return BatchedSweep(program=program, configs=configs,
                            makespans=mk, lower=mk, upper=mk,
                            is_chain=False, backend="engine",
                            verified=verified, exact=True)
    model = CostModel(program, configs[0], backend=backend)
    P = np.array([hw.params_from_config(c) for c in configs])
    nw = np.array([float(c.n_workers) for c in configs])
    lower, upper = model.bounds(P, n_workers=nw)
    verified: List[Dict] = []
    if top_k > 0:
        for i in np.argsort(lower, kind="stable")[:top_k]:
            i = int(i)
            res = engine.run(program, configs[i], model_flops=model_flops,
                             host_s=host_s, plan=plan)
            err = ((float(lower[i]) - res.makespan) / res.makespan
                   if res.makespan else 0.0)
            verified.append({
                "index": i, "config": configs[i], "result": res,
                "analytic_s": float(lower[i]), "exact_s": res.makespan,
                "relaxation_err": err})
        verified.sort(key=lambda v: v["exact_s"])
    return BatchedSweep(program=program, configs=configs,
                        makespans=lower, lower=lower, upper=upper,
                        is_chain=model.is_chain, backend=model.backend,
                        verified=verified, exact=model.is_chain)


@dataclasses.dataclass
class OptimizeResult:
    """An exact-engine-verified design point from ``optimize()``."""
    config: EngineConfig
    params: Dict[str, float]      # the optimized space fields
    exact_s: float                # engine.run makespan at the design
    analytic_s: float             # the model's value at the same point
    relaxation_err: float
    objective: float              # exact-makespan objective value
    feasible: Optional[bool]      # exact_s <= target_s (None: no target)
    target_s: Optional[float]
    backend: str                  # gradient backend actually used
    n_evals: int                  # analytic design points priced
    result: EngineResult
    candidates: List[Dict]        # every exact-verified finalist


def optimize(program: Program, space: Dict[str, Tuple[float, float]], *,
             base_config: Optional[EngineConfig] = None,
             target_s: Optional[float] = None,
             cost: Optional[Callable] = None,
             n_starts: int = 8, steps: int = 60, lr: float = 0.25,
             seed: int = 0, verify_k: int = 4, backend: str = "auto",
             model_flops: float = 0.0, host_s: Optional[float] = None
             ) -> OptimizeResult:
    """Gradient-based design-space search over continuous hardware knobs.

    ``space`` maps ``hw.PARAM_FIELDS`` names to (lo, hi) ranges.  The
    search runs multi-start projected gradient descent on the analytic
    cost model in normalized z-space (geometric interpolation per
    range): with the jax backend the gradients are analytic
    (jit+vmap+grad of the same term functions the engine runs), on numpy
    they are batched central differences — either way every step prices
    its whole stencil in one vectorized call.  Without ``target_s`` the
    objective is the makespan; with it, "the cheapest design meeting the
    latency target" (``cost`` defaults to mean normalized size; a
    callable receives the (B, 9) parameter matrix).  The ``verify_k``
    best candidates are re-run through the exact event engine and the
    returned design is chosen on EXACT numbers, so the relaxation can
    steer but never lie.
    """
    model = CostModel(program, base_config, backend=backend)
    if model.base.topology is not None:
        raise Unsupported(
            "optimize() searches flat configs (topology=None); express "
            "the SoC as flat fields, or grid explicit topologies through "
            "sweep()")
    obj = model.objective(space, target_s=target_s, cost=cost)
    d = len(obj.names)
    rng = np.random.default_rng(seed)
    S = max(int(n_starts), 1)
    Z = rng.uniform(size=(S, d))
    # deterministic anchor starts: center, max-hardware and min-hardware
    # corners (the pure-latency optimum usually lives near a corner)
    for i, z0 in enumerate((0.5, 1.0, 0.0)):
        if i < S:
            Z[i] = z0
    best_z = Z.copy()
    best_v = np.full(S, np.inf)
    lr_t = lr
    n_evals = 0
    for _ in range(int(steps)):
        v = obj.value(Z)
        n_evals += S
        better = v < best_v
        best_v = np.where(better, v, best_v)
        best_z[better] = Z[better]
        g = obj.grad(Z)
        n_evals += S * (2 * d if obj.backend == "numpy" else 1)
        gn = np.max(np.abs(g), axis=1, keepdims=True)
        Z = np.clip(Z - lr_t * (g / np.maximum(gn, 1e-12)), 0.0, 1.0)
        lr_t *= 0.97
    v = obj.value(Z)
    n_evals += S
    better = v < best_v
    best_v = np.where(better, v, best_v)
    best_z[better] = Z[better]

    # rank the per-start winners, dedupe, exact-verify the finalists
    order = np.argsort(best_v, kind="stable")
    seen = set()
    finalists: List[np.ndarray] = []
    for i in order:
        key = tuple(np.round(best_z[i], 5))
        if key in seen:
            continue
        seen.add(key)
        finalists.append(best_z[i])
        if len(finalists) >= max(int(verify_k), 1):
            break
    plan = engine.prepare(program)
    candidates: List[Dict] = []

    def _verify(z) -> Dict:
        P = obj.to_params(z[None, :])
        analytic = float(model.makespans(P)[0])
        params = {nm: float(P[0, di])
                  for nm, di in zip(obj.names, obj.dims)}
        cfg = model.config_for(params)
        res = engine.run(program, cfg, model_flops=model_flops,
                         host_s=host_s, plan=plan)
        exact = res.makespan
        if target_s is None:
            exact_obj = exact
            feasible = None
        else:
            c = (cost(P)[0] if cost is not None
                 else float(np.mean(z)))
            feasible = bool(exact <= target_s * (1.0 + 1e-12))
            exact_obj = float(c) + (0.0 if feasible else
                                    100.0 * (exact / target_s - 1.0) ** 2)
        return {"params": params, "config": cfg, "result": res,
                "exact_s": exact, "analytic_s": analytic,
                "relaxation_err": ((analytic - exact) / exact
                                   if exact else 0.0),
                "objective": float(exact_obj), "feasible": feasible}

    for z in finalists:
        candidates.append(_verify(z))
    if target_s is not None and not any(c["feasible"] for c in candidates):
        # every finalist sits just over the target (the descent converges
        # onto the feasibility boundary, and the exact engine may price
        # the boundary a hair above the relaxation).  Back the best one
        # off toward the max-hardware corner until the exact engine
        # confirms feasibility — t=1 is the corner itself, so a reachable
        # target always yields a feasible candidate.
        zb = finalists[int(np.argmin([c["objective"]
                                      for c in candidates]))]
        for t in (0.02, 0.05, 0.1, 0.2, 0.4, 1.0):
            cand = _verify(zb + t * (1.0 - zb))
            if cand["feasible"]:
                candidates.append(cand)
                break
    # exact numbers pick the winner; with a target, feasible designs
    # outrank infeasible ones outright
    candidates.sort(key=lambda c: (not c["feasible"]
                                   if c["feasible"] is not None else False,
                                   c["objective"]))
    win = candidates[0]
    return OptimizeResult(
        config=win["config"], params=win["params"],
        exact_s=win["exact_s"], analytic_s=win["analytic_s"],
        relaxation_err=win["relaxation_err"],
        objective=win["objective"], feasible=win["feasible"],
        target_s=target_s, backend=obj.backend, n_evals=n_evals,
        result=win["result"], candidates=candidates)


def topology_sweep(program: Program, topologies: Sequence[SoCTopology],
                   base_config: Optional[EngineConfig] = None,
                   **kw) -> List[EngineResult]:
    """Run ``program`` on every ``SoCTopology`` of a grid: each topology
    is installed into a copy of ``base_config`` (default: a fresh
    ``EngineConfig()``) and the grid goes through ``sweep`` — one
    lowering, one shared plan, one ``EngineResult`` per SoC.  The SMAUG
    SoC-tuning studies (how many accelerators, which frontend device,
    how many shared ports) are one call."""
    base = base_config if base_config is not None else EngineConfig()
    configs = [dataclasses.replace(base, topology=t) for t in topologies]
    return sweep(program, configs, **kw)


def training_sweep(cfg, *, schedules: Sequence[str] = ("gpipe", "1f1b"),
                   n_stages_grid: Sequence[int] = (1, 2, 4),
                   n_microbatches_grid: Sequence[int] = (1, 4, 8),
                   seq_len: int = 512, global_batch: Optional[int] = None,
                   base_config: Optional[EngineConfig] = None,
                   **kw) -> List:
    """Run the pipeline-parallel design-space grid: one
    ``repro.sim.training.TrainingResult`` per (n_stages, n_microbatches,
    schedule) cell, in that nesting order.  Every cell simulates the SAME
    amount of work — ``global_batch`` defaults to the least common
    multiple of ``n_microbatches_grid`` so every microbatch count divides
    it; a caller-supplied value must divide by every entry.  Extra keyword
    arguments pass through to ``simulate_training``."""
    import math

    from repro.sim.training import simulate_training
    base = base_config if base_config is not None else EngineConfig()
    if global_batch is None:
        global_batch = math.lcm(*n_microbatches_grid)
    out = []
    for p in n_stages_grid:
        for m in n_microbatches_grid:
            for schedule in schedules:
                res = simulate_training(
                    cfg, n_stages=p, n_microbatches=m, schedule=schedule,
                    seq_len=seq_len, global_batch=global_batch,
                    config=base, **kw)
                res.meta.update({"model": getattr(cfg, "name", "model")})
                out.append(res)
    return out


def fleet_sweep(cfg, *, routers: Sequence[str] = ("round_robin",
                                                  "least_outstanding",
                                                  "session_affinity"),
                replica_counts: Sequence[int] = (1, 2, 4),
                policy=None, n_requests: int = 2000,
                rate_rps: float = 200.0, trace_kind: str = "diurnal",
                seed: int = 0, config: Optional[EngineConfig] = None,
                bytes_per_param: float = 2.0, **trace_kw) -> List:
    """Run the router x replica-count fleet grid: one
    ``repro.sim.serving.FleetResult`` per (router, n_replicas) cell, in
    that nesting order.  Every cell replays the SAME seeded trace (one
    generator call, shared across cells) through ONE shared
    ``StepCostTable``, so the comparison isolates the routing/replica
    choice and the whole grid prices steps out of a single memo."""
    from repro.serve.policy import get_policy
    from repro.sim.serving import (TRACE_GENERATORS, StepCostTable,
                                   simulate_fleet)
    base = config if config is not None else EngineConfig()
    if policy is None:
        policy = get_policy("continuous", max_batch=8)
    trace = TRACE_GENERATORS[trace_kind](
        n_requests, rate_rps, seed=seed, arrays=True, **trace_kw) \
        if trace_kind == "diurnal" else \
        TRACE_GENERATORS[trace_kind](n_requests, rate_rps, seed=seed,
                                     **trace_kw)
    table = StepCostTable(cfg, base, bytes_per_param=bytes_per_param)
    out = []
    for router in routers:
        for n in replica_counts:
            res = simulate_fleet(cfg, trace, policy, base,
                                 n_replicas=n, router=router,
                                 bytes_per_param=bytes_per_param,
                                 table=table)
            res.meta.update({"model": getattr(cfg, "name", "model"),
                             "router": router, "n_replicas": n,
                             "rate_rps": rate_rps,
                             "trace_kind": trace_kind, "seed": seed})
            out.append(res)
    return out


def placements_for(n_accel: int, *, max_tp: int = 8,
                   max_pp: int = 8) -> List[Tuple[int, int, int]]:
    """All ``(dp, pp, tp)`` placements with ``dp * pp * tp == n_accel``,
    TP and PP restricted to powers of two up to their caps (the shapes
    real launch configs use: TP within a node, PP across a handful of
    stages, DP soaking up the rest)."""
    out = []
    tp = 1
    while tp <= min(max_tp, n_accel):
        pp = 1
        while tp * pp <= n_accel and pp <= max_pp:
            if n_accel % (tp * pp) == 0:
                out.append((n_accel // (tp * pp), pp, tp))
            pp *= 2
        tp *= 2
    return out


def cluster_sweep(cfg, *, n_accel_grid: Sequence[int] = (8, 64, 512),
                  algos: Sequence[str] = ("ring", "tree", "hierarchical"),
                  placements: Optional[Sequence[Tuple[int, int, int]]]
                  = None,
                  seq_len: int = 512, global_batch: int = 32,
                  schedule: str = "1f1b",
                  base_config: Optional[EngineConfig] = None,
                  accels_per_chip: int = 4, chips_per_node: int = 8,
                  max_tp: int = 8, max_pp: int = 8, **kw) -> List:
    """Run the cluster design-space grid: one ``TrainingResult`` per
    (n_accel, (dp, pp, tp), collective_algo) cell over a
    ``hw.Fabric.cluster`` of each size — the "cheapest N-accelerator
    config that trains the model under a step-time target" question is
    ``min`` over ``as_cluster_records`` rows filtered on ``step_time_s``.

    ``global_batch`` is the CLUSTER batch: each DP replica simulates
    ``global_batch / dp`` sequences (floored at one sequence per
    microbatch), with ``n_microbatches = min(2 * pp, 16)`` so deeper
    pipes get enough microbatches to fill.  Extra kwargs pass through to
    ``simulate_training``."""
    from repro.sim.training import simulate_training
    base = base_config if base_config is not None else EngineConfig()
    out = []
    for n in n_accel_grid:
        fab = hw.Fabric.cluster(n, accels_per_chip=accels_per_chip,
                                chips_per_node=chips_per_node)
        cells = (placements if placements is not None
                 else placements_for(n, max_tp=max_tp, max_pp=max_pp))
        for dp, pp, tp in cells:
            if dp * pp * tp != n:
                continue
            m = min(2 * pp, 16)
            replica_batch = m * max(1, round(global_batch / (dp * m)))
            for algo in algos:
                res = simulate_training(
                    cfg, n_stages=pp, n_microbatches=m,
                    schedule=schedule, seq_len=seq_len,
                    global_batch=replica_batch, config=base,
                    dp_degree=dp, tp_degree=tp, fabric=fab,
                    collective_algo=algo, **kw)
                res.meta.update({"model": getattr(cfg, "name", "model"),
                                 "cluster_global_batch": global_batch})
                out.append(res)
    return out


def as_cluster_records(results: Iterable) -> List[Dict[str, float]]:
    """Flatten cluster ``TrainingResult``s to tidy rows with the
    placement axes, whole-cluster throughput/energy, and per-step TCO
    (``hw.tco_per_step``: amortized accelerator capex + energy)."""
    rows = []
    for r in results:
        dp = int(r.meta.get("dp_degree", 1))
        tp = int(r.meta.get("tp_degree", 1))
        n_accel = int(r.meta.get("n_accel", dp * tp * r.n_stages))
        replica_j = r.engine.energy["total_j"]
        cluster_j = replica_j * dp * tp
        cluster_tokens = r.tokens * dp
        tco = hw.tco_per_step(n_accel, r.step_time_s, cluster_j)
        rows.append({
            "program": r.program.name,
            "model": r.meta.get("model", ""),
            "n_accel": n_accel,
            "dp_degree": dp, "pp_degree": r.n_stages, "tp_degree": tp,
            "collective_algo": r.meta.get("collective_algo", "ring"),
            "fabric": r.meta.get("fabric"),
            "schedule": r.schedule,
            "n_microbatches": r.n_microbatches,
            "replica_batch": r.meta.get("global_batch"),
            "seq_len": r.meta.get("seq_len"),
            "bound": r.engine.roofline.bound,
            "cluster_tokens_per_s": (cluster_tokens / r.step_time_s
                                     if r.step_time_s else 0.0),
            "replica_j": replica_j, "cluster_j": cluster_j,
            "tco_usd_per_step": tco,
            "tco_usd_per_mtok": (tco / (cluster_tokens / 1e6)
                                 if cluster_tokens else 0.0),
            **r.stats(),
        })
    return rows


def as_training_records(results: Iterable) -> List[Dict[str, float]]:
    """Flatten ``TrainingResult``s to tidy per-cell dicts (the training
    analogue of ``as_records``)."""
    rows = []
    for r in results:
        rows.append({
            "program": r.program.name,
            "model": r.meta.get("model", ""),
            "schedule": r.schedule,
            "n_stages": r.n_stages,
            "n_microbatches": r.n_microbatches,
            "seq_len": r.meta.get("seq_len"),
            "global_batch": r.meta.get("global_batch"),
            "interface": r.config.interface,
            "bound": r.engine.roofline.bound,
            "total_j": r.engine.energy["total_j"],
            **r.stats(),
        })
    return rows


def as_records(results: Iterable[EngineResult]) -> List[Dict[str, float]]:
    """Flatten results to tidy per-config dicts (DataFrame-friendly)."""
    rows = []
    for r in results:
        c = r.config
        topo = c.resolved_topology()
        rows.append({
            "program": r.program.name, "n_ops": len(r.program.ops),
            "interface": c.interface, "n_workers": c.n_workers,
            "topology": topo.name if c.topology is not None else "flat",
            "devices": topo.describe(), "n_accel": topo.n_accel,
            "hbm_ports": c.hbm_ports, "host_threads": c.host_threads,
            "datapath_scale": c.datapath_scale,
            "peak_flops": c.peak_flops,
            "makespan_s": r.makespan,
            "accelerator_s": r.breakdown.accelerator_s,
            "transfer_s": r.breakdown.transfer_s,
            "host_s": r.breakdown.host_s,
            "collective_s": r.breakdown.collective_s,
            "step_s": r.roofline.step_s, "bound": r.roofline.bound,
            "total_j": r.energy["total_j"],
            "utilization": r.utilization(),
            # analytic-model fidelity for free: 0.0 on chains (the model
            # IS the fast path), <= 0 lower-bound error on DAGs, None
            # where no analytic model exists (heterogeneous SoCs, custom
            # interfaces/energy models)
            "relaxation_err": costmodel.relaxation_err(r),
        })
    return rows
