"""Batched design-space exploration: one program, many SoC configs.

SMAUG's case studies are *sweeps* — the same workload evaluated over a grid
of interface choices, worker counts, host-threading levels and datapath
sizes (Fig 11/14/15/16/20).  ``sweep(program, configs)`` runs that grid
without re-paying per-config costs:

  * the program is lowered once and its dependency bookkeeping
    (``engine.prepare``: ops / consumers / n_waiting / totals) is shared by
    every run instead of being rebuilt per config;
  * ``lower_graph`` / ``lower_hlo`` memoize the ``from_graph`` /
    ``from_hlo`` lowerings keyed on (graph identity, batch, tile params),
    so benchmark loops that re-lower the same network hit a cache;
  * configs can be evaluated serially (fast engine + shared plan), across
    threads, or across processes (the program ships once per worker via
    the pool initializer, not once per config).

Results come back as a tidy list of ``EngineResult`` records, one per
config, in config order — the same objects ``engine.run`` returns, so every
downstream consumer (benchmarks, reports, figures) is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

from repro.sim import engine, ir
from repro.sim.engine import EngineConfig, EngineResult
from repro.sim.hw import SoCTopology
from repro.sim.ir import Program

__all__ = ["sweep", "topology_sweep", "training_sweep", "lower_graph",
           "lower_hlo", "as_records", "as_training_records"]

_CACHE_MAX = 64

# key -> (graph object, Program).  The graph object is retained so the
# id()-based key can never be recycled by a different (garbage-collected)
# graph; the identity check below makes the cache exact.
_graph_cache: Dict[tuple, tuple] = {}
_hlo_cache: Dict[tuple, Program] = {}


def lower_graph(g, batch: int = 1, max_tile_elems: int = 16384) -> Program:
    """Memoized ``ir.from_graph`` keyed on (graph id, batch, tile params)."""
    key = (id(g), int(batch), int(max_tile_elems))
    hit = _graph_cache.get(key)
    if hit is not None and hit[0] is g:
        return hit[1]
    prog = ir.from_graph(g, batch=batch, max_tile_elems=max_tile_elems)
    if len(_graph_cache) >= _CACHE_MAX:
        _graph_cache.pop(next(iter(_graph_cache)))
    _graph_cache[key] = (g, prog)
    return prog


def lower_hlo(hlo: Dict, n_ops: int = 8, name: str = "") -> Program:
    """Memoized ``ir.from_hlo`` keyed on the dict's numeric content."""
    key = (tuple(sorted((k, float(v)) for k, v in hlo.items()
                        if isinstance(v, (int, float)))),
           int(n_ops), name or str(hlo.get("entry", "hlo")))
    prog = _hlo_cache.get(key)
    if prog is None:
        prog = ir.from_hlo(hlo, n_ops=n_ops, name=name)
        if len(_hlo_cache) >= _CACHE_MAX:
            _hlo_cache.pop(next(iter(_hlo_cache)))
        _hlo_cache[key] = prog
    return prog


def clear_caches() -> None:
    """Drop the memoized lowerings (tests and long-lived sessions that
    churn through many graphs; the LRU-ish eviction above bounds memory
    for everyone else)."""
    _graph_cache.clear()
    _hlo_cache.clear()


# ---------------------------------------------------------------------------
# process-pool plumbing: the program crosses the fork/pickle boundary once
# per worker (initializer), then each task ships only its EngineConfig.

_proc_state: dict = {}


def _proc_init(program: Program, model_flops: float,
               host_s: Optional[float]) -> None:
    _proc_state["program"] = program
    _proc_state["plan"] = engine.prepare(program)
    _proc_state["model_flops"] = model_flops
    _proc_state["host_s"] = host_s


def _proc_run(config: EngineConfig) -> EngineResult:
    return engine.run(_proc_state["program"], config,
                      model_flops=_proc_state["model_flops"],
                      host_s=_proc_state["host_s"],
                      plan=_proc_state["plan"])


def sweep(program: Program, configs: Sequence[EngineConfig], *,
          model_flops: float = 0.0, host_s: Optional[float] = None,
          executor: str = "auto", max_workers: Optional[int] = None
          ) -> List[EngineResult]:
    """Run ``program`` under every config; one ``EngineResult`` per config.

    ``executor``:
      ``"serial"``   one process, shared ``Plan`` (default choice of auto —
                     the O(E log E) engine makes fan-out overhead the
                     bottleneck for all but the largest grids);
      ``"thread"``   ``ThreadPoolExecutor`` (the engine is pure — no shared
                     mutable state — so threads are safe; useful when the
                     numpy chain path dominates and releases the GIL);
      ``"process"``  ``ProcessPoolExecutor``; the program is shipped once
                     per worker, configs are the only per-task payload.
                     Falls back to serial if the platform refuses a pool;
      ``"auto"``     serial for small grids and chain programs, processes
                     for large DAG grids.

    Results are bit-identical across executors (each run is independent).
    """
    configs = list(configs)
    if not configs:
        return []
    plan = engine.prepare(program)
    if executor == "auto":
        big = len(program.ops) * len(configs) >= 400_000
        executor = "process" if (big and not plan.is_chain
                                 and len(configs) > 1) else "serial"
    if executor == "serial":
        return [engine.run(program, cfg, model_flops=model_flops,
                           host_s=host_s, plan=plan) for cfg in configs]
    if executor == "thread":
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=max_workers) as ex:
            return list(ex.map(
                lambda cfg: engine.run(program, cfg,
                                       model_flops=model_flops,
                                       host_s=host_s, plan=plan),
                configs))
    if executor == "process":
        try:
            from concurrent.futures import ProcessPoolExecutor
            import os
            nw = max_workers or min(len(configs), os.cpu_count() or 1)
            with ProcessPoolExecutor(
                    max_workers=nw, initializer=_proc_init,
                    initargs=(program, model_flops, host_s)) as ex:
                return list(ex.map(_proc_run, configs))
        except Exception:
            # sandboxed/forkless platforms: degrade to the serial path —
            # results are identical, only wall-clock differs
            return [engine.run(program, cfg, model_flops=model_flops,
                               host_s=host_s, plan=plan) for cfg in configs]
    raise ValueError(f"unknown executor {executor!r}; "
                     "one of serial|thread|process|auto")


def topology_sweep(program: Program, topologies: Sequence[SoCTopology],
                   base_config: Optional[EngineConfig] = None,
                   **kw) -> List[EngineResult]:
    """Run ``program`` on every ``SoCTopology`` of a grid: each topology
    is installed into a copy of ``base_config`` (default: a fresh
    ``EngineConfig()``) and the grid goes through ``sweep`` — one
    lowering, one shared plan, one ``EngineResult`` per SoC.  The SMAUG
    SoC-tuning studies (how many accelerators, which frontend device,
    how many shared ports) are one call."""
    base = base_config if base_config is not None else EngineConfig()
    configs = [dataclasses.replace(base, topology=t) for t in topologies]
    return sweep(program, configs, **kw)


def training_sweep(cfg, *, schedules: Sequence[str] = ("gpipe", "1f1b"),
                   n_stages_grid: Sequence[int] = (1, 2, 4),
                   n_microbatches_grid: Sequence[int] = (1, 4, 8),
                   seq_len: int = 512, global_batch: Optional[int] = None,
                   base_config: Optional[EngineConfig] = None,
                   **kw) -> List:
    """Run the pipeline-parallel design-space grid: one
    ``repro.sim.training.TrainingResult`` per (n_stages, n_microbatches,
    schedule) cell, in that nesting order.  Every cell simulates the SAME
    amount of work — ``global_batch`` defaults to the least common
    multiple of ``n_microbatches_grid`` so every microbatch count divides
    it; a caller-supplied value must divide by every entry.  Extra keyword
    arguments pass through to ``simulate_training``."""
    import math

    from repro.sim.training import simulate_training
    base = base_config if base_config is not None else EngineConfig()
    if global_batch is None:
        global_batch = math.lcm(*n_microbatches_grid)
    out = []
    for p in n_stages_grid:
        for m in n_microbatches_grid:
            for schedule in schedules:
                res = simulate_training(
                    cfg, n_stages=p, n_microbatches=m, schedule=schedule,
                    seq_len=seq_len, global_batch=global_batch,
                    config=base, **kw)
                res.meta.update({"model": getattr(cfg, "name", "model")})
                out.append(res)
    return out


def as_training_records(results: Iterable) -> List[Dict[str, float]]:
    """Flatten ``TrainingResult``s to tidy per-cell dicts (the training
    analogue of ``as_records``)."""
    rows = []
    for r in results:
        rows.append({
            "program": r.program.name,
            "model": r.meta.get("model", ""),
            "schedule": r.schedule,
            "n_stages": r.n_stages,
            "n_microbatches": r.n_microbatches,
            "seq_len": r.meta.get("seq_len"),
            "global_batch": r.meta.get("global_batch"),
            "interface": r.config.interface,
            "bound": r.engine.roofline.bound,
            "total_j": r.engine.energy["total_j"],
            **r.stats(),
        })
    return rows


def as_records(results: Iterable[EngineResult]) -> List[Dict[str, float]]:
    """Flatten results to tidy per-config dicts (DataFrame-friendly)."""
    rows = []
    for r in results:
        c = r.config
        topo = c.resolved_topology()
        rows.append({
            "program": r.program.name, "n_ops": len(r.program.ops),
            "interface": c.interface, "n_workers": c.n_workers,
            "topology": topo.name if c.topology is not None else "flat",
            "devices": topo.describe(), "n_accel": topo.n_accel,
            "hbm_ports": c.hbm_ports, "host_threads": c.host_threads,
            "datapath_scale": c.datapath_scale,
            "peak_flops": c.peak_flops,
            "makespan_s": r.makespan,
            "accelerator_s": r.breakdown.accelerator_s,
            "transfer_s": r.breakdown.transfer_s,
            "host_s": r.breakdown.host_s,
            "collective_s": r.breakdown.collective_s,
            "step_s": r.roofline.step_s, "bound": r.roofline.bound,
            "total_j": r.energy["total_j"],
            "utilization": r.utilization(),
        })
    return rows
