"""Hardware model: canonical constants + the SoC topology layer.

The constants (TPU v5e, per assignment) are the single home for numbers
that used to be re-declared across ``core/simulator.py``,
``core/interfaces.py`` and ``core/tiling.py``; those modules re-export
them for backward compatibility.

On top of the constants sits the **topology model**: a ``Device`` is one
execution resource on the SoC (a CPU core cluster, an accelerator, a
DSP), a ``Link`` is a shared data-movement resource (the HBM port pool,
an ACP/DMA path), and an ``SoCTopology`` composes them.  SMAUG's case
studies vary exactly this object — how many accelerators share how many
memory ports, and which device runs the camera frontend — so the engine
(``repro.sim.engine``) takes an ``EngineConfig.topology`` and schedules
every ``CostedOp`` onto the device matching its ``device_class``,
charging its traffic to that device's link.

Inheritance convention: every ``Device``/``Link`` field that is ``None``
falls back to the corresponding flat ``EngineConfig`` field, so the
*homogeneous expansion* of a legacy config (``n_workers`` identical
accelerators on one shared link) is ``SoCTopology.homogeneous(n)`` —
and is bit-identical to the pre-topology engine by construction
(asserted in ``tests/test_engine_equivalence.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
VMEM_BW = 11e12              # effective on-chip bandwidth
HOST_OVERHEAD_S = 50e-6      # per-step launch/framework floor (host runtime)

# -- fabric tiers (cluster-scale interconnect hierarchy) --------------------
# Per-hop latency + bandwidth for the three canonical tiers.  ``ici`` keeps
# zero default latency so the legacy single-lane collective charge
# (bytes / ici_bw, no latency term) is reproduced bit-for-bit by a
# single-tier fabric.
ICI_LAT_S = 0.0              # intra-chip hop latency (0 = legacy lane)
NODE_BW = 25e9               # bytes/s intra-node (board-level links;
                             # slower than the on-chip ici lane)
NODE_LAT_S = 1e-6            # per-hop intra-node latency
INTER_BW = 10e9              # bytes/s inter-node (100G-class NIC/switch)
INTER_LAT_S = 5e-6           # per-hop inter-node latency (NIC + switch)

# -- TCO (cost-per-step) constants ------------------------------------------
ACCEL_COST_USD = 15_000.0    # per accelerator, incl. host/switch share
ACCEL_AMORT_S = 3 * 365 * 24 * 3600.0   # 3-year straight-line amortization
USD_PER_KWH = 0.10           # blended datacenter energy price

# device kinds with modeled semantics; ``kind`` is open-ended (any string
# works as a placement class), these are the conventional ones
DEVICE_KINDS = ("cpu", "accel", "dsp")


@dataclass(frozen=True)
class Device:
    """One execution resource on the SoC.

    ``kind`` is the placement class ``CostedOp.device_class`` matches
    against (``cpu`` | ``accel`` | ``dsp`` by convention).  Every
    ``None`` field inherits the flat ``EngineConfig`` value, so a bare
    ``Device("acc0")`` is exactly one of today's workers."""
    name: str
    kind: str = "accel"
    peak_flops: Optional[float] = None       # None -> EngineConfig.peak_flops
    datapath_scale: Optional[float] = None   # None -> EngineConfig value
    interface: Optional[str] = None          # None -> EngineConfig.interface
    hbm_bw: Optional[float] = None           # None -> link bw -> EngineConfig
    vmem_bw: Optional[float] = None          # None -> EngineConfig.vmem_bw
    link: Optional[str] = None               # Link name; None -> first link
    # per-device compute-cost backend (repro.sim.backends); None inherits
    # EngineConfig.cost_backend (itself None = the native roofline math)
    cost_backend: Optional[object] = None


@dataclass(frozen=True)
class Link:
    """A shared data-movement resource (e.g. the HBM port pool).

    ``ports`` has the engine's contention semantics: active transfers on
    this link beyond ``ports`` share bandwidth (0 = uncontended,
    fractional values model a link narrower than one device's demand).
    ``bandwidth`` overrides the per-byte rate for devices on this link
    (``None`` inherits ``EngineConfig.hbm_bw``)."""
    name: str
    bandwidth: Optional[float] = None        # None -> EngineConfig.hbm_bw
    ports: Optional[float] = None            # None -> EngineConfig.hbm_ports


_DEFAULT_LINK = Link("shared")


@dataclass(frozen=True)
class SoCTopology:
    """Devices + links: the heterogeneous SoC the engine schedules onto.

    A topology with no ``links`` declared has one implicit shared link
    inheriting every ``EngineConfig`` value — today's single HBM port
    pool.  Ops are placed on the devices whose ``kind`` equals their
    ``device_class``; a class with no matching device falls back to the
    accelerators (and then to every device), so programs tagged for a
    richer SoC still run on a smaller one."""
    devices: Tuple[Device, ...]
    links: Tuple[Link, ...] = ()
    name: str = "soc"

    def __post_init__(self):
        object.__setattr__(self, "devices", tuple(self.devices))
        object.__setattr__(self, "links", tuple(self.links))
        if not self.devices:
            raise ValueError("SoCTopology needs at least one device")
        names = [d.name for d in self.devices]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate device names in topology: {names}")
        lnames = [l.name for l in self.links]
        if len(set(lnames)) != len(lnames):
            raise ValueError(f"duplicate link names in topology: {lnames}")
        known = set(lnames)
        for d in self.devices:
            if d.link is not None and d.link not in known:
                raise ValueError(
                    f"device {d.name!r} references unknown link {d.link!r}")

    # -- construction -------------------------------------------------------

    @classmethod
    def homogeneous(cls, n_workers: int, name: str = "") -> "SoCTopology":
        """The homogeneous expansion of a flat config: ``n_workers``
        identical accelerators (every field inherited) on one implicit
        shared link — bit-identical to the pre-topology engine.

        Memoized on the worker count (every ``run()`` of a flat config
        resolves one, and the frozen instances are safely shareable), so
        small-program runs don't pay device construction + validation."""
        n = max(int(n_workers), 1)
        if name:
            return cls(devices=tuple(Device(f"acc{i}") for i in range(n)),
                       name=name)
        return _homogeneous_cached(n)

    # -- queries ------------------------------------------------------------

    def devices_of(self, kind: str) -> Tuple[Device, ...]:
        return tuple(d for d in self.devices if d.kind == kind)

    @property
    def n_accel(self) -> int:
        return sum(1 for d in self.devices if d.kind == "accel")

    def kind_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.devices:
            out[d.kind] = out.get(d.kind, 0) + 1
        return out

    def describe(self) -> str:
        """Compact label like ``1cpu+4accel`` (device-order stable)."""
        counts = self.kind_counts()
        return "+".join(f"{c}{k}" for k, c in counts.items())

    def candidate_indices(self, device_class: str) -> Tuple[int, ...]:
        """Device indices an op of ``device_class`` may be placed on:
        exact kind match, else the accelerators, else every device."""
        idx = tuple(i for i, d in enumerate(self.devices)
                    if d.kind == device_class)
        if not idx:
            idx = tuple(i for i, d in enumerate(self.devices)
                        if d.kind == "accel")
        if not idx:
            idx = tuple(range(len(self.devices)))
        return idx

    def link_for(self, device: Device) -> Link:
        """The link this device's transfers traverse (declared by name,
        else the topology's first link, else the implicit shared one)."""
        if device.link is not None:
            for l in self.links:
                if l.name == device.link:
                    return l
        return self.links[0] if self.links else _DEFAULT_LINK


@lru_cache(maxsize=128)
def _homogeneous_cached(n: int) -> SoCTopology:
    return SoCTopology(devices=tuple(Device(f"acc{i}") for i in range(n)),
                       name=f"{n}accel")


# ---------------------------------------------------------------------------
# continuous hardware-parameter vector <-> EngineConfig mapping
#
# The analytic cost model (``repro.sim.costmodel``) and the DSE layer
# (``sweep.batched`` / ``sweep.optimize``) treat a design point as a flat
# float vector over these fields; everything else on the config
# (interface choice, energy constants, tile thresholds) is categorical
# and stays fixed within a batch.  ``host_threads``/``hbm_ports`` are
# kept continuous here — the engine only ever divides by them, so a
# fractional value is a perfectly well-defined (if physically idealized)
# design point, and keeping them continuous is what makes the gradient
# path smooth.

PARAM_FIELDS: Tuple[str, ...] = (
    "peak_flops", "datapath_scale", "hbm_bw", "vmem_bw", "ici_bw",
    "hbm_ports", "host_dispatch_s", "host_bw", "host_threads",
    "ici_lat_s", "node_bw", "node_lat_s", "inter_bw", "inter_lat_s")

ParamsLike = Union[Mapping[str, float], Sequence[float]]


def params_from_config(config) -> Tuple[float, ...]:
    """The ``PARAM_FIELDS`` vector of an ``EngineConfig``-like object (any
    object carrying the continuous fields), as plain floats in field
    order."""
    return tuple(float(getattr(config, f)) for f in PARAM_FIELDS)


def params_dict(params: ParamsLike) -> Dict[str, float]:
    """Normalize a params mapping/sequence to a ``{field: float}`` dict
    (sequences must be full-length and are zipped against PARAM_FIELDS)."""
    if isinstance(params, Mapping):
        unknown = set(params) - set(PARAM_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown hardware parameters {sorted(unknown)}; "
                f"continuous fields are {PARAM_FIELDS}")
        return {k: float(v) for k, v in params.items()}
    vals = list(params)
    if len(vals) != len(PARAM_FIELDS):
        raise ValueError(
            f"expected {len(PARAM_FIELDS)} values in PARAM_FIELDS order, "
            f"got {len(vals)}")
    return {k: float(v) for k, v in zip(PARAM_FIELDS, vals)}


def apply_params(config, params: ParamsLike):
    """A copy of ``config`` with the given continuous fields installed.

    ``params`` is either a ``{field: value}`` mapping (partial is fine)
    or a full vector in ``PARAM_FIELDS`` order.  Values stay floats —
    see the module note on continuous ``host_threads``/``hbm_ports`` —
    so the exact engine prices precisely the point the analytic model
    evaluated.  Explicit per-device/per-link overrides in
    ``config.topology`` are NOT rewritten (the flat fields are only
    inheritance defaults there); use ``with_ports`` for the port study."""
    return replace(config, **params_dict(params))


def with_ports(topo: SoCTopology, ports: float) -> SoCTopology:
    """A copy of ``topo`` with every link's ``ports`` set to ``ports``
    (an implicit shared link is made explicit first) — the knob the
    Fig-13-style port studies turn."""
    links = topo.links if topo.links else (_DEFAULT_LINK,)
    return replace(topo, links=tuple(replace(l, ports=float(ports))
                                     for l in links))


# ---------------------------------------------------------------------------
# hierarchical fabric: the cluster-scale generalization of ``Link``
#
# An ``SoCTopology`` models the devices *inside* one SoC; a ``Fabric``
# models the interconnect hierarchy *between* accelerators at cluster
# scale.  Tiers are listed innermost-first — e.g. 4 accelerators per
# chip on ICI, 8 chips per node on board-level links, N nodes behind
# NIC/switch — and each tier is a (latency, bandwidth) pair.  A tier
# whose ``bandwidth``/``latency_s`` is ``None`` inherits the flat
# ``EngineConfig`` field named by ``TIER_FIELDS`` (the same inheritance
# convention ``Device``/``Link`` use), which is what lets the DSE layer
# treat fabric rates as continuous ``PARAM_FIELDS``.

TIER_NAMES: Tuple[str, ...] = ("ici", "node", "inter")

# tier name -> (EngineConfig bandwidth field, latency field)
TIER_FIELDS: Dict[str, Tuple[str, str]] = {
    "ici": ("ici_bw", "ici_lat_s"),
    "node": ("node_bw", "node_lat_s"),
    "inter": ("inter_bw", "inter_lat_s"),
}


@dataclass(frozen=True)
class FabricTier:
    """One level of the interconnect hierarchy.

    ``group_size`` is how many units of the tier below this tier groups
    (for the innermost tier: accelerators per group).  ``None`` rates
    inherit the flat ``EngineConfig`` fields for this tier name."""
    name: str
    group_size: int
    bandwidth: Optional[float] = None        # None -> EngineConfig field
    latency_s: Optional[float] = None        # None -> EngineConfig field


@dataclass(frozen=True)
class Fabric:
    """Hierarchical interconnect: tiers innermost-first.

    Accelerators are numbered 0..n_accel-1 in tier order, innermost
    fastest-varying: with tiers ``ici(4), node(8), inter(2)`` ranks
    0-3 share a chip, 0-31 share a node.  ``span_tier(members)`` gives
    the outermost tier a member set crosses — the bottleneck tier a flat
    collective over those members runs on.  ``lane(members, t)`` names
    the contended engine lane: collectives sharing a tier AND a leading
    member contend (same physical links); disjoint groups on the same
    tier proceed in parallel."""
    tiers: Tuple[FabricTier, ...]
    name: str = "fabric"

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if not self.tiers:
            raise ValueError("Fabric needs at least one tier")
        order = [TIER_NAMES.index(t.name) if t.name in TIER_NAMES else -1
                 for t in self.tiers]
        if -1 in order:
            bad = [t.name for t in self.tiers if t.name not in TIER_NAMES]
            raise ValueError(
                f"unknown fabric tier names {bad}; tiers are named from "
                f"{TIER_NAMES} (innermost-first)")
        if sorted(order) != order or len(set(order)) != len(order):
            raise ValueError(
                f"fabric tiers must be innermost-first in {TIER_NAMES} "
                f"order, got {[t.name for t in self.tiers]}")
        for t in self.tiers:
            if int(t.group_size) < 1:
                raise ValueError(
                    f"tier {t.name!r} group_size must be >= 1, "
                    f"got {t.group_size}")

    # -- construction -------------------------------------------------------

    @classmethod
    def single_tier(cls, n_accel: int, *, bandwidth: Optional[float] = None,
                    latency_s: Optional[float] = None,
                    name: str = "soc") -> "Fabric":
        """One flat ICI tier over ``n_accel`` accelerators — the fabric
        equivalent of today's single shared collective lane (bit-identical
        to it at the default zero ICI latency)."""
        return cls(tiers=(FabricTier("ici", max(int(n_accel), 1),
                                     bandwidth=bandwidth,
                                     latency_s=latency_s),),
                   name=name)

    @classmethod
    def cluster(cls, n_accel: int, *, accels_per_chip: int = 4,
                chips_per_node: int = 8, name: str = "") -> "Fabric":
        """A fabric covering ``n_accel`` accelerators with the canonical
        three tiers, sized bottom-up: ICI groups of ``accels_per_chip``,
        board-level node groups of ``chips_per_node`` chips, and as many
        NIC/switch-connected nodes as it takes to cover ``n_accel``.
        Small counts drop the unused outer tiers."""
        n = max(int(n_accel), 1)
        if n <= accels_per_chip:
            tiers = (FabricTier("ici", n),)
        elif n <= accels_per_chip * chips_per_node:
            tiers = (FabricTier("ici", accels_per_chip),
                     FabricTier("node", -(-n // accels_per_chip)))
        else:
            per_node = accels_per_chip * chips_per_node
            tiers = (FabricTier("ici", accels_per_chip),
                     FabricTier("node", chips_per_node),
                     FabricTier("inter", -(-n // per_node)))
        return cls(tiers=tiers, name=name or f"{n}accel-cluster")

    # -- queries ------------------------------------------------------------

    @property
    def n_accel(self) -> int:
        out = 1
        for t in self.tiers:
            out *= int(t.group_size)
        return out

    def leaves_per_group(self) -> Tuple[int, ...]:
        """Cumulative products: ``leaves_per_group()[t]`` accelerators
        form one group at tier ``t``."""
        out, acc = [], 1
        for t in self.tiers:
            acc *= int(t.group_size)
            out.append(acc)
        return tuple(out)

    def span_tier(self, members: Sequence[int]) -> int:
        """Index of the outermost tier ``members`` crosses: the smallest
        ``t`` with every member in the same tier-``t`` group (the whole
        fabric is one group at the top tier)."""
        ms = [int(m) for m in members]
        if not ms:
            raise ValueError("span_tier needs at least one member")
        if max(ms) >= self.n_accel or min(ms) < 0:
            raise ValueError(
                f"members {min(ms)}..{max(ms)} out of range for "
                f"{self.n_accel}-accelerator fabric")
        for t, per in enumerate(self.leaves_per_group()):
            if all(m // per == ms[0] // per for m in ms):
                return t
        return len(self.tiers) - 1

    def lane(self, members: Sequence[int],
             tier_idx: Optional[int] = None) -> str:
        """Engine lane name for a collective over ``members``:
        ``"<tier>:<min member>"``.  Same tier + same leading member =>
        same physical links => contention; disjoint groups get distinct
        lanes and run in parallel."""
        t = self.span_tier(members) if tier_idx is None else int(tier_idx)
        return f"{self.tiers[t].name}:{min(int(m) for m in members)}"

    def has_overrides(self) -> bool:
        """Whether any tier pins an explicit rate (instead of inheriting
        the flat config fields the analytic model vectorizes over)."""
        return any(t.bandwidth is not None or t.latency_s is not None
                   for t in self.tiers)

    def describe(self) -> str:
        """Compact label like ``4ici x 8node x 2inter``."""
        return " x ".join(f"{t.group_size}{t.name}" for t in self.tiers)


def resolve_tier_params(config, tier: str) -> Tuple[float, float]:
    """(latency_s, bandwidth) the engine charges per hop on ``tier``.

    An explicit rate on the matching ``config.fabric`` tier wins; ``None``
    falls back to the flat ``EngineConfig`` fields named by
    ``TIER_FIELDS`` — the same inheritance convention as ``Device`` and
    ``Link``, and what keeps fabric rates inside the continuous
    ``PARAM_FIELDS`` design vector."""
    if tier not in TIER_FIELDS:
        raise ValueError(
            f"unknown fabric tier {tier!r}; tiers are named from "
            f"{TIER_NAMES}")
    bw_field, lat_field = TIER_FIELDS[tier]
    bw = float(getattr(config, bw_field))
    lat = float(getattr(config, lat_field))
    fab = getattr(config, "fabric", None)
    if fab is not None:
        for t in fab.tiers:
            if t.name == tier:
                if t.bandwidth is not None:
                    bw = float(t.bandwidth)
                if t.latency_s is not None:
                    lat = float(t.latency_s)
                break
    return lat, bw


def tco_per_step(n_accel: int, step_time_s: float,
                 energy_j: float) -> float:
    """Amortized USD cost of one training step on ``n_accel``
    accelerators: straight-line capex over ``ACCEL_AMORT_S`` plus energy
    at ``USD_PER_KWH``.  The TCO column of the cluster sweeps."""
    capex = n_accel * ACCEL_COST_USD / ACCEL_AMORT_S * step_time_s
    energy = energy_j / 3.6e6 * USD_PER_KWH
    return capex + energy
