"""Hardware constants shared by every cost path (TPU v5e, per assignment).

Single home for the numbers that used to be re-declared across
``core/simulator.py``, ``core/interfaces.py`` and ``core/tiling.py``;
those modules re-export them for backward compatibility.
"""

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
VMEM_BW = 11e12              # effective on-chip bandwidth
HOST_OVERHEAD_S = 50e-6      # per-step launch/framework floor (host runtime)
