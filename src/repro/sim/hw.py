"""Hardware model: canonical constants + the SoC topology layer.

The constants (TPU v5e, per assignment) are the single home for numbers
that used to be re-declared across ``core/simulator.py``,
``core/interfaces.py`` and ``core/tiling.py``; those modules re-export
them for backward compatibility.

On top of the constants sits the **topology model**: a ``Device`` is one
execution resource on the SoC (a CPU core cluster, an accelerator, a
DSP), a ``Link`` is a shared data-movement resource (the HBM port pool,
an ACP/DMA path), and an ``SoCTopology`` composes them.  SMAUG's case
studies vary exactly this object — how many accelerators share how many
memory ports, and which device runs the camera frontend — so the engine
(``repro.sim.engine``) takes an ``EngineConfig.topology`` and schedules
every ``CostedOp`` onto the device matching its ``device_class``,
charging its traffic to that device's link.

Inheritance convention: every ``Device``/``Link`` field that is ``None``
falls back to the corresponding flat ``EngineConfig`` field, so the
*homogeneous expansion* of a legacy config (``n_workers`` identical
accelerators on one shared link) is ``SoCTopology.homogeneous(n)`` —
and is bit-identical to the pre-topology engine by construction
(asserted in ``tests/test_engine_equivalence.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
VMEM_BW = 11e12              # effective on-chip bandwidth
HOST_OVERHEAD_S = 50e-6      # per-step launch/framework floor (host runtime)

# device kinds with modeled semantics; ``kind`` is open-ended (any string
# works as a placement class), these are the conventional ones
DEVICE_KINDS = ("cpu", "accel", "dsp")


@dataclass(frozen=True)
class Device:
    """One execution resource on the SoC.

    ``kind`` is the placement class ``CostedOp.device_class`` matches
    against (``cpu`` | ``accel`` | ``dsp`` by convention).  Every
    ``None`` field inherits the flat ``EngineConfig`` value, so a bare
    ``Device("acc0")`` is exactly one of today's workers."""
    name: str
    kind: str = "accel"
    peak_flops: Optional[float] = None       # None -> EngineConfig.peak_flops
    datapath_scale: Optional[float] = None   # None -> EngineConfig value
    interface: Optional[str] = None          # None -> EngineConfig.interface
    hbm_bw: Optional[float] = None           # None -> link bw -> EngineConfig
    vmem_bw: Optional[float] = None          # None -> EngineConfig.vmem_bw
    link: Optional[str] = None               # Link name; None -> first link


@dataclass(frozen=True)
class Link:
    """A shared data-movement resource (e.g. the HBM port pool).

    ``ports`` has the engine's contention semantics: active transfers on
    this link beyond ``ports`` share bandwidth (0 = uncontended,
    fractional values model a link narrower than one device's demand).
    ``bandwidth`` overrides the per-byte rate for devices on this link
    (``None`` inherits ``EngineConfig.hbm_bw``)."""
    name: str
    bandwidth: Optional[float] = None        # None -> EngineConfig.hbm_bw
    ports: Optional[float] = None            # None -> EngineConfig.hbm_ports


_DEFAULT_LINK = Link("shared")


@dataclass(frozen=True)
class SoCTopology:
    """Devices + links: the heterogeneous SoC the engine schedules onto.

    A topology with no ``links`` declared has one implicit shared link
    inheriting every ``EngineConfig`` value — today's single HBM port
    pool.  Ops are placed on the devices whose ``kind`` equals their
    ``device_class``; a class with no matching device falls back to the
    accelerators (and then to every device), so programs tagged for a
    richer SoC still run on a smaller one."""
    devices: Tuple[Device, ...]
    links: Tuple[Link, ...] = ()
    name: str = "soc"

    def __post_init__(self):
        object.__setattr__(self, "devices", tuple(self.devices))
        object.__setattr__(self, "links", tuple(self.links))
        if not self.devices:
            raise ValueError("SoCTopology needs at least one device")
        names = [d.name for d in self.devices]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate device names in topology: {names}")
        lnames = [l.name for l in self.links]
        if len(set(lnames)) != len(lnames):
            raise ValueError(f"duplicate link names in topology: {lnames}")
        known = set(lnames)
        for d in self.devices:
            if d.link is not None and d.link not in known:
                raise ValueError(
                    f"device {d.name!r} references unknown link {d.link!r}")

    # -- construction -------------------------------------------------------

    @classmethod
    def homogeneous(cls, n_workers: int, name: str = "") -> "SoCTopology":
        """The homogeneous expansion of a flat config: ``n_workers``
        identical accelerators (every field inherited) on one implicit
        shared link — bit-identical to the pre-topology engine.

        Memoized on the worker count (every ``run()`` of a flat config
        resolves one, and the frozen instances are safely shareable), so
        small-program runs don't pay device construction + validation."""
        n = max(int(n_workers), 1)
        if name:
            return cls(devices=tuple(Device(f"acc{i}") for i in range(n)),
                       name=name)
        return _homogeneous_cached(n)

    # -- queries ------------------------------------------------------------

    def devices_of(self, kind: str) -> Tuple[Device, ...]:
        return tuple(d for d in self.devices if d.kind == kind)

    @property
    def n_accel(self) -> int:
        return sum(1 for d in self.devices if d.kind == "accel")

    def kind_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.devices:
            out[d.kind] = out.get(d.kind, 0) + 1
        return out

    def describe(self) -> str:
        """Compact label like ``1cpu+4accel`` (device-order stable)."""
        counts = self.kind_counts()
        return "+".join(f"{c}{k}" for k, c in counts.items())

    def candidate_indices(self, device_class: str) -> Tuple[int, ...]:
        """Device indices an op of ``device_class`` may be placed on:
        exact kind match, else the accelerators, else every device."""
        idx = tuple(i for i, d in enumerate(self.devices)
                    if d.kind == device_class)
        if not idx:
            idx = tuple(i for i, d in enumerate(self.devices)
                        if d.kind == "accel")
        if not idx:
            idx = tuple(range(len(self.devices)))
        return idx

    def link_for(self, device: Device) -> Link:
        """The link this device's transfers traverse (declared by name,
        else the topology's first link, else the implicit shared one)."""
        if device.link is not None:
            for l in self.links:
                if l.name == device.link:
                    return l
        return self.links[0] if self.links else _DEFAULT_LINK


@lru_cache(maxsize=128)
def _homogeneous_cached(n: int) -> SoCTopology:
    return SoCTopology(devices=tuple(Device(f"acc{i}") for i in range(n)),
                       name=f"{n}accel")


# ---------------------------------------------------------------------------
# continuous hardware-parameter vector <-> EngineConfig mapping
#
# The analytic cost model (``repro.sim.costmodel``) and the DSE layer
# (``sweep.batched`` / ``sweep.optimize``) treat a design point as a flat
# float vector over these fields; everything else on the config
# (interface choice, energy constants, tile thresholds) is categorical
# and stays fixed within a batch.  ``host_threads``/``hbm_ports`` are
# kept continuous here — the engine only ever divides by them, so a
# fractional value is a perfectly well-defined (if physically idealized)
# design point, and keeping them continuous is what makes the gradient
# path smooth.

PARAM_FIELDS: Tuple[str, ...] = (
    "peak_flops", "datapath_scale", "hbm_bw", "vmem_bw", "ici_bw",
    "hbm_ports", "host_dispatch_s", "host_bw", "host_threads")

ParamsLike = Union[Mapping[str, float], Sequence[float]]


def params_from_config(config) -> Tuple[float, ...]:
    """The ``PARAM_FIELDS`` vector of an ``EngineConfig``-like object (any
    object carrying the nine continuous fields), as plain floats in field
    order."""
    return tuple(float(getattr(config, f)) for f in PARAM_FIELDS)


def params_dict(params: ParamsLike) -> Dict[str, float]:
    """Normalize a params mapping/sequence to a ``{field: float}`` dict
    (sequences must be full-length and are zipped against PARAM_FIELDS)."""
    if isinstance(params, Mapping):
        unknown = set(params) - set(PARAM_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown hardware parameters {sorted(unknown)}; "
                f"continuous fields are {PARAM_FIELDS}")
        return {k: float(v) for k, v in params.items()}
    vals = list(params)
    if len(vals) != len(PARAM_FIELDS):
        raise ValueError(
            f"expected {len(PARAM_FIELDS)} values in PARAM_FIELDS order, "
            f"got {len(vals)}")
    return {k: float(v) for k, v in zip(PARAM_FIELDS, vals)}


def apply_params(config, params: ParamsLike):
    """A copy of ``config`` with the given continuous fields installed.

    ``params`` is either a ``{field: value}`` mapping (partial is fine)
    or a full vector in ``PARAM_FIELDS`` order.  Values stay floats —
    see the module note on continuous ``host_threads``/``hbm_ports`` —
    so the exact engine prices precisely the point the analytic model
    evaluated.  Explicit per-device/per-link overrides in
    ``config.topology`` are NOT rewritten (the flat fields are only
    inheritance defaults there); use ``with_ports`` for the port study."""
    return replace(config, **params_dict(params))


def with_ports(topo: SoCTopology, ports: float) -> SoCTopology:
    """A copy of ``topo`` with every link's ``ports`` set to ``ports``
    (an implicit shared link is made explicit first) — the knob the
    Fig-13-style port studies turn."""
    links = topo.links if topo.links else (_DEFAULT_LINK,)
    return replace(topo, links=tuple(replace(l, ports=float(ports))
                                     for l in links))
