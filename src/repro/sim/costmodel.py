"""Vectorized analytic cost model over continuous hardware parameters.

The event engine prices a program one op-event at a time; its linear-chain
fast path already showed that on a chain the whole schedule is a prefix
sum of per-op (host, transfer, compute, collective) terms.  This module
factors those per-op terms out of ``engine._run_chain`` into pure
functions of a **continuous hardware-parameter vector**
(``hw.PARAM_FIELDS``: peak_flops, datapath_scale, hbm/vmem/ici bandwidth,
hbm_ports, host_dispatch_s, host_bw, host_threads) so that

  * the engine's chain fast path calls the SAME functions with scalar
    parameters — extraction changed no priced number (asserted by
    ``tests/test_engine_equivalence.py`` passing unmodified); and
  * a whole design-point batch evaluates at once: an (B, 9) parameter
    matrix broadcast against the (m,) per-op arrays gives a (B, 4m)
    interleaved term matrix whose row-wise ``cumsum`` ends are the B
    makespans — thousands of design points per second instead of one
    event-loop run per config (``BENCH_dse.json``).

Exactness contract:

  * **chain programs** (``from_hlo`` macro-ops, token-by-token decode,
    serving/training single-stage lowerings — where the huge sweeps
    live): the numpy backend is **bit-identical** to ``engine.run``.
    ``np.cumsum`` performs the same strict left-to-right IEEE additions
    as the event loop's ``itertools.accumulate`` (numpy's running sum is
    sequential; only full reductions re-associate).
  * **DAG programs**: the model returns a certified bracket
    ``lower <= exact <= upper``.  ``lower`` is the max of four relaxations
    (critical path with every transfer at its uncontended factor,
    aggregate device work over the worker count, the serial host lane,
    the serial ICI lane); ``upper`` charges every op serially with every
    transfer at the worst contention factor ``max(1, n_workers/ports)``.
    The bracket is deliberately conservative — it never flakes — and the
    exact engine stays the verifier of record (``sweep.batched`` /
    ``sweep.optimize`` re-run their winners through ``engine.run``).

Backends: numpy by default (``repro.sim`` stays jax-free, mirroring
``repro.serve``'s lazy-load convention); ``backend="jax"`` jits+vmaps the
same term functions and exposes analytic gradients for
``sweep.optimize``.  The jax backend may re-associate float additions, so
it promises ``allclose``, not bit-equality; it is chain-only (the DAG
critical-path recurrence would unroll into the jaxpr).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.energy import EnergyModel
from repro.core.interfaces import DMA_LAUNCH_S, FLUSH_PER_BYTE
from repro.sim import backends as _backends
from repro.sim import hw
from repro.sim.hw import PARAM_FIELDS

__all__ = ["CHAIN_INTERFACES", "ChainParams", "CostModel", "OpArrays",
           "Unsupported", "chain_params_for", "chain_terms", "interleave",
           "op_arrays", "relaxation_err"]

# interfaces the analytic term functions mirror exactly; a custom
# interface registered into engine.INTERFACES falls back to the event loop
CHAIN_INTERFACES = frozenset({"hbm", "ideal", "dma", "acp"})


class Unsupported(ValueError):
    """This (program, config) pair has no analytic model — heterogeneous
    cost signatures, a custom interface/energy model, or a jax request
    the backend can't honor.  The event engine still simulates it."""


# ---------------------------------------------------------------------------
# per-op arrays: the program side of the cost terms (parameter-free)


@dataclasses.dataclass(frozen=True)
class OpArrays:
    """Columnar view of a program's per-op cost inputs (float64)."""
    m: int
    flops: np.ndarray
    dot: np.ndarray
    nb: np.ndarray          # bytes_in + bytes_out
    coll: np.ndarray
    has_dur: np.ndarray
    dur: np.ndarray
    has_tov: np.ndarray     # explicit transfer_s override
    tov: np.ndarray
    # fabric collectives: per-op tier code (hw.TIER_NAMES index, -1 = not
    # a fabric hop), latency-hop multiplier, and a factorized lane id for
    # the per-lane DAG relaxation.  ``any_tier`` gates the tier math so
    # legacy programs run the exact pre-fabric operations.
    tcode: np.ndarray = None
    hops: np.ndarray = None
    lane_code: np.ndarray = None
    n_lanes: int = 1
    any_tier: bool = False


def op_arrays(ops: Sequence) -> OpArrays:
    """Extract the per-op cost columns of a sequence of ``CostedOp``s —
    exactly the arrays the chain fast path hoists."""
    tcodes = [(-1 if op.tier is None else hw.TIER_NAMES.index(op.tier))
              for op in ops]
    lanes: Dict[str, int] = {}
    lane_code = []
    for op in ops:
        lane_code.append(lanes.setdefault(op.lane, len(lanes)))
    return OpArrays(
        m=len(ops),
        flops=np.array([op.flops for op in ops], dtype=np.float64),
        dot=np.array([op.dot_flops for op in ops], dtype=np.float64),
        nb=np.array([op.bytes_in + op.bytes_out for op in ops],
                    dtype=np.float64),
        coll=np.array([op.collective_bytes for op in ops],
                      dtype=np.float64),
        has_dur=np.array([op.duration_s is not None for op in ops],
                         dtype=bool),
        dur=np.array([op.duration_s or 0.0 for op in ops],
                     dtype=np.float64),
        has_tov=np.array([op.transfer_s is not None for op in ops],
                         dtype=bool),
        tov=np.array([op.transfer_s or 0.0 for op in ops],
                     dtype=np.float64),
        tcode=np.array(tcodes, dtype=np.int64),
        hops=np.array([op.hops for op in ops], dtype=np.float64),
        lane_code=np.array(lane_code, dtype=np.int64),
        n_lanes=max(len(lanes), 1),
        any_tier=any(c >= 0 for c in tcodes))


# ---------------------------------------------------------------------------
# the continuous parameter point (scalars for the engine, (B,1) columns
# for a batch, 0-d tracers under jax)


@dataclasses.dataclass(frozen=True)
class ChainParams:
    """One hardware design point (or a broadcastable batch of them).

    The ``hw.PARAM_FIELDS`` are continuous; the rest are the
    categorical/static knobs that stay fixed within a batch."""
    peak_flops: object
    datapath_scale: object
    hbm_bw: object
    vmem_bw: object
    ici_bw: object
    hbm_ports: object
    host_dispatch_s: object
    host_bw: object
    host_threads: object
    # fabric tier rates (continuous PARAM_FIELDS like the rest; the tier
    # named "ici" shares ``ici_bw`` with the legacy collective lane)
    ici_lat_s: object
    node_bw: object
    node_lat_s: object
    inter_bw: object
    inter_lat_s: object
    # statics
    interface: str
    overlap: bool
    vmem_resident_bytes: float
    dma_transfer_bytes: float
    pj_hbm: float
    pj_vmem: float
    pj_host: float

    @classmethod
    def from_engine(cls, config, eff, ports) -> "ChainParams":
        """The engine chain fast path's exact scalar parameters: device
        terms at the resolved device config ``eff``, host/ICI terms at
        the flat ``config`` — the same split ``_run_chain`` used."""
        em = config.energy
        return cls(peak_flops=eff.peak_flops,
                   datapath_scale=eff.datapath_scale,
                   hbm_bw=eff.hbm_bw, vmem_bw=eff.vmem_bw,
                   ici_bw=config.ici_bw, hbm_ports=ports,
                   host_dispatch_s=config.host_dispatch_s,
                   host_bw=config.host_bw,
                   host_threads=config.host_threads,
                   ici_lat_s=config.ici_lat_s,
                   node_bw=config.node_bw,
                   node_lat_s=config.node_lat_s,
                   inter_bw=config.inter_bw,
                   inter_lat_s=config.inter_lat_s,
                   interface=eff.interface, overlap=eff.overlap,
                   vmem_resident_bytes=eff.vmem_resident_bytes,
                   dma_transfer_bytes=eff.dma_transfer_bytes,
                   pj_hbm=em.pj_per_byte_hbm, pj_vmem=em.pj_per_byte_vmem,
                   pj_host=em.pj_per_byte_host)

    @classmethod
    def from_matrix(cls, P, statics: Dict, xp=np) -> "ChainParams":
        """(B, 9) parameter matrix -> (B, 1) columns that broadcast
        against the (m,) op arrays."""
        P = xp.asarray(P)
        cols = {f: P[:, i:i + 1] for i, f in enumerate(PARAM_FIELDS)}
        return cls(**cols, **statics)

    @classmethod
    def from_vector(cls, vec, statics: Dict) -> "ChainParams":
        """A single parameter vector (jax tracers welcome)."""
        cols = {f: vec[i] for i, f in enumerate(PARAM_FIELDS)}
        return cls(**cols, **statics)


@dataclasses.dataclass(frozen=True)
class ChainTerms:
    """Per-op cost terms at a parameter point — what the event loop (and
    its chain prefix sum) charges.  All arrays broadcast to the batch."""
    comp: object
    full: object            # full interface seconds (pre-overlap)
    expo: object            # exposed seconds, pre-contention
    xfer: object            # exposed * chain contention factor
    xe: object              # transfer energy (J)
    hc: object              # host dispatch + tiling term
    cdur: object            # collective seconds on the ICI lane
    factor: object          # chain contention factor max(1, 1/ports)
    has_h: object
    has_x: object
    has_c: object


def chain_terms(a: OpArrays, p: ChainParams, xp=np,
                comp=None) -> ChainTerms:
    """The hoisted per-op terms of ``engine._run_chain`` as a pure
    function of (op arrays, parameter point) — formulas, operation order
    and IEEE semantics identical to the scalar interface models in
    ``core.interfaces`` / ``core.energy``.  With ``xp=np`` and scalar
    parameters this IS the engine's chain fast path math; with (B, 1)
    columns it prices B design points at once; with ``xp=jax.numpy`` it
    is traceable and differentiable.

    ``comp`` overrides the roofline compute column with externally priced
    per-op seconds (``engine._run_chain`` passes the cost backend's
    ``op_time`` values, keeping the chain fast path bit-identical to the
    event loop under non-roofline backends)."""
    with np.errstate(divide="ignore", invalid="ignore"):
        if comp is None:
            comp = xp.where(a.has_dur, a.dur, a.flops / p.peak_flops)
        else:
            comp = xp.asarray(comp)

        nb = a.nb
        iface = p.interface
        if iface == "hbm":
            t_if = nb / p.hbm_bw
            e_if = (nb * p.pj_hbm) * 1e-12
        elif iface == "ideal":
            t_if = xp.zeros_like(nb)
            e_if = xp.zeros_like(nb)
        elif iface == "dma":
            n_tr = xp.maximum(1.0,
                              xp.floor_divide(nb, p.dma_transfer_bytes))
            t_if = (2 * nb / p.hbm_bw + n_tr * DMA_LAUNCH_S
                    + nb * FLUSH_PER_BYTE)
            e_if = ((2 * nb) * p.pj_hbm) * 1e-12 \
                + ((nb * 0.05) * p.pj_host) * 1e-12
        elif iface == "acp":
            res_frac = xp.where(nb < p.vmem_resident_bytes, 1.0, 0.5)
            spill = nb * (1.0 - res_frac)
            t_if = (nb * res_frac) / p.vmem_bw \
                + 2 * spill / p.hbm_bw
            e_if = ((2 * nb * res_frac) * p.pj_vmem) * 1e-12 \
                + ((2 * spill) * p.pj_hbm) * 1e-12
        else:
            raise Unsupported(f"no analytic model for interface {iface!r}")
        t_if = t_if / p.datapath_scale
        if p.overlap:
            expo_if = xp.maximum(t_if - a.dot / p.peak_flops, 0.0)
        else:
            expo_if = t_if

        zero_b = nb == 0.0
        full = xp.where(a.has_tov, a.tov, xp.where(zero_b, 0.0, t_if))
        expo = xp.where(a.has_tov, a.tov, xp.where(zero_b, 0.0, expo_if))
        xe = xp.where(a.has_tov, ((a.tov * p.hbm_bw) * p.pj_hbm) * 1e-12,
                      xp.where(zero_b, 0.0, e_if))

        # chain transfers never overlap -> every window sees live == 1
        ports = p.hbm_ports
        pos = ports > 0.0
        factor = xp.where(pos, xp.maximum(1.0, 1.0 / xp.where(pos, ports,
                                                              1.0)), 1.0)
        has_x = expo > 0.0
        xfer = xp.where(has_x, expo * factor, 0.0)

        # the engine branches on the scalar's truthiness (any nonzero
        # host_bw charges the tiling term), so mirror != 0, not > 0
        hb = p.host_bw
        nz = hb != 0.0
        hc = xp.where(nz,
                      p.host_dispatch_s + (nb / xp.where(nz, hb, 1.0))
                      / p.host_threads,
                      p.host_dispatch_s + xp.zeros_like(nb))
        has_h = hc > 0.0
        has_c = a.coll > 0.0
        cdur = xp.where(has_c, a.coll / p.ici_bw, 0.0)
        if a.any_tier:
            # fabric hops: lane-only ops priced hops*lat + bytes/bw at
            # their tier's rates; no host/compute charge.  Gated so
            # tier-free programs run the exact pre-fabric operations.
            is_t = a.tcode >= 0
            t0 = a.tcode == 0
            t1 = a.tcode == 1
            lat = xp.where(t0, p.ici_lat_s,
                           xp.where(t1, p.node_lat_s, p.inter_lat_s))
            bw = xp.where(t0, p.ici_bw,
                          xp.where(t1, p.node_bw, p.inter_bw))
            cdur = xp.where(is_t, a.hops * lat + a.coll / bw, cdur)
            has_c = is_t | has_c
            comp = xp.where(is_t, 0.0, comp)
            hc = xp.where(is_t, 0.0, hc)
            has_h = hc > 0.0
    return ChainTerms(comp=comp, full=full, expo=expo, xfer=xfer, xe=xe,
                      hc=hc, cdur=cdur, factor=factor, has_h=has_h,
                      has_x=has_x, has_c=has_c)


def chain_params_for(config, device_class: str = "accel") -> ChainParams:
    """The scalar :class:`ChainParams` point at which
    ``engine.chain_op_costs`` prices ops of ``device_class`` under
    ``config`` — device terms from the class's resolved reference device,
    host/ICI terms from the flat config.  Raises :class:`Unsupported` for
    interfaces outside :data:`CHAIN_INTERFACES` (custom interfaces keep
    going through the event-loop models)."""
    from repro.sim import engine as _engine
    fab = getattr(config, "fabric", None)
    if fab is not None and fab.has_overrides():
        raise Unsupported(
            "fabric carries explicit per-tier rate overrides; the analytic "
            "model prices tiers from the flat PARAM_FIELDS only")
    eff, ports = _engine._class_params(config, device_class)
    if eff.interface not in CHAIN_INTERFACES:
        raise Unsupported(f"interface {eff.interface!r} has no analytic "
                          "chain model")
    if not _backends.is_roofline(eff.cost_backend):
        raise Unsupported(
            "non-roofline cost backend: per-op compute has no analytic "
            "chain model; price through the exact engine")
    return ChainParams.from_engine(config, eff, ports)


def interleave(t: ChainTerms, xp=np):
    """The (..., 4m) interleaved (host, transfer, compute, collective)
    duration rows whose running sum is the chain schedule — entry order
    identical to the event loop's charge order."""
    parts = xp.stack([xp.where(t.has_h, t.hc, 0.0), t.xfer, t.comp,
                      t.cdur], axis=-1)
    return xp.reshape(parts, parts.shape[:-2] + (4 * parts.shape[-2],))


# ---------------------------------------------------------------------------
# program-side structure cache (arrays + chain flag + DAG order), keyed on
# program identity like sweep's lowering caches


_INFO_MAX = 32
_info_cache: "OrderedDict[int, tuple]" = OrderedDict()


def _program_info(program):
    key = id(program)
    hit = _info_cache.get(key)
    if hit is not None and hit[0] is program:
        _info_cache.move_to_end(key)
        return hit
    ops = program.ops
    arrays = op_arrays(ops)
    names = {op.name: i for i, op in enumerate(ops)}
    deps = tuple(tuple(names[d] for d in op.deps if d in names)
                 for op in ops)
    is_chain = len(names) == len(ops)
    prev = None
    for op in ops:
        if not is_chain:
            break
        if op.affinity is not None:
            is_chain = False
            break
        want = () if prev is None else (prev,)
        if tuple(op.deps) != want:
            is_chain = False
            break
        prev = op.name
    # Kahn topological order for the DAG critical-path recurrence
    n_wait = [len(d) for d in deps]
    consumers: List[List[int]] = [[] for _ in ops]
    for i, d in enumerate(deps):
        for j in d:
            consumers[j].append(i)
    queue = [i for i, w in enumerate(n_wait) if w == 0]
    order: List[int] = []
    for i in queue:
        order.append(i)
        for c in consumers[i]:
            n_wait[c] -= 1
            if n_wait[c] == 0:
                queue.append(c)
    info = (program, arrays, is_chain, deps,
            tuple(order) if len(order) == len(ops) else None)
    if len(_info_cache) >= _INFO_MAX:
        _info_cache.popitem(last=False)
    _info_cache[key] = info
    return info


# ---------------------------------------------------------------------------
# the model


_JAX_PROBE_WARNED = False


def _has_jax() -> bool:
    """True when jax imports cleanly.

    ``ModuleNotFoundError`` naming jax itself is the expected no-toolchain
    case and stays silent.  Anything else — a jaxlib/CUDA mismatch raising
    ``ImportError``/``RuntimeError``/``OSError``, or a missing transitive
    dependency — is a *broken* install, not an absent one: the model still
    degrades to numpy, but with a one-time ``RuntimeWarning`` naming the
    cause instead of swallowing it.  Exceptions outside those types
    propagate."""
    global _JAX_PROBE_WARNED
    try:
        import jax  # noqa: F401
        return True
    except ModuleNotFoundError as e:
        if e.name in ("jax", "jaxlib"):
            return False
        cause = e
    except (ImportError, RuntimeError, OSError) as e:
        cause = e
    if not _JAX_PROBE_WARNED:
        _JAX_PROBE_WARNED = True
        warnings.warn(
            f"jax import failed with {type(cause).__name__}: {cause} — "
            "the jax install looks broken (not merely absent); falling "
            "back to the numpy cost-model backend", RuntimeWarning,
            stacklevel=2)
    return False


class CostModel:
    """Analytic cost model of one program under one categorical config.

    ``makespans(P)`` prices an (B, 9) ``hw.PARAM_FIELDS`` matrix: exact
    (bit-identical to ``engine.run``) on chains, the certified lower
    bound on DAGs.  ``bounds(P)`` returns the (lower, upper) bracket.
    ``objective(space, ...)`` builds the z-space value/gradient pair
    ``sweep.optimize`` descends.  Raises ``Unsupported`` when the
    (program, config) pair has no analytic model — callers keep the
    event engine as the fallback/verifier.
    """

    def __init__(self, program, base_config=None, *, backend: str = "auto"):
        from repro.sim import engine   # lazy: engine lazily imports us too
        self.program = program
        base = base_config if base_config is not None \
            else engine.EngineConfig()
        self.base = base
        if type(base.energy) is not EnergyModel:
            raise Unsupported("custom EnergyModel subclass: the analytic "
                              "terms mirror the default model only")
        if base.fabric is not None and base.fabric.has_overrides():
            raise Unsupported(
                "fabric carries explicit per-tier rate overrides; the "
                "analytic model prices tiers from the flat PARAM_FIELDS "
                "only")
        topo = base.resolved_topology()
        res = engine._resolve(base, topo)
        if len(res.sig_cfgs) != 1 or len(res.ports_l) != 1:
            raise Unsupported(
                "heterogeneous topology: devices resolve to more than one "
                "cost signature or link; use the event engine")
        eff = res.sig_cfgs[0]
        if eff.interface not in CHAIN_INTERFACES:
            raise Unsupported(
                f"no analytic model for interface {eff.interface!r}")
        if not (_backends.is_roofline(base.cost_backend)
                and _backends.is_roofline(eff.cost_backend)):
            raise Unsupported(
                "non-roofline cost backend: per-op compute is priced by "
                "backend.op_time, outside the analytic chain terms; use "
                "the exact engine (sweep())")
        self._eff = eff
        self._ports = res.ports_l[0]
        self.n_workers = len(topo.devices)
        (_, self.arrays, self.is_chain, self._deps,
         self._order) = _program_info(program)
        em = base.energy
        self._statics = dict(
            interface=eff.interface, overlap=eff.overlap,
            vmem_resident_bytes=eff.vmem_resident_bytes,
            dma_transfer_bytes=eff.dma_transfer_bytes,
            pj_hbm=em.pj_per_byte_hbm, pj_vmem=em.pj_per_byte_vmem,
            pj_host=em.pj_per_byte_host)
        p0 = dict(zip(PARAM_FIELDS, hw.params_from_config(base)))
        p0.update(peak_flops=eff.peak_flops,
                  datapath_scale=eff.datapath_scale, hbm_bw=eff.hbm_bw,
                  vmem_bw=eff.vmem_bw, hbm_ports=float(self._ports))
        self.params0 = np.array([p0[f] for f in PARAM_FIELDS],
                                dtype=np.float64)
        if backend == "auto":
            backend = "jax" if (self.is_chain and _has_jax()) else "numpy"
        elif backend == "jax":
            if not self.is_chain:
                raise Unsupported("jax backend is chain-only (the DAG "
                                  "critical-path recurrence would unroll "
                                  "into the jaxpr)")
            if not _has_jax():
                raise Unsupported("jax is not importable here")
        elif backend != "numpy":
            raise ValueError(f"unknown backend {backend!r}; "
                             "one of numpy|jax|auto")
        self.backend = backend
        self._jax_one = None
        self._jax_ms = None

    # -- evaluation ---------------------------------------------------------

    def _as_matrix(self, P) -> np.ndarray:
        P = np.asarray(P, dtype=np.float64)
        if P.ndim == 1:
            P = P[None, :]
        if P.ndim != 2 or P.shape[1] != len(PARAM_FIELDS):
            raise ValueError(
                f"expected an (B, {len(PARAM_FIELDS)}) matrix over "
                f"hw.PARAM_FIELDS, got shape {P.shape}")
        return P

    def makespans(self, P) -> np.ndarray:
        """(B,) makespans: exact on chains (numpy backend bit-identical
        to ``engine.run``; jax allclose), the lower bound on DAGs."""
        P = self._as_matrix(P)
        if self.is_chain:
            if self.backend == "jax":
                return np.asarray(self._jax_makespans()(P))
            return self._chain_numpy(P)
        return self._dag_bounds(P)[0]

    def bounds(self, P, n_workers=None) -> Tuple[np.ndarray, np.ndarray]:
        """The certified (lower, upper) makespan bracket; on chains both
        sides are the exact value."""
        P = self._as_matrix(P)
        if self.is_chain:
            ms = (np.asarray(self._jax_makespans()(P))
                  if self.backend == "jax" else self._chain_numpy(P))
            return ms, ms.copy()
        return self._dag_bounds(P, n_workers=n_workers)

    def makespan(self) -> float:
        """The model's value at the base config's own parameter point
        (exact on chains, lower bound on DAGs) — numpy path, so chain
        values are bit-identical to ``engine.run(program, base)``."""
        if self.is_chain:
            return float(self._chain_numpy(self.params0[None, :])[0])
        return float(self._dag_bounds(self.params0[None, :])[0][0])

    def _chain_numpy(self, P: np.ndarray) -> np.ndarray:
        m = self.arrays.m
        B = len(P)
        if m == 0:
            return np.zeros(B, dtype=np.float64)
        out = np.empty(B, dtype=np.float64)
        # bound the (chunk, 4m) scratch to ~16 MiB
        chunk = max(1, int(2_000_000 // max(1, 4 * m)))
        for s in range(0, B, chunk):
            p = ChainParams.from_matrix(P[s:s + chunk], self._statics)
            flat = interleave(chain_terms(self.arrays, p))
            # row-wise cumsum adds strictly left-to-right: the last
            # column IS the event loop's accumulate() total, bit-for-bit
            out[s:s + chunk] = np.cumsum(flat, axis=-1)[:, -1]
        return out

    def _dag_bounds(self, P: np.ndarray, n_workers=None
                    ) -> Tuple[np.ndarray, np.ndarray]:
        if self._order is None:
            raise Unsupported("dependency cycle in program")
        m = self.arrays.m
        B = len(P)
        if m == 0:
            z = np.zeros(B, dtype=np.float64)
            return z, z.copy()
        p = ChainParams.from_matrix(P, self._statics)
        t = chain_terms(self.arrays, p)
        hcz = np.where(t.has_h, t.hc, 0.0)
        v_min = hcz + t.xfer + t.comp + t.cdur          # (B, m)
        # lower bound: max of four relaxations, each of which the event
        # loop provably cannot beat (done[op] >= done[dep] + its charges;
        # per-device, host-lane and ICI-lane work all fit inside the span)
        done = np.zeros((B, m), dtype=np.float64)
        for i in self._order:
            d = self._deps[i]
            if d:
                ready = done[:, d[0]]
                for j in d[1:]:
                    ready = np.maximum(ready, done[:, j])
                done[:, i] = ready + v_min[:, i]
            else:
                done[:, i] = v_min[:, i]
        crit = done.max(axis=-1)
        nw = (np.full(B, float(self.n_workers))
              if n_workers is None
              else np.asarray(n_workers, dtype=np.float64))
        work = np.sum(t.xfer + t.comp, axis=-1) / nw
        # collective relaxation: each LANE is serial, but distinct fabric
        # lanes run in parallel — the busiest lane bounds the span (the
        # single-lane case is the legacy serial-ICI sum, bit for bit)
        a = self.arrays
        if a.n_lanes > 1:
            coll_lane = np.zeros(B, dtype=np.float64)
            for l in range(a.n_lanes):
                mask = a.lane_code == l
                if mask.any():
                    coll_lane = np.maximum(
                        coll_lane, np.sum(t.cdur[:, mask], axis=-1))
        else:
            coll_lane = np.sum(t.cdur, axis=-1)
        lower = np.maximum(
            np.maximum(crit, work),
            np.maximum(np.sum(hcz, axis=-1), coll_lane))
        # upper bound: serial sum with every transfer at the worst-case
        # contention factor (live transfers never exceed the devices on
        # the link, so factor <= max(1, n_workers/ports))
        ports = np.asarray(p.hbm_ports)[:, 0]
        pos = ports > 0.0
        fmax = np.where(
            pos, np.maximum(1.0, np.minimum(nw, float(m))
                            / np.where(pos, ports, 1.0)), 1.0)
        upper = np.sum(hcz + t.expo * fmax[:, None] + t.comp + t.cdur,
                       axis=-1)
        return lower, upper

    # -- jax backend --------------------------------------------------------

    def _jax_chain_one(self) -> Callable:
        if self._jax_one is None:
            import jax.numpy as jnp
            a = self.arrays
            ja = OpArrays(m=a.m, flops=jnp.asarray(a.flops),
                          dot=jnp.asarray(a.dot), nb=jnp.asarray(a.nb),
                          coll=jnp.asarray(a.coll),
                          has_dur=jnp.asarray(a.has_dur),
                          dur=jnp.asarray(a.dur),
                          has_tov=jnp.asarray(a.has_tov),
                          tov=jnp.asarray(a.tov),
                          tcode=jnp.asarray(a.tcode),
                          hops=jnp.asarray(a.hops),
                          lane_code=a.lane_code, n_lanes=a.n_lanes,
                          any_tier=a.any_tier)
            statics = self._statics

            def one(pvec):
                p = ChainParams.from_vector(pvec, statics)
                # jnp.sum of an empty flat row is 0.0, like the loop
                return jnp.sum(interleave(chain_terms(ja, p, xp=jnp),
                                          xp=jnp))
            self._jax_one = one
        return self._jax_one

    def _jax_makespans(self) -> Callable:
        if self._jax_ms is None:
            import jax
            self._jax_ms = jax.jit(jax.vmap(self._jax_chain_one()))
        return self._jax_ms

    # -- design-space objective (z-space in [0, 1]^d) -----------------------

    def config_for(self, params) -> "object":
        """The exact-engine config at a parameter point (only the given
        fields are replaced on the base config)."""
        return hw.apply_params(self.base, params)

    def objective(self, space: Dict[str, Tuple[float, float]], *,
                  target_s: Optional[float] = None,
                  cost: Optional[Callable] = None) -> "Objective":
        """Build the normalized design-space objective.

        ``space`` maps ``hw.PARAM_FIELDS`` names to (lo, hi) ranges; a
        point is a z-vector in [0, 1]^d mapped geometrically onto each
        range (linearly when lo <= 0).  Without ``target_s`` the
        objective is ``log(makespan)`` (scale-free descent direction);
        with it, ``cost + 100 * relu(makespan/target - 1)^2`` where
        ``cost`` defaults to ``mean(z)`` (bigger hardware = costlier) —
        "the cheapest design meeting the latency target".  Gradients are
        analytic (jit+vmap+grad) on the jax backend, batched central
        differences on numpy; a custom ``cost`` callable (taking the
        (B, 9) matrix) always uses finite differences."""
        names = list(space)
        for k in names:
            if k not in PARAM_FIELDS:
                raise ValueError(f"unknown space field {k!r}; "
                                 f"one of {PARAM_FIELDS}")
        dims = [PARAM_FIELDS.index(k) for k in names]
        lo = np.array([float(space[k][0]) for k in names])
        hi = np.array([float(space[k][1]) for k in names])
        if np.any(hi < lo):
            raise ValueError("space ranges need hi >= lo")
        geo = lo > 0.0
        ratio = np.where(geo, hi / np.where(geo, lo, 1.0), 1.0)

        def to_values(Z, xp=np):
            return xp.where(geo, lo * ratio ** Z, lo + (hi - lo) * Z)

        def to_params(Z) -> np.ndarray:
            Z = np.atleast_2d(np.asarray(Z, dtype=np.float64))
            P = np.tile(self.params0, (len(Z), 1))
            P[:, dims] = to_values(Z)
            return P

        def value(Z) -> np.ndarray:
            Z = np.atleast_2d(np.asarray(Z, dtype=np.float64))
            ms = self.makespans(to_params(Z))
            if target_s is None:
                return np.log(np.maximum(ms, 1e-300))
            c = cost(to_params(Z)) if cost is not None else Z.mean(axis=1)
            return c + 100.0 * np.maximum(ms / target_s - 1.0, 0.0) ** 2

        use_jax = (self.backend == "jax" and self.is_chain
                   and cost is None)
        if use_jax:
            import jax
            import jax.numpy as jnp
            one = self._jax_chain_one()
            p0 = jnp.asarray(self.params0)
            jdims = jnp.asarray(dims)
            jlo, jratio, jhi = (jnp.asarray(lo), jnp.asarray(ratio),
                                jnp.asarray(hi))
            jgeo = jnp.asarray(geo)

            def obj_one(zvec):
                vals = jnp.where(jgeo, jlo * jratio ** zvec,
                                 jlo + (jhi - jlo) * zvec)
                ms = one(p0.at[jdims].set(vals))
                if target_s is None:
                    return jnp.log(jnp.maximum(ms, 1e-300))
                return (jnp.mean(zvec)
                        + 100.0 * jnp.maximum(ms / target_s - 1.0,
                                              0.0) ** 2)
            jgrad = jax.jit(jax.vmap(jax.grad(obj_one)))

            def grad(Z) -> np.ndarray:
                Z = np.atleast_2d(np.asarray(Z, dtype=np.float64))
                return np.asarray(jgrad(Z))
            backend = "jax"
        else:
            def grad(Z) -> np.ndarray:
                """Batched central differences: one vectorized value()
                call prices the whole 2*d*S stencil."""
                Z = np.atleast_2d(np.asarray(Z, dtype=np.float64))
                S, d = Z.shape
                h = 1e-4
                E = np.eye(d) * h
                stack = np.concatenate([
                    (Z[None, :, :] + E[:, None, :]).reshape(-1, d),
                    (Z[None, :, :] - E[:, None, :]).reshape(-1, d)])
                v = value(np.clip(stack, 0.0, 1.0))
                vp = v[:d * S].reshape(d, S)
                vm = v[d * S:].reshape(d, S)
                return ((vp - vm) / (2.0 * h)).T
            backend = "numpy"
        return Objective(names=tuple(names), dims=tuple(dims),
                         lo=lo, hi=hi, value=value, grad=grad,
                         to_params=to_params, backend=backend,
                         target_s=target_s)


@dataclasses.dataclass(frozen=True)
class Objective:
    """The z-space objective ``sweep.optimize`` descends."""
    names: Tuple[str, ...]
    dims: Tuple[int, ...]
    lo: np.ndarray
    hi: np.ndarray
    value: Callable         # (S, d) -> (S,)
    grad: Callable          # (S, d) -> (S, d)
    to_params: Callable     # (S, d) -> (S, 9)
    backend: str
    target_s: Optional[float]


# ---------------------------------------------------------------------------
# model-fidelity probe for sweep.as_records


def relaxation_err(result) -> Optional[float]:
    """Relative error of the analytic model against an exact
    ``EngineResult``: 0.0 on chains (the model IS the fast path),
    ``(lower - exact) / exact`` (<= 0) on DAGs, ``None`` when the
    (program, config) pair has no analytic model."""
    try:
        model = CostModel(result.program, result.config, backend="numpy")
    except Unsupported:
        return None
    analytic = model.makespan()
    exact = result.makespan
    if not np.isfinite(analytic):
        return None
    if exact == 0.0:
        return 0.0 if analytic == 0.0 else None
    return (analytic - exact) / exact
