"""Pluggable per-op compute-cost backends.

The engine's native per-op compute charge is the roofline scalar
``flops / peak_flops`` (an explicit ``duration_s`` always wins).  This
module turns that charge into a strategy object behind the
:class:`CostBackend` protocol, so the microarchitecture detail level is
an ``EngineConfig``/``Device`` knob:

* :class:`RooflineBackend` — the engine's own math, extracted verbatim.
  ``EngineConfig.cost_backend=None`` (the default) *means* roofline and
  keeps every engine hot path on its original inline expression, so the
  default configuration is bit-identical to the pre-backend engine by
  construction (and asserted in ``tests/test_backends.py``).
* :class:`SystolicBackend` — SCALE-Sim-style PE-array timing: spatial
  utilization of a ``rows x cols`` array under the op's compute-tile
  shape, pipeline fill/drain exposed when SRAM double-buffering is off,
  and im2col staging traffic for convolution tiles.
* :class:`TableBackend` — interpolated lookup over measured samples;
  ``tools/calibrate.py`` fits one from the real Pallas kernels in
  ``repro/kernels/``.

Backends price *compute* only.  Transfer, host and collective terms stay
with the engine's interface models — a backend sees the resolved
effective config (``peak_flops``, ``hbm_bw``, ...) of the device the op
lands on and returns seconds.

Every backend here is a frozen dataclass, so configs carrying one stay
hashable (the engine's ``lru_cache`` resolution layers require this).
The analytic chain model (``costmodel.CostModel`` behind
``sweep.batched`` / ``sweep.optimize``) prices roofline only; configs
with a non-roofline backend raise ``costmodel.Unsupported`` there and
are priced exactly by the event engine via ``sweep()``.

The calibration helpers at the bottom (:func:`fit_linear_cost`,
:func:`mape`, :func:`table_from_samples`) are pure numpy — shared by
``tools/calibrate.py``, ``benchmarks/bench_calibration.py`` and the
tests, with no jax dependency.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property
from typing import Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class CostBackend(Protocol):
    """Prices one op's compute time on one resolved device config.

    ``op`` is a :class:`repro.sim.ir.CostedOp` (``flops``, optional
    ``duration_s`` override, optional ``tile``/``op_kind`` metadata);
    ``eff`` is the effective ``EngineConfig`` of the device the op runs
    on.  Implementations must honor ``op.duration_s`` when set — that is
    the engine's contract with the legacy TileTask lowering."""

    name: str

    def op_time(self, op, eff) -> float:
        ...


@dataclass(frozen=True)
class RooflineBackend:
    """The engine's native charge: ``flops / peak_flops``.

    Exists so a backend can be *named*; the engine treats
    ``cost_backend=None`` and an instance of this class identically (the
    ``op_time`` body below is textually the engine's inline expression,
    so even the explicit instance is bit-identical)."""

    name: str = "roofline"

    def op_time(self, op, eff) -> float:
        return (op.duration_s if op.duration_s is not None
                else op.flops / eff.peak_flops)


@dataclass(frozen=True)
class SystolicBackend:
    """SCALE-Sim-style output-stationary PE-array model.

    An op whose ``tile`` metadata names a ``(M, N, K)`` compute tile is
    priced at ``flops / (peak_flops * utilization)``:

    * **spatial** — the ``M x N`` output tile folds onto the
      ``rows x cols`` array in ``ceil(M/rows) * ceil(N/cols)`` passes;
      partially filled edge folds idle PEs, so utilization is
      ``(M / (ceil(M/rows)*rows)) * (N / (ceil(N/cols)*cols))`` — exactly
      1.0 when both dims are array-aligned.
    * **temporal** — with ``double_buffered`` SRAM (the default) operand
      staging overlaps the previous fold and only the steady-state ``K``
      beats count; without it each fold exposes the ``rows + cols - 2``
      pipeline fill/drain beats: ``K / (K + rows + cols - 2)``.

    Convolution tiles additionally pay im2col staging: the lowered
    ``M x K`` patch matrix re-reads each input element up to ``k*k``
    times, so traffic beyond the op's original operand bytes is charged
    at the device's HBM rate (``im2col=False`` switches that off, for
    hardware with native convolution dataflow).

    Ops without tile metadata (``from_hlo``/``from_decode`` macro-ops)
    fall back to full utilization — the roofline charge."""

    rows: int = 128
    cols: int = 128
    double_buffered: bool = True
    im2col: bool = True
    name: str = "systolic"

    def utilization(self, tile: Sequence[int]) -> float:
        """PE-array utilization in (0, 1] for a ``(M, N, K)`` tile."""
        if not tile or len(tile) < 2:
            return 1.0
        m, n = float(tile[0]), float(tile[1])
        if m <= 0.0 or n <= 0.0:
            return 1.0
        spatial = (m / (math.ceil(m / self.rows) * self.rows)) \
            * (n / (math.ceil(n / self.cols) * self.cols))
        if self.double_buffered:
            return spatial
        k = float(tile[2]) if len(tile) > 2 and tile[2] > 0 else 1.0
        return spatial * (k / (k + self.rows + self.cols - 2.0))

    def op_time(self, op, eff) -> float:
        if op.duration_s is not None:
            return op.duration_s
        if op.flops <= 0.0:
            return 0.0
        t = op.flops / (eff.peak_flops * self.utilization(op.tile))
        if (self.im2col and op.op_kind == "conv" and op.tile
                and len(op.tile) >= 3):
            patch_bytes = 4.0 * float(op.tile[0]) * float(op.tile[2])
            extra = patch_bytes - op.bytes_in
            if extra > 0.0:
                t += extra / eff.hbm_bw
        return t


@dataclass(frozen=True)
class TableBackend:
    """Measured-sample lookup: ``(op_kind, flops, seconds)`` tuples.

    Pricing is log-log interpolation over the samples of the op's
    ``op_kind`` (falling back to the ``""`` kind, then to all samples
    pooled), clamped at the measured range's ends.  An op whose flops
    exactly matches a sample returns the measured seconds exactly —
    the round-trip contract ``tests/test_backends.py`` asserts.

    Not smooth in the hardware parameter vector (the measured seconds do
    not move with ``peak_flops``), so the analytic DSE layer rejects it;
    the event engine prices it exactly."""

    samples: Tuple[Tuple[str, float, float], ...]
    name: str = "table"

    def __post_init__(self):
        if not self.samples:
            raise ValueError("TableBackend needs at least one sample")

    @cached_property
    def _tables(self) -> Dict[str, tuple]:
        by_kind: Dict[str, list] = {}
        for kind, flops, secs in self.samples:
            by_kind.setdefault(kind, []).append((float(flops),
                                                 float(secs)))
            by_kind.setdefault(None, []).append((float(flops),
                                                 float(secs)))
        tables: Dict[str, tuple] = {}
        for kind, pts in by_kind.items():
            pts.sort()
            xs = np.log(np.array([p[0] for p in pts]))
            ys = np.log(np.array([p[1] for p in pts]))
            tables[kind] = (xs, ys, dict(pts))
        return tables

    def _lookup(self, kind: str, flops: float) -> float:
        tabs = self._tables
        tab = tabs.get(kind)
        if tab is None:
            tab = tabs.get("") if "" in tabs else tabs[None]
        xs, ys, exact = tab
        hit = exact.get(flops)
        if hit is not None:
            return hit
        return float(np.exp(np.interp(math.log(flops), xs, ys)))

    def op_time(self, op, eff) -> float:
        if op.duration_s is not None:
            return op.duration_s
        if op.flops <= 0.0:
            return 0.0
        return self._lookup(op.op_kind, op.flops)


ROOFLINE = RooflineBackend()

_NAMED = {"roofline": lambda: ROOFLINE,
          "systolic": SystolicBackend}


def is_roofline(backend) -> bool:
    """True when ``backend`` prices exactly like the engine's inline
    roofline math (the ``None`` default or an explicit
    :class:`RooflineBackend`)."""
    return (backend is None or backend == "roofline"
            or isinstance(backend, RooflineBackend))


def get_backend(spec) -> CostBackend:
    """Resolve a ``cost_backend`` field value to a backend instance.

    ``None`` / ``"roofline"`` -> the shared :data:`ROOFLINE`;
    ``"systolic"`` -> a default :class:`SystolicBackend`; any object with
    an ``op_time`` method passes through."""
    if spec is None:
        return ROOFLINE
    if isinstance(spec, str):
        try:
            return _NAMED[spec]()
        except KeyError:
            raise ValueError(f"unknown cost backend {spec!r}; one of "
                             f"{sorted(_NAMED)} (or a CostBackend "
                             "instance)") from None
    if not hasattr(spec, "op_time"):
        raise TypeError(f"cost_backend must be a name or CostBackend, "
                        f"got {type(spec).__name__}")
    return spec


# ---------------------------------------------------------------------------
# calibration: least-squares fit of roofline-shaped parameters to
# measured samples (numpy only; used by tools/calibrate.py and tests)


def mape(pred, measured) -> float:
    """Mean absolute percentage error of ``pred`` against ``measured``."""
    p = np.asarray(pred, dtype=np.float64)
    m = np.asarray(measured, dtype=np.float64)
    return float(np.mean(np.abs(p - m) / m))


def fit_linear_cost(flops, bytes_, measured) -> Dict[str, float]:
    """Fit ``t ~= flops/peak_eff + bytes/bw_eff + overhead_s`` by least
    squares over measured samples.

    The design columns are ``[flops, bytes, 1]``; a column whose best
    coefficient comes out negative is dropped and the rest refit (a
    one-pass non-negativity projection — exact recovery when the true
    generating model is non-negative, which
    ``tests/test_backends.py::test_fit_recovers_synthetic`` asserts).

    Returns ``peak_flops_eff`` / ``bw_eff`` (inf when the term vanished),
    ``overhead_s``, the per-sample predictions and the fit MAPE."""
    f = np.asarray(flops, dtype=np.float64)
    b = np.asarray(bytes_, dtype=np.float64)
    t = np.asarray(measured, dtype=np.float64)
    cols = [f, b, np.ones_like(t)]
    active = [0, 1, 2]
    coef = np.zeros(3)
    for _ in range(3):
        X = np.stack([cols[i] for i in active], axis=1)
        sol, *_ = np.linalg.lstsq(X, t, rcond=None)
        coef[:] = 0.0
        for i, c in zip(active, sol):
            coef[i] = c
        neg = [i for i, c in zip(active, sol) if c < 0.0]
        if not neg:
            break
        worst = min(neg, key=lambda i: coef[i])
        coef[worst] = 0.0
        active = [i for i in active if i != worst]
        if not active:
            break
    pred = coef[0] * f + coef[1] * b + coef[2]
    return {
        "peak_flops_eff": (1.0 / coef[0]) if coef[0] > 0.0 else math.inf,
        "bw_eff": (1.0 / coef[1]) if coef[1] > 0.0 else math.inf,
        "overhead_s": float(coef[2]),
        "pred": pred,
        "mape": mape(pred, t),
    }


def table_from_samples(records) -> TableBackend:
    """Build a :class:`TableBackend` from calibration records — dicts
    with ``kind`` (op_kind), ``flops`` and ``measured_s`` keys."""
    return TableBackend(samples=tuple(
        (r["kind"], float(r["flops"]), float(r["measured_s"]))
        for r in records))
