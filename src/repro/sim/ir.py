"""CostedOp IR — the single currency of the simulation engine.

A ``CostedOp`` carries everything the executor needs to place it in time:
compute (flops, with the dot/MXU share split out), data movement (operand
and result bytes, routed through the pluggable interface model), collective
traffic (assignment-metric operand bytes plus ring-model wire bytes),
scheduling structure (deps, reduction affinity), a ``device_class``
placement tag (which kind of ``SoCTopology`` device may run it — host
preprocessing on the CPU, NN ops on the accelerators), and a reporting
phase.

Six lowerings produce ``Program``s:

  from_graph          the declarative ``repro.core.graph.Graph`` -> tile-level
                      ops via the dataflow tiling optimizer (replaces the old
                      ``graph.tile_tasks`` / ``graph_ops.node_cost`` path),
  from_hlo            an ``analyze_hlo`` cost dict -> a chain of uniform
                      macro-ops that preserves every aggregate exactly (the
                      compiled module is already fused; per-instruction
                      structure is gone),
  from_decode         a ``ModelConfig`` -> token-by-token autoregressive
                      decode chain (weight streaming + growing KV re-reads
                      per token),
  from_serving_step   one continuous-batching scheduler iteration (batched
                      prefill of newly admitted requests + one decode token
                      for every live request) -> a <=2-op step program; the
                      serving simulator (``repro.sim.serving``) chains these
                      into a full served-trace Program,
  from_training_step  one optimizer step (forward, backward at ~2x forward
                      FLOPs with activation re-reads, data-parallel gradient
                      all-reduce, optimizer update) -> a <=4-op chain, for
                      the whole model or for one pipeline stage's layer
                      share; the training simulator (``repro.sim.training``)
                      replicates these per (stage, microbatch) under a
                      GPipe / 1F1B schedule,
  from_tasks          legacy ``TileTask`` lists (scheduler compat).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

BYTES_PER_ELEM = 4  # graph tensors are fp32


@dataclass(frozen=True)
class CostedOp:
    name: str
    flops: float = 0.0
    dot_flops: float = 0.0          # MXU share (can hide memory traffic)
    bytes_in: float = 0.0           # operand bytes staged producer->consumer
    bytes_out: float = 0.0          # result bytes
    collective_bytes: float = 0.0   # operand-sum metric
    wire_bytes: float = 0.0         # ring-model per-device wire bytes
    transcendentals: float = 0.0
    deps: Tuple[str, ...] = ()
    affinity: Optional[str] = None  # same key -> same worker queue
    phase: str = ""                 # reporting group (layer / figure phase)
    # placement: which SoCTopology device kind may run this op ("cpu" |
    # "accel" | "dsp"); a class with no matching device falls back to the
    # accelerators, so flat configs behave exactly as before
    device_class: str = "accel"
    # explicit-time overrides (legacy TileTask lowering; None = derive from
    # flops/bytes and the engine's hardware model)
    duration_s: Optional[float] = None
    transfer_s: Optional[float] = None

    @property
    def bytes(self) -> float:
        return self.bytes_in + self.bytes_out


@dataclass
class Program:
    ops: List[CostedOp]
    name: str = ""
    source: str = ""                # graph | hlo | tasks | custom
    meta: Dict = field(default_factory=dict)

    def __len__(self):
        return len(self.ops)

    # -- aggregates (the roofline inputs; preserved exactly by lowerings) ---
    def total(self, attr: str) -> float:
        return sum(getattr(op, attr) for op in self.ops)

    def totals(self) -> Dict[str, float]:
        return {k: self.total(k) for k in
                ("flops", "dot_flops", "bytes_in", "bytes_out",
                 "collective_bytes", "wire_bytes", "transcendentals")}

    def as_hlo_dict(self) -> Dict[str, float]:
        """Aggregate cost dict in the ``analyze_hlo`` schema — feeding this
        back to the closed-form wrappers reproduces the engine's terms."""
        t = self.totals()
        return {"flops": t["flops"], "dot_flops": t["dot_flops"],
                "bytes": t["bytes_in"] + t["bytes_out"],
                "collective_bytes": t["collective_bytes"],
                "wire_bytes": t["wire_bytes"],
                "transcendentals": t["transcendentals"],
                "collectives": {}, "n_while": 0, "custom_calls": {}}

    def then(self, other: "Program", name: str = "") -> "Program":
        """Sequential composition: ``other`` starts after this program's
        sinks complete (every root of ``other`` gains deps on our sinks)."""
        if not self.ops or not other.ops:
            return Program(self.ops + other.ops, name or self.name,
                           self.source)
        consumed = {d for op in self.ops for d in op.deps}
        sinks = tuple(op.name for op in self.ops if op.name not in consumed)
        other_names = {op.name for op in other.ops}
        bridged = [
            replace(op, deps=tuple(op.deps) + sinks)
            if not any(d in other_names for d in op.deps) else op
            for op in other.ops]
        return Program(self.ops + bridged,
                       name or f"{self.name}+{other.name}", "custom")


# ---------------------------------------------------------------------------
# lowering 1: declarative graph -> tile-level program


def _node_cost_parts(g, n, batch: int) -> Tuple[float, float, float]:
    """(flops, bytes_in, bytes_out) of one graph node at the given batch."""
    import numpy as np
    elems_out = int(np.prod(n.shape)) * batch // max(n.shape[0], 1)
    bytes_out = BYTES_PER_ELEM * elems_out
    if n.op == "convolution":
        k = n.attrs.get("kernel", 3)
        cin = n.attrs.get("cin", n.shape[-1])
        flops = 2.0 * elems_out * k * k * cin
        return flops, bytes_out, bytes_out        # act in ~ act out (same HW)
    if n.op == "matmul":
        cin = n.attrs.get("cin", n.shape[-1])
        flops = 2.0 * elems_out * cin
        bytes_in = BYTES_PER_ELEM * (elems_out + cin * n.shape[-1])
        return flops, bytes_in, bytes_out
    return float(elems_out), bytes_out, bytes_out  # elementwise / pool / norm


def from_graph(g, batch: int = 1, max_tile_elems: int = 16384,
               device_class: str = "accel") -> Program:
    """Lower a ``repro.core.graph.Graph`` to a tile-level Program.

    Each op is tiled by the dataflow tiling optimizer; tile *i* of a node
    depends on tile *i* of each producer (wavefront pipelining — consumers
    start as soon as the matching producer tile lands).  Convolution tiles
    that cut the reduction dim share an affinity key: their partial sums
    reduce in place on one worker queue (the paper's Fig 14 effect).

    ``device_class`` is the placement tag every lowered op carries: NN
    graphs target the accelerators (the default); a preprocessing /
    frontend graph can be lowered onto the ``"cpu"`` or ``"dsp"`` device
    of a heterogeneous ``SoCTopology``.
    """
    import numpy as np

    from repro.core.tensor import TensorSpec
    from repro.core.tiling import choose_tiling

    ops: List[CostedOp] = []
    n_tiles_of: Dict[str, int] = {}
    for name in g.order:
        n = g.nodes[name]
        if n.op in ("input", "weight"):
            continue
        # resolve real kernel/cin from the weight operand when present
        if n.op in ("convolution", "matmul") and len(n.inputs) > 1:
            wshape = g.nodes[n.inputs[1]].shape
            if n.op == "convolution":
                n.attrs.setdefault("kernel", wshape[0])
                n.attrs.setdefault("cin", wshape[2])
            else:
                n.attrs.setdefault("cin", wshape[0])
        flops, bytes_in, bytes_out = _node_cost_parts(g, n, batch)
        shape4 = tuple(n.shape) if len(n.shape) == 4 else \
            (1, 1, 1, int(np.prod(n.shape)))
        tiling = choose_tiling(
            TensorSpec(shape4, "NHWC", "float32"), max_tile_elems,
            reduce_dim="C" if n.op in ("convolution", "matmul") else None)
        n_tiles = max(tiling.n_tiles, 1)
        n_tiles_of[name] = n_tiles
        reduce_aff = "C" in tiling.strategy and n.op == "convolution"
        producers = [d for d in n.inputs
                     if d in g.nodes and g.nodes[d].op not in
                     ("input", "weight")]
        for i in range(n_tiles):
            deps = tuple(
                f"{d}/t{min(i, n_tiles_of.get(d, 1) - 1)}"
                for d in producers)
            ops.append(CostedOp(
                name=f"{name}/t{i}",
                flops=flops / n_tiles,
                dot_flops=(flops / n_tiles
                           if n.op in ("convolution", "matmul") else 0.0),
                bytes_in=bytes_in / n_tiles,
                bytes_out=bytes_out / n_tiles,
                deps=deps,
                affinity=(name if reduce_aff else None),
                phase=name,
                device_class=device_class))
    return Program(ops, name=g.name, source="graph",
                   meta={"batch": batch, "max_tile_elems": max_tile_elems})


# ---------------------------------------------------------------------------
# lowering 2: analyzed compiled HLO -> macro-op chain


def from_hlo(hlo: Dict, n_ops: int = 8, name: str = "") -> Program:
    """Lower an ``analyze_hlo`` cost dict to a chain of uniform macro-ops.

    The compiled module is one fused step — per-instruction structure is not
    recoverable from the aggregate dict — so the program is ``n_ops``
    proportional slices executed in sequence.  All aggregates (flops, bytes,
    collective/wire bytes) are preserved exactly, so the engine's roofline
    and breakdown equal the closed-form values by construction.
    """
    n_ops = max(int(n_ops), 1)
    flops = float(hlo.get("flops", 0.0))
    dot = float(hlo.get("dot_flops", 0.0))
    nbytes = float(hlo.get("bytes", 0.0))
    coll = float(hlo.get("collective_bytes", 0.0))
    # ring-model wire bytes when the analyzer produced them; the raw operand
    # sum is the fallback ONLY when the key is absent (hand-written dicts) —
    # a legitimate 0.0 (e.g. group-size-1 collectives) must stay 0.0
    wire = float(hlo["wire_bytes"]) if "wire_bytes" in hlo else coll
    trans = float(hlo.get("transcendentals", 0.0))
    ops = []
    for i in range(n_ops):
        ops.append(CostedOp(
            name=f"step/{i}",
            flops=flops / n_ops,
            dot_flops=dot / n_ops,
            bytes_in=0.5 * nbytes / n_ops,
            bytes_out=0.5 * nbytes / n_ops,
            collective_bytes=coll / n_ops,
            wire_bytes=wire / n_ops,
            transcendentals=trans / n_ops,
            deps=(f"step/{i-1}",) if i else (),
            phase="step",
            device_class="accel"))
    return Program(ops, name=name or hlo.get("entry", "hlo"), source="hlo",
                   meta={"n_ops": n_ops})


# ---------------------------------------------------------------------------
# lowering 2b: autoregressive decode -> per-token macro-op chain


def _decode_terms(cfg, bytes_per_param: float
                  ) -> Tuple[float, float, int, float]:
    """(active params, per-layer KV width, attention layer count, streamed
    weight bytes) of a ``ModelConfig`` — the shared accounting behind
    ``from_decode`` and ``from_serving_step``.

    The KV width is ``n_kv_heads * head_dim`` elements per layer; a token
    at cache position ``p`` costs ``4 * n_attn_layers * kv_dim * p`` flops
    (QK^T + AV over K and V) and re-reads ``2 * n_attn_layers * kv_dim * p``
    cached elements.  SSM families (and hybrids outside their shared
    attention block) carry no growing KV term.
    """
    n_active = float(cfg.active_param_count())
    kv_dim = 0.0
    n_attn_layers = 0
    if getattr(cfg, "n_kv_heads", 0) and getattr(cfg, "family", "") != "ssm":
        kv_dim = float(cfg.n_kv_heads * cfg.resolved_head_dim)
        n_attn_layers = (cfg.n_layers // cfg.hybrid_attn_every
                         if cfg.family == "hybrid" else cfg.n_layers)
    return n_active, kv_dim, n_attn_layers, n_active * bytes_per_param


def from_decode(cfg, n_tokens: int, *, seq_len: int = 1024, batch: int = 1,
                ops_per_token: int = 8, bytes_per_param: float = 2.0,
                name: str = "") -> Program:
    """Lower token-by-token decode of a ``ModelConfig`` to a chain Program.

    Every generated token streams the full (active) weight set and re-reads
    a KV cache that grows with position — the canonical memory-bound serial
    workload (and, at several ops per token over hundreds of tokens, the
    multi-thousand-op chain that stresses the executor).  Token ``t`` is
    ``ops_per_token`` uniform macro-op slices chained back-to-back, phase
    ``tok<t>``; aggregates follow the ``core.simulator.model_flops`` decode
    accounting (2·N_active per token plus the KV re-read term).
    """
    n_tokens = max(int(n_tokens), 1)
    ops_per_token = max(int(ops_per_token), 1)
    n_active, kv_dim, n_attn_layers, weight_bytes = \
        _decode_terms(cfg, bytes_per_param)
    ops: List[CostedOp] = []
    prev: Optional[str] = None
    for t in range(n_tokens):
        pos = seq_len + t
        flops = 2.0 * n_active * batch \
            + 4.0 * n_attn_layers * kv_dim * pos * batch
        kv_bytes = 2.0 * n_attn_layers * kv_dim * pos * bytes_per_param \
            * batch
        bytes_in = weight_bytes + kv_bytes
        bytes_out = kv_dim * n_attn_layers * bytes_per_param * batch
        for k in range(ops_per_token):
            nm = f"tok{t}/s{k}"
            ops.append(CostedOp(
                name=nm,
                flops=flops / ops_per_token,
                dot_flops=flops / ops_per_token,
                bytes_in=bytes_in / ops_per_token,
                bytes_out=bytes_out / ops_per_token,
                deps=(prev,) if prev else (),
                phase=f"tok{t}",
                device_class="accel"))
            prev = nm
    return Program(ops, name=name or f"{getattr(cfg, 'name', 'model')}"
                   f"/decode{n_tokens}", source="decode",
                   meta={"n_tokens": n_tokens, "seq_len": seq_len,
                         "batch": batch, "ops_per_token": ops_per_token})


# ---------------------------------------------------------------------------
# lowering 2c: one serving-scheduler iteration -> batched step program


def from_serving_step(cfg, *, prefill_lens: Sequence[int] = (),
                      decode_positions: Sequence[int] = (),
                      step: int = 0, bytes_per_param: float = 2.0,
                      name: str = "") -> Program:
    """Lower ONE serving-scheduler iteration to a <=2-op step Program.

    A continuous-batching model step does two things in a single forward
    pass: it prefills the requests admitted this iteration and decodes one
    token for every request already live.  The lowering mirrors that:

      ``step<k>/prefill``  batched prefill of ``prefill_lens`` prompts —
                           ``sum(L_j)`` tokens of dense compute plus the
                           causal attention term
                           ``4 * n_attn * kv_dim * L_j*(L_j-1)/2`` per
                           prompt, writing ``L_j`` KV entries each;
      ``step<k>/decode``   one token per entry of ``decode_positions``
                           (the per-request KV length) — per slot the same
                           ``from_decode`` accounting: ``2*N_active`` dense
                           flops plus ``4 * n_attn * kv_dim * p`` attention
                           flops and a ``2 * n_attn * kv_dim * p`` element
                           KV re-read.

    The full streamed weight set (``N_active * bytes_per_param``) is
    charged ONCE per step, on the step's first op — this is the weight
    amortization that makes batched decode pay off: the memory-bound cost
    of a step is nearly flat in batch size while its token yield scales
    with it.  Padded slots (static batching) are modeled by passing their
    positions in ``decode_positions`` even though they yield no token —
    the cost of computing garbage is real.

    ``repro.sim.serving`` chains these step programs (each step's first op
    depends on the previous step's last op) into one served-trace Program;
    the result is a pure linear chain, so the engine's prefix-sum fast
    path applies to whole-trace runs.
    """
    n_active, kv_dim, n_attn, weight_bytes = \
        _decode_terms(cfg, bytes_per_param)
    kv_entry = kv_dim * n_attn * bytes_per_param     # one token's KV write
    ops: List[CostedOp] = []
    prev: Optional[str] = None
    if prefill_lens:
        n_tok = float(sum(prefill_lens))
        attn = sum(4.0 * n_attn * kv_dim * (L * (L - 1) // 2)
                   for L in prefill_lens)
        flops = 2.0 * n_active * n_tok + attn
        prev = f"step{step}/prefill"
        ops.append(CostedOp(
            name=prev, flops=flops, dot_flops=flops,
            bytes_in=weight_bytes,
            bytes_out=kv_entry * n_tok,
            phase=f"step{step}",
            device_class="accel",
            ))
    if decode_positions:
        batch = float(len(decode_positions))
        pos_sum = float(sum(decode_positions))
        flops = 2.0 * n_active * batch + 4.0 * n_attn * kv_dim * pos_sum
        kv_read = 2.0 * n_attn * kv_dim * pos_sum * bytes_per_param
        ops.append(CostedOp(
            name=f"step{step}/decode", flops=flops, dot_flops=flops,
            bytes_in=(0.0 if prev else weight_bytes) + kv_read,
            bytes_out=kv_entry * batch,
            deps=(prev,) if prev else (),
            phase=f"step{step}",
            device_class="accel",
            ))
    return Program(ops, name=name or f"{getattr(cfg, 'name', 'model')}"
                   f"/step{step}", source="serving",
                   meta={"step": step,
                         "n_prefill": len(prefill_lens),
                         "n_decode": len(decode_positions)})


def serving_step_signature(prefill_lens: Sequence[int],
                           decode_positions: Sequence[int]) -> Tuple:
    """The cost-sufficient signature of one serving step.

    ``from_serving_step`` reads ``decode_positions`` only through ``len()``
    (the decode batch size) and ``sum()`` (the KV position total, an exact
    integer sum), while the prefill ops' causal-attention term is a float
    sum over the *individual* prompt lengths — so ``(tuple(prefill_lens),
    len(decode_positions), sum(decode_positions))`` determines every cost
    field of the step's ops bit-for-bit.  The step index only names ops;
    it never changes a cost.  ``serving.StepCostTable`` memoizes step
    pricing on this key, and this function is the single place that
    encodes the coupling — extend it if ``from_serving_step`` ever reads
    more structure out of ``decode_positions``.
    """
    return (tuple(prefill_lens), len(decode_positions),
            int(sum(decode_positions)))


def positions_for_signature(n_decode: int, pos_sum: int) -> Tuple[int, ...]:
    """A canonical ``decode_positions`` tuple realizing a signature's
    ``(n_decode, pos_sum)`` — any tuple with that length and sum lowers to
    bit-identical decode-op costs (see ``serving_step_signature``)."""
    if n_decode <= 0:
        return ()
    return (int(pos_sum) - (n_decode - 1),) + (1,) * (n_decode - 1)


# ---------------------------------------------------------------------------
# lowering 2d: one training step -> fwd/bwd/reduce/update chain


# AdamW arithmetic per parameter (two moment EMAs, bias correction, weight
# decay, the update itself) — the constant the optimizer-update op charges
OPTIMIZER_FLOPS_PER_PARAM = 12.0
# backward pass = grad wrt activations + grad wrt weights: the canonical
# 2x-forward FLOP accounting (recomputation/remat would add a third pass)
BWD_FLOPS_MULT = 2.0


def partition_stages(n_layers: int, n_stages: int) -> Tuple[int, ...]:
    """Balanced layer partition for pipeline parallelism: the first
    ``n_layers % n_stages`` stages carry one extra layer.  This is the
    single source of truth shared by the training simulator
    (``repro.sim.training``) and the real JAX pipeline
    (``repro.dist.pipeline``), so simulated and executed stage shares
    cannot drift apart."""
    n_layers, n_stages = int(n_layers), int(n_stages)
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_layers < n_stages:
        raise ValueError(
            f"cannot split {n_layers} layers over {n_stages} stages: "
            "every stage needs at least one layer")
    base, extra = divmod(n_layers, n_stages)
    return tuple(base + (1 if s < extra else 0) for s in range(n_stages))


def _training_terms(cfg, seq_len: int, batch: int, bytes_per_param: float,
                    bytes_per_act: float) -> Dict[str, float]:
    """Whole-model per-step cost terms of one fwd+bwd over ``batch``
    sequences of ``seq_len`` tokens — the shared accounting behind
    ``from_training_step``.

      fwd_flops    dense ``2 * N_active * tokens`` plus the causal
                   attention term ``4 * n_attn * kv_dim * S*(S-1)/2`` per
                   sequence (the ``from_serving_step`` prefill formula),
      act_bytes    stored activations: one residual-stream tensor per
                   layer (``n_layers * d_model * tokens * bytes_per_act``),
                   written by the forward and re-read by the backward,
      weight_bytes streamed active weights (charged per pass — training
                   streams them forward AND backward),
      grad_bytes   dense gradient traffic (active params),
      opt_params   the full parameter count the optimizer state covers
                   (MoE: every expert has moments, not just routed ones).
    """
    n_active, kv_dim, n_attn, weight_bytes = \
        _decode_terms(cfg, bytes_per_param)
    tokens = float(batch) * float(seq_len)
    attn = 4.0 * n_attn * kv_dim * (seq_len * (seq_len - 1) // 2) * batch
    return {
        "fwd_flops": 2.0 * n_active * tokens + attn,
        "act_bytes": float(cfg.n_layers) * float(cfg.d_model) * tokens
        * bytes_per_act,
        "weight_bytes": weight_bytes,
        "grad_bytes": n_active * bytes_per_param,
        "opt_params": float(cfg.param_count()),
        "tokens": tokens,
    }


def from_training_step(cfg, *, seq_len: int = 1024, batch: int = 8,
                       stage: Optional[int] = None, n_stages: int = 1,
                       bytes_per_param: float = 2.0,
                       bytes_per_act: float = 2.0,
                       optimizer_bytes_per_param: float = 12.0,
                       dp_degree: int = 1, name: str = "") -> Program:
    """Lower ONE training optimizer step to a <=4-op chain Program.

    The chain is ``fwd -> bwd [-> reduce] -> update``:

      ``train/fwd``     forward over ``batch`` sequences: streams the
                        (active) weights, writes the stored activations;
      ``train/bwd``     backward at ``BWD_FLOPS_MULT`` (2x) the forward
                        FLOPs: re-streams the weights, RE-READS the stored
                        activations, writes the dense gradients;
      ``train/reduce``  the data-parallel gradient all-reduce, emitted only
                        when ``dp_degree > 1``: operand-sum metric =
                        gradient bytes, ring wire bytes =
                        ``2 * (d-1)/d * grad_bytes``;
      ``train/update``  the AdamW update: ``OPTIMIZER_FLOPS_PER_PARAM``
                        flops per (full, not active) parameter, reading the
                        gradients + optimizer state
                        (``optimizer_bytes_per_param`` covers fp32 m, v and
                        master weights) and writing the state back plus the
                        fresh streaming weights.

    ``stage``/``n_stages`` select one pipeline stage's share of the model:
    the layers partition via ``partition_stages`` and every term scales by
    ``layers_in_stage / n_layers`` (embeddings and the attention mix are
    apportioned uniformly — a deliberate first-order model).  ``stage=None``
    with ``n_stages=1`` is the whole model; the training simulator
    (``repro.sim.training``) calls this per stage and per microbatch, so a
    1-stage 1-microbatch simulated step is THIS chain, bit for bit.
    """
    if n_stages > 1 and stage is None:
        raise ValueError("stage index required when n_stages > 1; "
                         "use repro.sim.training for the full pipeline")
    share = 1.0
    if stage is not None:
        layers = partition_stages(cfg.n_layers, n_stages)
        if not 0 <= stage < n_stages:
            raise ValueError(f"stage {stage} out of range for "
                             f"{n_stages} stages")
        share = layers[stage] / float(cfg.n_layers)
    t = _training_terms(cfg, seq_len, batch, bytes_per_param, bytes_per_act)
    fwd_flops = t["fwd_flops"] * share
    act_bytes = t["act_bytes"] * share
    weight_bytes = t["weight_bytes"] * share
    grad_bytes = t["grad_bytes"] * share
    opt_params = t["opt_params"] * share
    opt_state_bytes = opt_params * optimizer_bytes_per_param

    ops = [
        CostedOp(name="train/fwd", flops=fwd_flops, dot_flops=fwd_flops,
                 bytes_in=weight_bytes, bytes_out=act_bytes,
                 phase="fwd", device_class="accel"),
        CostedOp(name="train/bwd",
                 flops=BWD_FLOPS_MULT * fwd_flops,
                 dot_flops=BWD_FLOPS_MULT * fwd_flops,
                 bytes_in=weight_bytes + act_bytes,   # activation re-reads
                 bytes_out=grad_bytes,
                 deps=("train/fwd",), phase="bwd", device_class="accel"),
    ]
    prev = "train/bwd"
    if dp_degree > 1:
        ops.append(CostedOp(
            name="train/reduce",
            collective_bytes=grad_bytes,
            wire_bytes=2.0 * (dp_degree - 1) / dp_degree * grad_bytes,
            deps=(prev,), phase="reduce", device_class="accel"))
        prev = "train/reduce"
    ops.append(CostedOp(
        name="train/update",
        flops=OPTIMIZER_FLOPS_PER_PARAM * opt_params,
        bytes_in=grad_bytes + opt_state_bytes,
        bytes_out=opt_state_bytes + weight_bytes,
        deps=(prev,), phase="opt", device_class="accel"))
    return Program(ops, name=name or f"{getattr(cfg, 'name', 'model')}"
                   f"/train", source="training",
                   meta={"seq_len": seq_len, "batch": batch,
                         "stage": stage, "n_stages": n_stages,
                         "dp_degree": dp_degree, "share": share,
                         "tokens": t["tokens"]})


# ---------------------------------------------------------------------------
# lowering 3: legacy TileTask lists (scheduler compat)


def from_tasks(tasks: Sequence, name: str = "tasks") -> Program:
    """Lower ``core.scheduler.TileTask``s, preserving their explicit times."""
    ops = [CostedOp(name=t.name,
                    duration_s=float(t.duration),
                    transfer_s=float(t.transfer) if t.transfer else 0.0,
                    deps=tuple(t.deps),
                    affinity=t.affinity,
                    phase=t.name.split("/")[0])
           for t in tasks]
    return Program(ops, name=name, source="tasks")
