"""CostedOp IR — the single currency of the simulation engine.

A ``CostedOp`` carries everything the executor needs to place it in time:
compute (flops, with the dot/MXU share split out), data movement (operand
and result bytes, routed through the pluggable interface model), collective
traffic (assignment-metric operand bytes plus ring-model wire bytes),
scheduling structure (deps, reduction affinity), a ``device_class``
placement tag (which kind of ``SoCTopology`` device may run it — host
preprocessing on the CPU, NN ops on the accelerators), and a reporting
phase.

Six lowerings produce ``Program``s:

  from_graph          the declarative ``repro.core.graph.Graph`` -> tile-level
                      ops via the dataflow tiling optimizer (replaces the old
                      ``graph.tile_tasks`` / ``graph_ops.node_cost`` path),
  from_hlo            an ``analyze_hlo`` cost dict -> a chain of uniform
                      macro-ops that preserves every aggregate exactly (the
                      compiled module is already fused; per-instruction
                      structure is gone),
  from_decode         a ``ModelConfig`` -> token-by-token autoregressive
                      decode chain (weight streaming + growing KV re-reads
                      per token),
  from_serving_step   one continuous-batching scheduler iteration (batched
                      prefill of newly admitted requests + one decode token
                      for every live request) -> a <=2-op step program; the
                      serving simulator (``repro.sim.serving``) chains these
                      into a full served-trace Program,
  from_training_step  one optimizer step (forward, backward at ~2x forward
                      FLOPs with activation re-reads, data-parallel gradient
                      all-reduce, optimizer update) -> a <=4-op chain, for
                      the whole model or for one pipeline stage's layer
                      share; the training simulator (``repro.sim.training``)
                      replicates these per (stage, microbatch) under a
                      GPipe / 1F1B schedule,
  from_tasks          legacy ``TileTask`` lists (scheduler compat).

``from_collective`` lowers one collective (all-reduce / reduce-scatter /
all-gather / all-to-all) over a group of accelerators on a hierarchical
``hw.Fabric`` into explicit per-hop transfer ops: each algorithm step of
ring / tree / hierarchical becomes one ``CostedOp`` with ``tier`` set to
the fabric tier it crosses and ``lane`` naming the contended link set —
the engine prices it at run time as ``hops * tier_latency +
collective_bytes / tier_bandwidth``, so fabric rates stay inside the
continuous DSE parameter vector.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

BYTES_PER_ELEM = 4  # graph tensors are fp32


@dataclass(frozen=True)
class CostedOp:
    name: str
    flops: float = 0.0
    dot_flops: float = 0.0          # MXU share (can hide memory traffic)
    bytes_in: float = 0.0           # operand bytes staged producer->consumer
    bytes_out: float = 0.0          # result bytes
    collective_bytes: float = 0.0   # operand-sum metric
    wire_bytes: float = 0.0         # ring-model per-device wire bytes
    transcendentals: float = 0.0
    deps: Tuple[str, ...] = ()
    affinity: Optional[str] = None  # same key -> same worker queue
    phase: str = ""                 # reporting group (layer / figure phase)
    # placement: which SoCTopology device kind may run this op ("cpu" |
    # "accel" | "dsp"); a class with no matching device falls back to the
    # accelerators, so flat configs behave exactly as before
    device_class: str = "accel"
    # explicit-time overrides (legacy TileTask lowering; None = derive from
    # flops/bytes and the engine's hardware model)
    duration_s: Optional[float] = None
    transfer_s: Optional[float] = None
    # fabric collectives: ``tier`` marks a per-hop transfer priced from the
    # named fabric tier's (latency, bandwidth) at run time — such ops
    # occupy only their ``lane`` (no worker placement, host dispatch or
    # compute).  ``lane`` is the contended serial resource the transfer
    # runs on ("ici" = the legacy single collective lane); ``hops``
    # multiplies the tier latency (a compressed run of back-to-back hops).
    tier: Optional[str] = None
    lane: str = "ici"
    hops: float = 1.0
    # microarchitecture pricing metadata (see ``repro.sim.backends``):
    # the ``(M, N, K)`` compute-tile shape the op's dot work maps onto a
    # PE array, and the op family it lowered from ("matmul" | "conv" |
    # "").  Advisory — the default roofline backend never reads them;
    # lowerings without tile structure leave them empty.
    tile: Tuple[int, ...] = ()
    op_kind: str = ""

    @property
    def bytes(self) -> float:
        return self.bytes_in + self.bytes_out


_OP_FIELDS = frozenset(f.name for f in dataclasses.fields(CostedOp))


def replace(op, **changes):
    """``dataclasses.replace`` with a fast path for :class:`CostedOp`.

    The training/cluster lowerings clone hundreds of thousands of ops per
    sweep (segment templates stamped out per stage and microbatch);
    ``dataclasses.replace`` re-runs the frozen ``__init__`` — one guarded
    ``object.__setattr__`` per field — which dominates program
    construction.  ``CostedOp`` has no ``__post_init__`` and no derived
    state, so a shallow ``__dict__`` copy produces the identical frozen
    instance.  Unknown field names still raise ``TypeError`` like
    ``dataclasses.replace``; any other dataclass takes the stock path."""
    if type(op) is CostedOp:
        if not changes.keys() <= _OP_FIELDS:
            bad = sorted(changes.keys() - _OP_FIELDS)
            raise TypeError(f"replace() got unexpected CostedOp "
                            f"field(s) {bad}")
        new = object.__new__(CostedOp)
        new.__dict__.update(op.__dict__)
        new.__dict__.update(changes)
        return new
    return dataclasses.replace(op, **changes)


def linear_runs(ops: Sequence[CostedOp]) -> List[List[str]]:
    """Maximal linear runs of fabric hop ops: each interior link is a
    single-consumer -> single-dep edge between two ``tier`` ops that are
    LPT-neutral (``flops == 0`` and no pinned ``duration_s`` — the
    scheduling priority of such a hop is exactly 0.0 under every config,
    so contracting the link can never reorder the ready heap).

    These are the segments the engine's compiled plan contracts (the
    chain fast path generalized from whole-program to per-segment): along
    a run, finishing op ``i`` readies exactly its successor, so the event
    loop's behavior over the run is statically replayable.  Returns runs
    of length >= 2, in program order; single hop ops are not runs."""
    index = {op.name: i for i, op in enumerate(ops)}
    n_consumers = [0] * len(ops)
    sole_consumer = [-1] * len(ops)
    for i, op in enumerate(ops):
        for d in op.deps:
            j = index.get(d)
            if j is not None:
                n_consumers[j] += 1
                sole_consumer[j] = i

    def neutral(op: CostedOp) -> bool:
        return (op.tier is not None and op.flops == 0.0
                and op.duration_s is None)

    nxt = [-1] * len(ops)
    has_prev = [False] * len(ops)
    for i, op in enumerate(ops):
        if not neutral(op) or n_consumers[i] != 1:
            continue
        j = sole_consumer[i]
        succ = ops[j]
        if not neutral(succ) or len(succ.deps) != 1:
            continue
        nxt[i] = j
        has_prev[j] = True
    runs: List[List[str]] = []
    for i, op in enumerate(ops):
        if op.tier is None or has_prev[i] or nxt[i] < 0:
            continue
        run = [op.name]
        j = nxt[i]
        while j >= 0:
            run.append(ops[j].name)
            j = nxt[j]
        runs.append(run)
    return runs


@dataclass
class Program:
    ops: List[CostedOp]
    name: str = ""
    source: str = ""                # graph | hlo | tasks | custom
    meta: Dict = field(default_factory=dict)

    def __len__(self):
        return len(self.ops)

    # -- aggregates (the roofline inputs; preserved exactly by lowerings) ---
    def total(self, attr: str) -> float:
        return sum(getattr(op, attr) for op in self.ops)

    def totals(self) -> Dict[str, float]:
        # one pass over the ops; each accumulator adds left-to-right in op
        # order, so every sum is the same IEEE fold ``total()`` performs
        fl = dot = bi = bo = cb = wb = tc = 0.0
        for op in self.ops:
            fl += op.flops
            dot += op.dot_flops
            bi += op.bytes_in
            bo += op.bytes_out
            cb += op.collective_bytes
            wb += op.wire_bytes
            tc += op.transcendentals
        return {"flops": fl, "dot_flops": dot, "bytes_in": bi,
                "bytes_out": bo, "collective_bytes": cb, "wire_bytes": wb,
                "transcendentals": tc}

    def as_hlo_dict(self) -> Dict[str, float]:
        """Aggregate cost dict in the ``analyze_hlo`` schema — feeding this
        back to the closed-form wrappers reproduces the engine's terms."""
        t = self.totals()
        return {"flops": t["flops"], "dot_flops": t["dot_flops"],
                "bytes": t["bytes_in"] + t["bytes_out"],
                "collective_bytes": t["collective_bytes"],
                "wire_bytes": t["wire_bytes"],
                "transcendentals": t["transcendentals"],
                "collectives": {}, "n_while": 0, "custom_calls": {}}

    def then(self, other: "Program", name: str = "") -> "Program":
        """Sequential composition: ``other`` starts after this program's
        sinks complete (every root of ``other`` gains deps on our sinks)."""
        if not self.ops or not other.ops:
            return Program(self.ops + other.ops, name or self.name,
                           self.source)
        consumed = {d for op in self.ops for d in op.deps}
        sinks = tuple(op.name for op in self.ops if op.name not in consumed)
        other_names = {op.name for op in other.ops}
        bridged = [
            replace(op, deps=tuple(op.deps) + sinks)
            if not any(d in other_names for d in op.deps) else op
            for op in other.ops]
        return Program(self.ops + bridged,
                       name or f"{self.name}+{other.name}", "custom")


# ---------------------------------------------------------------------------
# lowering 1: declarative graph -> tile-level program


def _node_cost_parts(g, n, batch: int) -> Tuple[float, float, float]:
    """(flops, bytes_in, bytes_out) of one graph node at the given batch."""
    import numpy as np
    elems_out = int(np.prod(n.shape)) * batch // max(n.shape[0], 1)
    bytes_out = BYTES_PER_ELEM * elems_out
    if n.op == "convolution":
        k = n.attrs.get("kernel", 3)
        cin = n.attrs.get("cin", n.shape[-1])
        flops = 2.0 * elems_out * k * k * cin
        return flops, bytes_out, bytes_out        # act in ~ act out (same HW)
    if n.op == "matmul":
        cin = n.attrs.get("cin", n.shape[-1])
        flops = 2.0 * elems_out * cin
        bytes_in = BYTES_PER_ELEM * (elems_out + cin * n.shape[-1])
        return flops, bytes_in, bytes_out
    return float(elems_out), bytes_out, bytes_out  # elementwise / pool / norm


def from_graph(g, batch: int = 1, max_tile_elems: int = 16384,
               device_class: str = "accel") -> Program:
    """Lower a ``repro.core.graph.Graph`` to a tile-level Program.

    Each op is tiled by the dataflow tiling optimizer; tile *i* of a node
    depends on tile *i* of each producer (wavefront pipelining — consumers
    start as soon as the matching producer tile lands).  Convolution tiles
    that cut the reduction dim share an affinity key: their partial sums
    reduce in place on one worker queue (the paper's Fig 14 effect).

    ``device_class`` is the placement tag every lowered op carries: NN
    graphs target the accelerators (the default); a preprocessing /
    frontend graph can be lowered onto the ``"cpu"`` or ``"dsp"`` device
    of a heterogeneous ``SoCTopology``.
    """
    import numpy as np

    from repro.core.tensor import TensorSpec
    from repro.core.tiling import choose_tiling

    ops: List[CostedOp] = []
    n_tiles_of: Dict[str, int] = {}
    for name in g.order:
        n = g.nodes[name]
        if n.op in ("input", "weight"):
            continue
        # resolve real kernel/cin from the weight operand when present
        if n.op in ("convolution", "matmul") and len(n.inputs) > 1:
            wshape = g.nodes[n.inputs[1]].shape
            if n.op == "convolution":
                n.attrs.setdefault("kernel", wshape[0])
                n.attrs.setdefault("cin", wshape[2])
            else:
                n.attrs.setdefault("cin", wshape[0])
        flops, bytes_in, bytes_out = _node_cost_parts(g, n, batch)
        shape4 = tuple(n.shape) if len(n.shape) == 4 else \
            (1, 1, 1, int(np.prod(n.shape)))
        tiling = choose_tiling(
            TensorSpec(shape4, "NHWC", "float32"), max_tile_elems,
            reduce_dim="C" if n.op in ("convolution", "matmul") else None)
        n_tiles = max(tiling.n_tiles, 1)
        n_tiles_of[name] = n_tiles
        reduce_aff = "C" in tiling.strategy and n.op == "convolution"
        # (M, N, K) compute-tile metadata for the systolic cost backend:
        # M output rows (spatial elems of one tile), N output channels of
        # the tile, K the reduction depth (im2col-expanded for convs)
        op_kind = ("conv" if n.op == "convolution"
                   else "matmul" if n.op == "matmul" else "")
        tile_meta: Tuple[int, ...] = ()
        if op_kind:
            ts = tiling.tile_shape
            kern = int(n.attrs.get("kernel", 1)) if op_kind == "conv" \
                else 1
            cin = int(n.attrs.get("cin", shape4[3]))
            tile_meta = (int(ts[0] * ts[1] * ts[2]), int(ts[3]),
                         kern * kern * cin)
        producers = [d for d in n.inputs
                     if d in g.nodes and g.nodes[d].op not in
                     ("input", "weight")]
        for i in range(n_tiles):
            deps = tuple(
                f"{d}/t{min(i, n_tiles_of.get(d, 1) - 1)}"
                for d in producers)
            ops.append(CostedOp(
                name=f"{name}/t{i}",
                flops=flops / n_tiles,
                dot_flops=(flops / n_tiles
                           if n.op in ("convolution", "matmul") else 0.0),
                bytes_in=bytes_in / n_tiles,
                bytes_out=bytes_out / n_tiles,
                deps=deps,
                affinity=(name if reduce_aff else None),
                phase=name,
                device_class=device_class,
                tile=tile_meta,
                op_kind=op_kind))
    return Program(ops, name=g.name, source="graph",
                   meta={"batch": batch, "max_tile_elems": max_tile_elems})


# ---------------------------------------------------------------------------
# lowering 2: analyzed compiled HLO -> macro-op chain


def from_hlo(hlo: Dict, n_ops: int = 8, name: str = "") -> Program:
    """Lower an ``analyze_hlo`` cost dict to a chain of uniform macro-ops.

    The compiled module is one fused step — per-instruction structure is not
    recoverable from the aggregate dict — so the program is ``n_ops``
    proportional slices executed in sequence.  All aggregates (flops, bytes,
    collective/wire bytes) are preserved exactly, so the engine's roofline
    and breakdown equal the closed-form values by construction.
    """
    n_ops = max(int(n_ops), 1)
    flops = float(hlo.get("flops", 0.0))
    dot = float(hlo.get("dot_flops", 0.0))
    nbytes = float(hlo.get("bytes", 0.0))
    coll = float(hlo.get("collective_bytes", 0.0))
    # ring-model wire bytes when the analyzer produced them; the raw operand
    # sum is the fallback ONLY when the key is absent (hand-written dicts) —
    # a legitimate 0.0 (e.g. group-size-1 collectives) must stay 0.0
    wire = float(hlo["wire_bytes"]) if "wire_bytes" in hlo else coll
    trans = float(hlo.get("transcendentals", 0.0))
    ops = []
    for i in range(n_ops):
        ops.append(CostedOp(
            name=f"step/{i}",
            flops=flops / n_ops,
            dot_flops=dot / n_ops,
            bytes_in=0.5 * nbytes / n_ops,
            bytes_out=0.5 * nbytes / n_ops,
            collective_bytes=coll / n_ops,
            wire_bytes=wire / n_ops,
            transcendentals=trans / n_ops,
            deps=(f"step/{i-1}",) if i else (),
            phase="step",
            device_class="accel"))
    return Program(ops, name=name or hlo.get("entry", "hlo"), source="hlo",
                   meta={"n_ops": n_ops})


# ---------------------------------------------------------------------------
# lowering 2b: autoregressive decode -> per-token macro-op chain


def _decode_terms(cfg, bytes_per_param: float
                  ) -> Tuple[float, float, int, float]:
    """(active params, per-layer KV width, attention layer count, streamed
    weight bytes) of a ``ModelConfig`` — the shared accounting behind
    ``from_decode`` and ``from_serving_step``.

    The KV width is ``n_kv_heads * head_dim`` elements per layer; a token
    at cache position ``p`` costs ``4 * n_attn_layers * kv_dim * p`` flops
    (QK^T + AV over K and V) and re-reads ``2 * n_attn_layers * kv_dim * p``
    cached elements.  SSM families (and hybrids outside their shared
    attention block) carry no growing KV term.
    """
    n_active = float(cfg.active_param_count())
    kv_dim = 0.0
    n_attn_layers = 0
    if getattr(cfg, "n_kv_heads", 0) and getattr(cfg, "family", "") != "ssm":
        kv_dim = float(cfg.n_kv_heads * cfg.resolved_head_dim)
        n_attn_layers = (cfg.n_layers // cfg.hybrid_attn_every
                         if cfg.family == "hybrid" else cfg.n_layers)
    return n_active, kv_dim, n_attn_layers, n_active * bytes_per_param


def from_decode(cfg, n_tokens: int, *, seq_len: int = 1024, batch: int = 1,
                ops_per_token: int = 8, bytes_per_param: float = 2.0,
                name: str = "") -> Program:
    """Lower token-by-token decode of a ``ModelConfig`` to a chain Program.

    Every generated token streams the full (active) weight set and re-reads
    a KV cache that grows with position — the canonical memory-bound serial
    workload (and, at several ops per token over hundreds of tokens, the
    multi-thousand-op chain that stresses the executor).  Token ``t`` is
    ``ops_per_token`` uniform macro-op slices chained back-to-back, phase
    ``tok<t>``; aggregates follow the ``core.simulator.model_flops`` decode
    accounting (2·N_active per token plus the KV re-read term).
    """
    n_tokens = max(int(n_tokens), 1)
    ops_per_token = max(int(ops_per_token), 1)
    n_active, kv_dim, n_attn_layers, weight_bytes = \
        _decode_terms(cfg, bytes_per_param)
    ops: List[CostedOp] = []
    prev: Optional[str] = None
    for t in range(n_tokens):
        pos = seq_len + t
        flops = 2.0 * n_active * batch \
            + 4.0 * n_attn_layers * kv_dim * pos * batch
        kv_bytes = 2.0 * n_attn_layers * kv_dim * pos * bytes_per_param \
            * batch
        bytes_in = weight_bytes + kv_bytes
        bytes_out = kv_dim * n_attn_layers * bytes_per_param * batch
        for k in range(ops_per_token):
            nm = f"tok{t}/s{k}"
            ops.append(CostedOp(
                name=nm,
                flops=flops / ops_per_token,
                dot_flops=flops / ops_per_token,
                bytes_in=bytes_in / ops_per_token,
                bytes_out=bytes_out / ops_per_token,
                deps=(prev,) if prev else (),
                phase=f"tok{t}",
                device_class="accel"))
            prev = nm
    return Program(ops, name=name or f"{getattr(cfg, 'name', 'model')}"
                   f"/decode{n_tokens}", source="decode",
                   meta={"n_tokens": n_tokens, "seq_len": seq_len,
                         "batch": batch, "ops_per_token": ops_per_token})


# ---------------------------------------------------------------------------
# lowering 2c: one serving-scheduler iteration -> batched step program


def from_serving_step(cfg, *, prefill_lens: Sequence[int] = (),
                      decode_positions: Sequence[int] = (),
                      step: int = 0, bytes_per_param: float = 2.0,
                      name: str = "") -> Program:
    """Lower ONE serving-scheduler iteration to a <=2-op step Program.

    A continuous-batching model step does two things in a single forward
    pass: it prefills the requests admitted this iteration and decodes one
    token for every request already live.  The lowering mirrors that:

      ``step<k>/prefill``  batched prefill of ``prefill_lens`` prompts —
                           ``sum(L_j)`` tokens of dense compute plus the
                           causal attention term
                           ``4 * n_attn * kv_dim * L_j*(L_j-1)/2`` per
                           prompt, writing ``L_j`` KV entries each;
      ``step<k>/decode``   one token per entry of ``decode_positions``
                           (the per-request KV length) — per slot the same
                           ``from_decode`` accounting: ``2*N_active`` dense
                           flops plus ``4 * n_attn * kv_dim * p`` attention
                           flops and a ``2 * n_attn * kv_dim * p`` element
                           KV re-read.

    The full streamed weight set (``N_active * bytes_per_param``) is
    charged ONCE per step, on the step's first op — this is the weight
    amortization that makes batched decode pay off: the memory-bound cost
    of a step is nearly flat in batch size while its token yield scales
    with it.  Padded slots (static batching) are modeled by passing their
    positions in ``decode_positions`` even though they yield no token —
    the cost of computing garbage is real.

    ``repro.sim.serving`` chains these step programs (each step's first op
    depends on the previous step's last op) into one served-trace Program;
    the result is a pure linear chain, so the engine's prefix-sum fast
    path applies to whole-trace runs.
    """
    n_active, kv_dim, n_attn, weight_bytes = \
        _decode_terms(cfg, bytes_per_param)
    kv_entry = kv_dim * n_attn * bytes_per_param     # one token's KV write
    ops: List[CostedOp] = []
    prev: Optional[str] = None
    if prefill_lens:
        n_tok = float(sum(prefill_lens))
        attn = sum(4.0 * n_attn * kv_dim * (L * (L - 1) // 2)
                   for L in prefill_lens)
        flops = 2.0 * n_active * n_tok + attn
        prev = f"step{step}/prefill"
        ops.append(CostedOp(
            name=prev, flops=flops, dot_flops=flops,
            bytes_in=weight_bytes,
            bytes_out=kv_entry * n_tok,
            phase=f"step{step}",
            device_class="accel",
            ))
    if decode_positions:
        batch = float(len(decode_positions))
        pos_sum = float(sum(decode_positions))
        flops = 2.0 * n_active * batch + 4.0 * n_attn * kv_dim * pos_sum
        kv_read = 2.0 * n_attn * kv_dim * pos_sum * bytes_per_param
        ops.append(CostedOp(
            name=f"step{step}/decode", flops=flops, dot_flops=flops,
            bytes_in=(0.0 if prev else weight_bytes) + kv_read,
            bytes_out=kv_entry * batch,
            deps=(prev,) if prev else (),
            phase=f"step{step}",
            device_class="accel",
            ))
    return Program(ops, name=name or f"{getattr(cfg, 'name', 'model')}"
                   f"/step{step}", source="serving",
                   meta={"step": step,
                         "n_prefill": len(prefill_lens),
                         "n_decode": len(decode_positions)})


def serving_step_signature(prefill_lens: Sequence[int],
                           decode_positions: Sequence[int]) -> Tuple:
    """The cost-sufficient signature of one serving step.

    ``from_serving_step`` reads ``decode_positions`` only through ``len()``
    (the decode batch size) and ``sum()`` (the KV position total, an exact
    integer sum), while the prefill ops' causal-attention term is a float
    sum over the *individual* prompt lengths — so ``(tuple(prefill_lens),
    len(decode_positions), sum(decode_positions))`` determines every cost
    field of the step's ops bit-for-bit.  The step index only names ops;
    it never changes a cost.  ``serving.StepCostTable`` memoizes step
    pricing on this key, and this function is the single place that
    encodes the coupling — extend it if ``from_serving_step`` ever reads
    more structure out of ``decode_positions``.
    """
    return (tuple(prefill_lens), len(decode_positions),
            int(sum(decode_positions)))


def positions_for_signature(n_decode: int, pos_sum: int) -> Tuple[int, ...]:
    """A canonical ``decode_positions`` tuple realizing a signature's
    ``(n_decode, pos_sum)`` — any tuple with that length and sum lowers to
    bit-identical decode-op costs (see ``serving_step_signature``)."""
    if n_decode <= 0:
        return ()
    return (int(pos_sum) - (n_decode - 1),) + (1,) * (n_decode - 1)


# ---------------------------------------------------------------------------
# lowering 2d: one training step -> fwd/bwd/reduce/update chain


# AdamW arithmetic per parameter (two moment EMAs, bias correction, weight
# decay, the update itself) — the constant the optimizer-update op charges
OPTIMIZER_FLOPS_PER_PARAM = 12.0
# backward pass = grad wrt activations + grad wrt weights: the canonical
# 2x-forward FLOP accounting (recomputation/remat would add a third pass)
BWD_FLOPS_MULT = 2.0


def partition_stages(n_layers: int, n_stages: int) -> Tuple[int, ...]:
    """Balanced layer partition for pipeline parallelism: the first
    ``n_layers % n_stages`` stages carry one extra layer.  This is the
    single source of truth shared by the training simulator
    (``repro.sim.training``) and the real JAX pipeline
    (``repro.dist.pipeline``), so simulated and executed stage shares
    cannot drift apart."""
    n_layers, n_stages = int(n_layers), int(n_stages)
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_layers < n_stages:
        raise ValueError(
            f"cannot split {n_layers} layers over {n_stages} stages: "
            "every stage needs at least one layer")
    base, extra = divmod(n_layers, n_stages)
    return tuple(base + (1 if s < extra else 0) for s in range(n_stages))


# ---------------------------------------------------------------------------
# lowering: collectives -> per-hop fabric transfers
#
# Every algorithm step becomes one op on the lane of the fabric tier it
# crosses; steps chain, independent groups (distinct lanes) overlap.  On a
# homogeneous uncontended fabric the makespan is therefore the textbook
# closed form, asserted exactly in tests/test_collectives.py:
#
#   ring all-reduce       2*(p-1) steps of B/p   -> 2*(p-1)/p * B/bw
#                                                   + 2*(p-1)*lat
#   ring RS / AG          (p-1) steps of B/p     ->   (p-1)/p * B/bw
#                                                   + (p-1)*lat
#   tree all-reduce       2*ceil(log2 p) steps   -> 2*ceil(log2 p)
#                         of B                      * (lat + B/bw)
#   all-to-all            (p-1) pairwise steps   -> (p-1)*(lat + (B/p)/bw)
#                         of B/p
#   hierarchical          ring-RS within each sub-group, recursive
#   all-reduce            all-reduce of B/k across sub-group leads,
#                         ring-AG back — the composed per-tier bound.
#
# The k parallel shard-rings of the hierarchical cross-tier phase run on
# disjoint lanes with identical cost; the lowering emits the lead ring as
# their (equal-time) representative to keep programs small.

COLLECTIVE_KINDS = ("all_reduce", "reduce_scatter", "all_gather",
                    "all_to_all")
COLLECTIVE_ALGOS = ("ring", "tree", "hierarchical")


def _sinks(ops: Sequence[CostedOp]) -> Tuple[str, ...]:
    consumed = {d for op in ops for d in op.deps}
    return tuple(op.name for op in ops if op.name not in consumed)


def _hop_chain(prefix: str, n_steps: int, step_bytes: float, tier: str,
               lane: str, deps: Tuple[str, ...], phase: str,
               device_class: str, count: float) -> List[CostedOp]:
    """``n_steps`` chained per-hop transfers of ``step_bytes`` each on one
    lane; ``count`` compresses that many back-to-back collectives into the
    same ops (bytes and latency hops both scale — exact, since the steps
    serialize on the lane anyway)."""
    ops: List[CostedOp] = []
    for i in range(n_steps):
        nm = f"{prefix}/s{i}"
        ops.append(CostedOp(
            name=nm, collective_bytes=count * step_bytes,
            wire_bytes=count * step_bytes, tier=tier, lane=lane,
            hops=count, deps=deps if not ops else (ops[-1].name,),
            phase=phase, device_class=device_class))
    return ops


def _lower_collective(kind: str, nbytes: float, members: Tuple[int, ...],
                      fabric, prefix: str, deps: Tuple[str, ...],
                      phase: str, device_class: str,
                      count: float, algo: str) -> List[CostedOp]:
    p = len(members)
    if p <= 1:
        return []
    span = fabric.span_tier(members)
    tier = fabric.tiers[span].name
    lane = fabric.lane(members, span)
    if kind == "all_to_all":
        # pairwise exchange: each of the p-1 steps trades one B/p shard
        # with the k-th neighbor (algorithm choice does not change the
        # uncontended cost, so every algo lowers the same way)
        return _hop_chain(prefix, p - 1, nbytes / p, tier, lane, deps,
                          phase, device_class, count)
    if algo == "ring":
        steps = {"all_reduce": 2 * (p - 1), "reduce_scatter": p - 1,
                 "all_gather": p - 1}[kind]
        return _hop_chain(prefix, steps, nbytes / p, tier, lane, deps,
                          phase, device_class, count)
    if algo == "tree":
        depth = max(1, (p - 1).bit_length())   # ceil(log2 p)
        if kind == "all_reduce":
            # binomial reduce to the root + broadcast back: full payload
            # per level
            return _hop_chain(prefix, 2 * depth, nbytes, tier, lane, deps,
                              phase, device_class, count)
        # recursive halving (RS) / doubling (AG): level k moves B/2^k
        ops: List[CostedOp] = []
        sizes = [nbytes / (2 ** (k + 1)) for k in range(depth)]
        if kind == "all_gather":
            sizes.reverse()
        for i, sz in enumerate(sizes):
            nm = f"{prefix}/s{i}"
            ops.append(CostedOp(
                name=nm, collective_bytes=count * sz, wire_bytes=count * sz,
                tier=tier, lane=lane, hops=count,
                deps=deps if not ops else (ops[-1].name,),
                phase=phase, device_class=device_class))
        return ops
    if algo == "hierarchical":
        if kind != "all_reduce":
            raise ValueError(
                f"hierarchical lowering covers all_reduce only, got {kind}")
        if span == 0:
            return _lower_collective(kind, nbytes, members, fabric, prefix,
                                     deps, phase, device_class, count,
                                     "ring")
        per = fabric.leaves_per_group()[span - 1]
        groups: Dict[int, List[int]] = {}
        for m in members:
            groups.setdefault(m // per, []).append(m)
        subs = [tuple(sorted(g)) for g in groups.values()]
        if len(subs) == 1:
            return _lower_collective(kind, nbytes, members, fabric, prefix,
                                     deps, phase, device_class, count,
                                     "ring")
        k = len(subs[0])
        if any(len(s) != k for s in subs):
            raise ValueError(
                "hierarchical all_reduce needs uniform sub-groups per "
                f"tier, got sizes {[len(s) for s in subs]}")
        if k == 1:
            # nothing below the spanning tier: plain ring across members
            return _lower_collective(kind, nbytes, members, fabric, prefix,
                                     deps, phase, device_class, count,
                                     "ring")
        ops = []
        # phase 1: ring reduce-scatter inside every sub-group (parallel
        # lanes)
        for gi, sub in enumerate(subs):
            ops.extend(_lower_collective(
                "reduce_scatter", nbytes, sub, fabric, f"{prefix}/rs{gi}",
                deps, phase, device_class, count, "ring"))
        rs_sinks = _sinks(ops) if ops else deps
        # phase 2: all-reduce the B/k shard across the sub-group leads
        # (recursively hierarchical, so 3-tier fabrics compose)
        reps = tuple(s[0] for s in subs)
        up = _lower_collective("all_reduce", nbytes / k, reps, fabric,
                               f"{prefix}/up", rs_sinks, phase,
                               device_class, count, "hierarchical")
        ops.extend(up)
        up_sinks = _sinks(up) if up else rs_sinks
        # phase 3: ring all-gather back inside every sub-group
        for gi, sub in enumerate(subs):
            ops.extend(_lower_collective(
                "all_gather", nbytes, sub, fabric, f"{prefix}/ag{gi}",
                up_sinks, phase, device_class, count, "ring"))
        return ops
    raise ValueError(f"unknown collective algo {algo!r}; "
                     f"one of {COLLECTIVE_ALGOS}")


def from_collective(kind: str, nbytes: float, group, fabric=None, *,
                    algo: str = "ring", count: float = 1.0,
                    prefix: str = "", phase: str = "collective",
                    deps: Sequence[str] = (),
                    device_class: str = "accel",
                    name: str = "") -> Program:
    """Lower ONE collective over ``group`` into per-hop fabric transfers.

    ``group`` is a member-id sequence or a plain count (members ``0..p-1``);
    ``fabric`` defaults to a flat single-tier ICI fabric over the group.
    ``count`` compresses that many identical back-to-back collectives
    (e.g. one per transformer layer) into the same per-hop ops — bytes
    and latency hops scale together, so the cost is exact.  A 1-member
    group lowers to the empty Program (composing it via ``Program.then``
    is bit-identical to a no-op; asserted in tests/test_collectives.py).
    """
    from repro.sim import hw
    if kind not in COLLECTIVE_KINDS:
        raise ValueError(f"unknown collective kind {kind!r}; "
                         f"one of {COLLECTIVE_KINDS}")
    if algo not in COLLECTIVE_ALGOS:
        raise ValueError(f"unknown collective algo {algo!r}; "
                         f"one of {COLLECTIVE_ALGOS}")
    members = (tuple(range(int(group))) if isinstance(group, int)
               else tuple(int(m) for m in group))
    if len(set(members)) != len(members):
        raise ValueError(f"duplicate members in collective group {members}")
    if fabric is None:
        fabric = hw.Fabric.single_tier(max(members) + 1 if members else 1)
    ops = _lower_collective(kind, float(nbytes), members, fabric,
                            prefix or kind, tuple(deps), phase,
                            device_class, float(count), algo)
    return Program(ops, name=name or f"{kind}/{algo}", source="collective",
                   meta={"kind": kind, "algo": algo, "nbytes": float(nbytes),
                         "group": members, "count": float(count),
                         "fabric": fabric.describe()})


def collective_time(kind: str, nbytes: float, group, fabric=None, *,
                    algo: str = "ring", count: float = 1.0,
                    config=None) -> float:
    """Uncontended analytic time of one collective: the longest
    dependency path over the lowered per-hop ops, priced from ``config``
    (default ``EngineConfig()``) via ``hw.resolve_tier_params``.  Parallel
    sub-group chains live on disjoint lanes, so on an otherwise idle
    fabric the engine's makespan equals this bound exactly."""
    from repro.sim import hw
    if config is None:
        from repro.sim.engine import EngineConfig
        config = EngineConfig()
    prog = from_collective(kind, nbytes, group, fabric, algo=algo,
                           count=count)
    finish: Dict[str, float] = {}
    for op in prog.ops:    # lowering emits in topological order
        lat, bw = hw.resolve_tier_params(config, op.tier)
        cost = op.hops * lat + op.collective_bytes / bw
        start = max((finish[d] for d in op.deps if d in finish),
                    default=0.0)
        finish[op.name] = start + cost
    return max(finish.values(), default=0.0)


def _training_terms(cfg, seq_len: int, batch: int, bytes_per_param: float,
                    bytes_per_act: float) -> Dict[str, float]:
    """Whole-model per-step cost terms of one fwd+bwd over ``batch``
    sequences of ``seq_len`` tokens — the shared accounting behind
    ``from_training_step``.

      fwd_flops    dense ``2 * N_active * tokens`` plus the causal
                   attention term ``4 * n_attn * kv_dim * S*(S-1)/2`` per
                   sequence (the ``from_serving_step`` prefill formula),
      act_bytes    stored activations: one residual-stream tensor per
                   layer (``n_layers * d_model * tokens * bytes_per_act``),
                   written by the forward and re-read by the backward,
      weight_bytes streamed active weights (charged per pass — training
                   streams them forward AND backward),
      grad_bytes   dense gradient traffic (active params),
      opt_params   the full parameter count the optimizer state covers
                   (MoE: every expert has moments, not just routed ones).
    """
    n_active, kv_dim, n_attn, weight_bytes = \
        _decode_terms(cfg, bytes_per_param)
    tokens = float(batch) * float(seq_len)
    attn = 4.0 * n_attn * kv_dim * (seq_len * (seq_len - 1) // 2) * batch
    return {
        "fwd_flops": 2.0 * n_active * tokens + attn,
        "act_bytes": float(cfg.n_layers) * float(cfg.d_model) * tokens
        * bytes_per_act,
        "weight_bytes": weight_bytes,
        "grad_bytes": n_active * bytes_per_param,
        "opt_params": float(cfg.param_count()),
        "tokens": tokens,
    }


def from_training_step(cfg, *, seq_len: int = 1024, batch: int = 8,
                       stage: Optional[int] = None, n_stages: int = 1,
                       bytes_per_param: float = 2.0,
                       bytes_per_act: float = 2.0,
                       optimizer_bytes_per_param: float = 12.0,
                       dp_degree: int = 1, tp_degree: int = 1,
                       fabric=None, collective_algo: str = "ring",
                       overlap_dp: bool = False,
                       tp_group: Optional[Sequence[int]] = None,
                       dp_group: Optional[Sequence[int]] = None,
                       name: str = "") -> Program:
    """Lower ONE training optimizer step to a <=4-op chain Program.

    The chain is ``fwd -> bwd [-> reduce] -> update``:

      ``train/fwd``     forward over ``batch`` sequences: streams the
                        (active) weights, writes the stored activations;
      ``train/bwd``     backward at ``BWD_FLOPS_MULT`` (2x) the forward
                        FLOPs: re-streams the weights, RE-READS the stored
                        activations, writes the dense gradients;
      ``train/reduce``  the data-parallel gradient all-reduce, emitted only
                        when ``dp_degree > 1``: operand-sum metric =
                        gradient bytes, ring wire bytes =
                        ``2 * (d-1)/d * grad_bytes``;
      ``train/update``  the AdamW update: ``OPTIMIZER_FLOPS_PER_PARAM``
                        flops per (full, not active) parameter, reading the
                        gradients + optimizer state
                        (``optimizer_bytes_per_param`` covers fp32 m, v and
                        master weights) and writing the state back plus the
                        fresh streaming weights.

    ``stage``/``n_stages`` select one pipeline stage's share of the model:
    the layers partition via ``partition_stages`` and every term scales by
    ``layers_in_stage / n_layers`` (embeddings and the attention mix are
    apportioned uniformly — a deliberate first-order model).  ``stage=None``
    with ``n_stages=1`` is the whole model; the training simulator
    (``repro.sim.training``) calls this per stage and per microbatch, so a
    1-stage 1-microbatch simulated step is THIS chain, bit for bit.

    **Cluster placement** (``fabric`` given): compute, weights, gradients
    and optimizer state shard ``tp_degree``-ways (Megatron-style — the
    residual-stream activations stay replicated per TP rank), with two
    TP all-reduces per layer per pass lowered via ``from_collective``
    (compressed: ``count = 2 * layers``) after the forward and the
    backward; the DP gradient all-reduce becomes explicit per-hop
    transfers over ``dp_group`` with ``collective_algo``
    (ring / tree / hierarchical) instead of the legacy single
    ``train/reduce`` op.  ``overlap_dp`` starts the gradient all-reduce
    alongside the backward (grads stream out as bwd retires layers;
    first-order), with the update waiting on both.  ``tp_group`` /
    ``dp_group`` place the collectives on fabric member ids (defaults:
    TP ranks ``0..tp-1``, DP peers at stride ``tp_degree``).  With
    ``fabric=None`` the legacy <=4-op chain is produced bit-for-bit.
    """
    if n_stages > 1 and stage is None:
        raise ValueError("stage index required when n_stages > 1; "
                         "use repro.sim.training for the full pipeline")
    tp = int(tp_degree)
    dp = int(dp_degree)
    if fabric is None:
        if tp != 1:
            raise ValueError(
                "tp_degree > 1 requires a fabric; pass "
                "hw.Fabric.single_tier(tp_degree * dp_degree) for a flat "
                "group")
        if overlap_dp:
            raise ValueError("overlap_dp requires a fabric")
    share = 1.0
    layers_here = float(cfg.n_layers)
    if stage is not None:
        layers = partition_stages(cfg.n_layers, n_stages)
        if not 0 <= stage < n_stages:
            raise ValueError(f"stage {stage} out of range for "
                             f"{n_stages} stages")
        share = layers[stage] / float(cfg.n_layers)
        layers_here = float(layers[stage])
    t = _training_terms(cfg, seq_len, batch, bytes_per_param, bytes_per_act)
    fwd_flops = t["fwd_flops"] * share
    act_bytes = t["act_bytes"] * share
    weight_bytes = t["weight_bytes"] * share
    grad_bytes = t["grad_bytes"] * share
    opt_params = t["opt_params"] * share
    if tp > 1:   # TP shards compute/weights/grads/state; acts replicate
        fwd_flops /= tp
        weight_bytes /= tp
        grad_bytes /= tp
        opt_params /= tp
    opt_state_bytes = opt_params * optimizer_bytes_per_param

    ops = [
        CostedOp(name="train/fwd", flops=fwd_flops, dot_flops=fwd_flops,
                 bytes_in=weight_bytes, bytes_out=act_bytes,
                 phase="fwd", device_class="accel"),
    ]
    fwd_side: Tuple[str, ...] = ("train/fwd",)
    tp_members: Tuple[int, ...] = ()
    if fabric is not None and tp > 1:
        tp_members = (tuple(int(m) for m in tp_group)
                      if tp_group is not None else tuple(range(tp)))
        if len(tp_members) != tp:
            raise ValueError(f"tp_group has {len(tp_members)} members "
                             f"for tp_degree={tp}")
        # two all-reduces per layer per pass over the residual stream
        tp_bytes = t["tokens"] * float(cfg.d_model) * bytes_per_act
        tpf = from_collective("all_reduce", tp_bytes, tp_members, fabric,
                              algo=collective_algo,
                              count=2.0 * layers_here,
                              prefix="train/tpf", phase="tp",
                              deps=fwd_side)
        ops.extend(tpf.ops)
        if tpf.ops:
            fwd_side = _sinks(tpf.ops)
    ops.append(
        CostedOp(name="train/bwd",
                 flops=BWD_FLOPS_MULT * fwd_flops,
                 dot_flops=BWD_FLOPS_MULT * fwd_flops,
                 bytes_in=weight_bytes + act_bytes,   # activation re-reads
                 bytes_out=grad_bytes,
                 deps=fwd_side, phase="bwd", device_class="accel"))
    bwd_side: Tuple[str, ...] = ("train/bwd",)
    if fabric is not None and tp > 1:
        tp_bytes = t["tokens"] * float(cfg.d_model) * bytes_per_act
        tpb = from_collective("all_reduce", tp_bytes, tp_members, fabric,
                              algo=collective_algo,
                              count=2.0 * layers_here,
                              prefix="train/tpb", phase="tp",
                              deps=bwd_side)
        ops.extend(tpb.ops)
        if tpb.ops:
            bwd_side = _sinks(tpb.ops)
    update_deps: Tuple[str, ...] = bwd_side
    if dp > 1:
        if fabric is None:
            ops.append(CostedOp(
                name="train/reduce",
                collective_bytes=grad_bytes,
                wire_bytes=2.0 * (dp - 1) / dp * grad_bytes,
                deps=bwd_side, phase="reduce", device_class="accel"))
            update_deps = ("train/reduce",)
        else:
            dp_members = (tuple(int(m) for m in dp_group)
                          if dp_group is not None
                          else tuple(d * tp for d in range(dp)))
            if len(dp_members) != dp:
                raise ValueError(f"dp_group has {len(dp_members)} members "
                                 f"for dp_degree={dp}")
            red = from_collective("all_reduce", grad_bytes, dp_members,
                                  fabric, algo=collective_algo,
                                  prefix="train/dp", phase="reduce",
                                  deps=fwd_side if overlap_dp else bwd_side)
            ops.extend(red.ops)
            red_sinks = _sinks(red.ops) if red.ops else ()
            if overlap_dp:
                update_deps = tuple(bwd_side) + red_sinks
            else:
                update_deps = red_sinks or bwd_side
    ops.append(CostedOp(
        name="train/update",
        flops=OPTIMIZER_FLOPS_PER_PARAM * opt_params,
        bytes_in=grad_bytes + opt_state_bytes,
        bytes_out=opt_state_bytes + weight_bytes,
        deps=update_deps, phase="opt", device_class="accel"))
    return Program(ops, name=name or f"{getattr(cfg, 'name', 'model')}"
                   f"/train", source="training",
                   meta={"seq_len": seq_len, "batch": batch,
                         "stage": stage, "n_stages": n_stages,
                         "dp_degree": dp_degree, "tp_degree": tp,
                         "share": share, "tokens": t["tokens"],
                         "collective_algo": collective_algo,
                         "overlap_dp": bool(overlap_dp),
                         "fabric": fabric.describe() if fabric is not None
                         else None})


# ---------------------------------------------------------------------------
# lowering 3: legacy TileTask lists (scheduler compat)


def from_tasks(tasks: Sequence, name: str = "tasks") -> Program:
    """Lower ``core.scheduler.TileTask``s, preserving their explicit times."""
    ops = [CostedOp(name=t.name,
                    duration_s=float(t.duration),
                    transfer_s=float(t.transfer) if t.transfer else 0.0,
                    deps=tuple(t.deps),
                    affinity=t.affinity,
                    phase=t.name.split("/")[0])
           for t in tasks]
    return Program(ops, name=name, source="tasks")
