"""Shared reporting layer: the result dataclasses every figure reads, and
the per-kind / per-phase aggregations previously duplicated across
``core/simulator.py``, ``core/timeline.py`` and the benchmarks.

``Breakdown`` and ``Roofline`` live here (and are re-exported by
``repro.core.simulator`` for API stability) so the engine, the closed-form
wrappers, and the benchmarks all speak the same types.  The serving layer
adds population statistics: ``percentile`` (deterministic linear
interpolation, no numpy dependency in the hot path) and ``latency_stats``
(the p50/p90/p99/mean/max summary every serving table reports).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional

from repro.sim.hw import HOST_OVERHEAD_S, PEAK_FLOPS


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    bound: str
    step_s: float                # max of terms (+ host floor)
    roofline_fraction: float     # ideal compute_s / step_s
    detail: Dict = field(default_factory=dict)

    def to_dict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio, "bound": self.bound,
            "step_s": self.step_s,
            "roofline_fraction": self.roofline_fraction,
            **self.detail,
        }


@dataclass
class Breakdown:
    """End-to-end phase breakdown (Fig 1 analogue)."""
    accelerator_s: float
    transfer_s: float
    host_s: float
    collective_s: float

    @property
    def total_s(self):
        return (self.accelerator_s + self.transfer_s + self.host_s
                + self.collective_s)

    def fractions(self):
        t = self.total_s or 1.0
        return {"accelerator": self.accelerator_s / t,
                "transfer": self.transfer_s / t,
                "host": self.host_s / t,
                "collective": self.collective_s / t}


# ---------------------------------------------------------------------------
# aggregations over timeline events


def aggregate(events: Iterable, key: str = "kind") -> Dict[str, float]:
    """Sum event durations grouped by an event attribute (kind/worker/phase
    — phase uses the event's phase tag, else the op-name prefix)."""
    out: Dict[str, float] = {}
    get = out.get
    if key == "kind":              # the hot aggregation: direct attribute
        for e in events:
            k = e.kind
            out[k] = get(k, 0.0) + e.duration
        return out
    for e in events:
        if key == "phase":
            k = getattr(e, "phase", "") or e.name.split("/")[0]
        else:
            k = getattr(e, key)
        out[k] = get(k, 0.0) + e.duration
    return out


def breakdown_from_events(events: Iterable,
                          host_floor_s: float = 0.0) -> Breakdown:
    """Fig-1 breakdown as pure aggregation of a simulated timeline."""
    kinds = aggregate(events, "kind")
    return Breakdown(
        accelerator_s=kinds.get("compute", 0.0),
        transfer_s=kinds.get("transfer", 0.0),
        host_s=kinds.get("host", 0.0) + host_floor_s,
        collective_s=kinds.get("collective", 0.0))


def per_device(events: Iterable) -> Dict[str, Dict[str, float]]:
    """Per-device kind->seconds aggregation of a simulated timeline: what
    each ``SoCTopology`` device (plus the ``host`` and ``ici`` pseudo
    lanes) spent its time on.  The heterogeneous analogue of
    ``aggregate(events, "kind")``."""
    out: Dict[str, Dict[str, float]] = {}
    for e in events:
        d = out.setdefault(e.worker, {})
        d[e.kind] = d.get(e.kind, 0.0) + e.duration
    return out


def device_breakdowns(events: Iterable) -> Dict[str, Breakdown]:
    """Fig-1 style ``Breakdown`` per device.  Host-dispatch events live on
    the ``host`` lane, collectives on ``ici``, so a compute device's row
    typically carries only its compute + transfer seconds; run-level host
    floors are a whole-run property and are not attributed here."""
    return {dev: Breakdown(accelerator_s=kinds.get("compute", 0.0),
                           transfer_s=kinds.get("transfer", 0.0),
                           host_s=kinds.get("host", 0.0),
                           collective_s=kinds.get("collective", 0.0))
            for dev, kinds in per_device(events).items()}


def roofline_from_totals(totals: Dict[str, float], *, host_s: float,
                         n_chips: int = 1, model_flops: float = 0.0,
                         peak_flops: float = PEAK_FLOPS,
                         hbm_bw: float = None, ici_bw: float = None
                         ) -> Roofline:
    """Roofline object from program aggregates (identical to the legacy
    closed form: the terms are per-device sums over the same op set)."""
    from repro.sim import hw
    hbm_bw = hbm_bw or hw.HBM_BW
    ici_bw = ici_bw or hw.ICI_BW
    comp = totals["flops"] / peak_flops
    mem = (totals["bytes_in"] + totals["bytes_out"]) / hbm_bw
    # lowerings resolve the wire-vs-operand-sum choice; use wire as-is
    coll = totals.get("wire_bytes", 0.0) / ici_bw
    terms = {"compute": comp, "memory": mem, "collective": coll}
    bound = max(terms, key=terms.get)
    step = max(comp, mem, coll) + host_s
    hlo_total = totals["flops"] * n_chips
    ideal = (model_flops / n_chips) / peak_flops if n_chips else 0.0
    return Roofline(
        compute_s=comp, memory_s=mem, collective_s=coll,
        model_flops=model_flops, hlo_flops=hlo_total,
        useful_ratio=(model_flops / hlo_total) if hlo_total else 0.0,
        bound=bound, step_s=step,
        roofline_fraction=(ideal / step) if step else 0.0,
        detail={"ideal_compute_s": ideal, "host_s": host_s,
                "n_chips": n_chips})


def percentile(values: Iterable[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation between order
    statistics — same convention as ``numpy.percentile(...,
    method="linear")``, but deterministic pure Python so serving metrics
    stay bit-reproducible across numpy versions.  Empty input -> 0.0."""
    xs = sorted(values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    rank = (len(xs) - 1) * (q / 100.0)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] + (xs[hi] - xs[lo]) * frac


def latency_stats(values: Iterable[float]) -> Dict[str, float]:
    """p50/p90/p99/mean/max summary of a latency population (seconds in,
    seconds out).  ``n`` carries the population size; an empty population
    yields all-zero stats."""
    xs = sorted(values)
    if not xs:
        return {"p50": 0.0, "p90": 0.0, "p99": 0.0, "mean": 0.0,
                "max": 0.0, "n": 0}
    return {"p50": percentile(xs, 50), "p90": percentile(xs, 90),
            "p99": percentile(xs, 99), "mean": sum(xs) / len(xs),
            "max": xs[-1], "n": len(xs)}


def latency_stats_array(values) -> Dict[str, float]:
    """``latency_stats`` vectorized for large populations: the sort runs
    in C (``numpy.sort``), the percentiles use the exact scalar
    interpolation formula of :func:`percentile`, and the mean sums the
    *sorted* values left to right — so every field is bit-identical to
    the pure-Python path on the same (NaN-free) population.  Outputs are
    Python floats (json-serializable)."""
    import numpy as np
    xs = np.sort(np.asarray(values, dtype=np.float64).ravel())
    n = int(xs.size)
    if n == 0:
        return {"p50": 0.0, "p90": 0.0, "p99": 0.0, "mean": 0.0,
                "max": 0.0, "n": 0}
    lst = xs.tolist()          # Python floats; sum(lst) matches sum(sorted)

    def pct(q: float) -> float:
        if n == 1:
            return lst[0]
        rank = (n - 1) * (q / 100.0)
        lo = int(rank)
        hi = min(lo + 1, n - 1)
        frac = rank - lo
        return lst[lo] + (lst[hi] - lst[lo]) * frac

    return {"p50": pct(50), "p90": pct(90), "p99": pct(99),
            "mean": sum(lst) / n, "max": lst[-1], "n": n}


def row(name: str, seconds: float, derived: str) -> Dict[str, object]:
    """The ``name,us_per_call,derived`` CSV convention of benchmarks/run.py."""
    return {"name": name, "us_per_call": round(seconds * 1e6, 1),
            "derived": derived}


def fractions_str(b: Breakdown) -> str:
    f = b.fractions()
    return (f"accel={f['accelerator']*100:.0f}% "
            f"transfer={f['transfer']*100:.0f}% "
            f"host={f['host']*100:.0f}% "
            f"coll={f['collective']*100:.0f}%")
