"""Pipeline-parallel training simulation: schedules, stages, the engine.

SMAUG's argument — end-to-end behavior is dominated by what happens
*around* the accelerator — applies with full force to distributed
training: the pipeline *schedule* (when each stage runs which microbatch)
and the inter-stage activation/gradient transfers decide step time as much
as per-stage kernel speed does.  This module opens that workload class on
the existing event engine: a training step (``ir.from_training_step``) is
split over ``n_stages`` pipeline stages, each stage pinned to one device
of a PR-4 ``SoCTopology``, and the per-(stage, microbatch) forward /
backward work items are serialized per device in the exact order of a
classic pipeline schedule:

  ``gpipe``  all M forwards, then all M backwards (the flush schedule:
             largest bubble, simplest memory profile);
  ``1f1b``   the Megatron one-forward-one-backward order: stage ``s``
             warms up with ``min(S-1-s, M)`` forwards, then alternates
             F/B in steady state, then drains the remaining backwards —
             same bubble bound as GPipe on homogeneous stages, and never
             slower on an *uncontended* homogeneous pipe.  On a
             port-constrained shared link (or a congested serial host
             lane) 1F1B keeps both pipeline directions in flight at
             once — roughly double GPipe's concurrent demand — and can
             genuinely lose to the flush schedule
             (``benchmarks/bench_training.py`` records the inversion).

How the co-simulation works.  The schedule is *encoded in the program*:
every op depends on its predecessor in its device's schedule order (the
serialization edge) in addition to its dataflow deps (``F(s,m)`` needs the
activation transfer from stage ``s-1``; ``B(s,m)`` needs ``F(s,m)``'s
stored activations and the gradient transfer from stage ``s+1``).  Any
topological execution of that DAG yields the same timing, so the engine's
event loop — with per-device placement via per-stage ``device_class``
tags, per-link transfer contention, the host model and the ICI lane —
prices the schedule exactly.  Inter-stage boundary tensors
(``d_model * microbatch_tokens * bytes_per_act``) are explicit transfer
ops placed on the *receiving* stage, so they contend on that device's
link like any other traffic.

``TrainingResult`` reports the step time, per-stage utilization, and the
measured pipeline bubble fraction next to the analytic homogeneous bound
``(p-1)/(m+p-1)`` (equal ideal per-microbatch cost, free transfers).  A
1-stage 1-microbatch simulation is bit-identical to running the flat
``ir.from_training_step`` chain through ``engine.run`` — asserted in
``tests/test_training.py`` — and the whole layer is deterministic: same
config, same result, bit for bit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.sim import engine, ir
from repro.sim.engine import EngineConfig, EngineResult
from repro.sim.hw import Device, Link, SoCTopology
from repro.sim.ir import CostedOp, Program, partition_stages

__all__ = ["TrainingResult", "SCHEDULES", "bubble_bound",
           "simulate_training", "schedule_order", "partition_stages"]

SCHEDULES = ("gpipe", "1f1b")


def bubble_bound(n_stages: int, n_microbatches: int) -> float:
    """The analytic pipeline bubble fraction ``(p-1)/(m+p-1)`` for
    homogeneous stages with equal per-microbatch cost and free
    transfers — both GPipe and 1F1B meet it exactly in that regime."""
    return (n_stages - 1) / float(n_microbatches + n_stages - 1)


def schedule_order(schedule: str, stage: int, n_stages: int,
                   n_microbatches: int) -> List[Tuple[str, int]]:
    """The work-item order of one stage under a schedule: a list of
    ``("F"|"B", microbatch)`` covering every microbatch exactly once in
    each direction.  This IS the per-device serialization order the
    simulator encodes as dependency edges."""
    m = n_microbatches
    if schedule == "gpipe":
        return [("F", i) for i in range(m)] + [("B", i) for i in range(m)]
    if schedule == "1f1b":
        nw = min(n_stages - 1 - stage, m)
        order = [("F", i) for i in range(nw)]
        for i in range(m - nw):
            order.append(("F", nw + i))
            order.append(("B", i))
        order.extend(("B", i) for i in range(m - nw, m))
        return order
    raise ValueError(f"unknown schedule {schedule!r}; one of {SCHEDULES}")


@dataclass
class TrainingResult:
    """Everything one simulated training step produced.

    ``engine`` is the ordinary ``EngineResult`` of the scheduled step
    program; ``step_time_s`` is its makespan (reduce + optimizer update
    included).  ``bubble_fraction`` is measured over the *pipeline body*
    (first forward start to last backward end, forward/backward compute
    only — transfers, reduce and update excluded), so on homogeneous
    stages with an ideal interface it equals ``bubble_bound`` to float
    precision."""
    program: Program
    engine: EngineResult
    schedule: str
    n_stages: int
    n_microbatches: int
    step_time_s: float
    tokens: float
    per_stage_busy_s: Dict[str, float]
    per_stage_utilization: Dict[str, float]
    bubble_fraction: float
    bubble_bound: float
    config: EngineConfig
    meta: Dict = field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.step_time_s if self.step_time_s else 0.0

    def stats(self) -> Dict[str, float]:
        """Tidy scalar summary (the ``as_training_records`` row body)."""
        utils = list(self.per_stage_utilization.values())
        return {
            "step_time_s": self.step_time_s,
            "tokens_per_s": self.tokens_per_s,
            "bubble_fraction": self.bubble_fraction,
            "bubble_bound": self.bubble_bound,
            "bubble_excess": self.bubble_fraction - self.bubble_bound,
            "stage_util_mean": sum(utils) / len(utils) if utils else 0.0,
            "stage_util_min": min(utils) if utils else 0.0,
            "collective_s": self.engine.breakdown.collective_s,
            "n_ops": float(len(self.program.ops)),
        }


def _stage_topology(config: EngineConfig, n_stages: int
                    ) -> Tuple[SoCTopology, Tuple[str, ...]]:
    """(topology with per-stage placement kinds, stage device names).

    ``config.topology`` set: its accelerator-class devices, in declaration
    order, become the stages (kinds rewritten to ``stage<s>``; per-device
    overrides — a slower stage, a different link — are preserved, which is
    exactly how heterogeneous-stage studies are set up).  A topology with
    NO accelerator-class devices follows the engine's placement-fallback
    convention (class -> accel -> any): every device is stage-capable, so
    training on an all-cpu/dsp SoC runs on those devices at their own
    cost parameters.  Unset: the homogeneous expansion — ``n_stages``
    identical stage devices on one shared link inheriting every flat
    field.
    """
    if config.topology is not None:
        topo = config.topology
        accel = [i for i, d in enumerate(topo.devices) if d.kind == "accel"]
        if not accel:
            accel = list(range(len(topo.devices)))
        if len(accel) < n_stages:
            raise ValueError(
                f"topology {topo.name!r} has {len(accel)} stage-capable "
                f"devices but the schedule needs {n_stages}")
        chosen = accel[:n_stages]
        devices = list(topo.devices)
        names = []
        for s, i in enumerate(chosen):
            devices[i] = dataclasses.replace(devices[i], kind=f"stage{s}")
            names.append(devices[i].name)
        return (SoCTopology(devices=tuple(devices), links=topo.links,
                            name=topo.name), tuple(names))
    devices = tuple(Device(f"stage{s}", kind=f"stage{s}")
                    for s in range(n_stages))
    return (SoCTopology(devices=devices, links=(Link("hbm"),),
                        name=f"{n_stages}stage"),
            tuple(d.name for d in devices))


def simulate_training(cfg, *, n_stages: int = 1, n_microbatches: int = 1,
                      schedule: str = "1f1b", seq_len: int = 512,
                      global_batch: int = 8,
                      config: Optional[EngineConfig] = None,
                      bytes_per_param: float = 2.0,
                      bytes_per_act: float = 2.0,
                      dp_degree: int = 1, tp_degree: int = 1,
                      fabric=None, collective_algo: str = "ring",
                      name: str = "") -> TrainingResult:
    """Simulate one pipeline-parallel training step; see the module header.

    ``cfg`` is a ``repro.core.config.ModelConfig``; ``config`` defaults to
    a fresh flat ``EngineConfig()`` (``None`` sentinel).
    ``n_microbatches`` must divide ``global_batch`` evenly (every
    microbatch carries the same sequences).  With ``n_stages == 1``
    and no topology the program runs on the flat config unchanged, so the
    single-stage single-microbatch case is the plain
    ``ir.from_training_step`` chain.

    **Cluster placement** (``fabric`` given): one global rank per
    accelerator, ``rank(d, s, t) = (d * n_stages + s) * tp_degree + t``
    (TP fastest-varying, so TP groups sit on the innermost fabric tiers).
    DP-rank 0's pipeline is simulated; the collectives it participates in
    are lowered to explicit per-hop fabric transfers
    (``ir.from_collective``): TP all-reduces after every forward/backward
    (per stage, per microbatch, on the stage's TP-group lane), pipeline
    boundary tensors as hops on the tier the adjacent stages span, and
    the per-stage DP gradient all-reduce with ``collective_algo`` — which
    starts as soon as THAT stage's last backward retires, so late stages'
    gradient reduction genuinely overlaps earlier stages' backwards.
    """
    if config is None:
        config = EngineConfig()
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"one of {SCHEDULES}")
    n_stages = int(n_stages)
    n_microbatches = int(n_microbatches)
    tp_degree = int(tp_degree)
    dp_degree = int(dp_degree)
    if n_microbatches < 1:
        raise ValueError(f"n_microbatches must be >= 1, "
                         f"got {n_microbatches}")
    if global_batch % n_microbatches:
        raise ValueError(
            f"global_batch {global_batch} is not divisible by "
            f"n_microbatches {n_microbatches}")
    mb_batch = global_batch // n_microbatches
    if tp_degree > 1 and fabric is None:
        raise ValueError("tp_degree > 1 requires a fabric")
    n_accel = dp_degree * n_stages * tp_degree
    if fabric is not None and fabric.n_accel < n_accel:
        raise ValueError(
            f"fabric {fabric.describe()} has {fabric.n_accel} "
            f"accelerators; placement dp{dp_degree} x pp{n_stages} x "
            f"tp{tp_degree} needs {n_accel}")

    pinned = n_stages > 1 or config.topology is not None
    if pinned:
        topo, stage_devs = _stage_topology(config, n_stages)
        run_config = dataclasses.replace(config, topology=topo)
    else:
        topo, stage_devs = None, ("",)
        run_config = config
    if fabric is not None and config.fabric is None:
        # per-tier rate overrides on the Fabric resolve through the config
        run_config = dataclasses.replace(run_config, fabric=fabric)

    # placement: global rank of (dp, stage, tp) under the rank convention
    def tp_members(s: int) -> Tuple[int, ...]:
        base = s * tp_degree                  # dp rank 0
        return tuple(base + t for t in range(tp_degree))

    def dp_members(s: int) -> Tuple[int, ...]:
        return tuple((d * n_stages + s) * tp_degree
                     for d in range(dp_degree))

    # per-stage cost templates: ir.from_training_step is the single source
    # of cost truth (fwd/bwd per microbatch; reduce/update once per stage)
    templates = [ir.from_training_step(
        cfg, seq_len=seq_len, batch=mb_batch,
        stage=(s if n_stages > 1 else None), n_stages=n_stages,
        bytes_per_param=bytes_per_param, bytes_per_act=bytes_per_act,
        dp_degree=dp_degree, tp_degree=tp_degree, fabric=fabric,
        collective_algo=collective_algo,
        tp_group=tp_members(s) if fabric is not None else None,
        dp_group=dp_members(s) if fabric is not None else None)
        for s in range(n_stages)]
    by_name = [{op.name: op for op in t.ops} for t in templates]

    # hop segments of each template (empty without a fabric): the TP
    # all-reduce after fwd/bwd and the per-stage DP gradient reduce
    def _segment(t: Program, prefix: str):
        sel = [op for op in t.ops if op.name.startswith(prefix)]
        names = {o.name for o in sel}
        # internal deps precomputed once: per clone only the rename varies
        pre = [(o, tuple(d for d in o.deps if d in names)) for o in sel]
        return pre, (ir._sinks(sel) if sel else ())

    tpf_seg = [_segment(t, "train/tpf") for t in templates]
    tpb_seg = [_segment(t, "train/tpb") for t in templates]
    dp_seg = [_segment(t, "train/dp") for t in templates]

    def f_out(s: int, m: int) -> Tuple[str, ...]:
        """Names the stage-s microbatch-m forward RESULT waits on (the
        TP all-reduce sinks when TP is on, else the fwd op itself)."""
        sinks = tpf_seg[s][1]
        return (tuple(f"{n}@s{s}m{m}" for n in sinks)
                or (f"F/s{s}/m{m}",))

    def b_out(s: int, m: int) -> Tuple[str, ...]:
        sinks = tpb_seg[s][1]
        return (tuple(f"{n}@s{s}m{m}" for n in sinks)
                or (f"B/s{s}/m{m}",))

    # one residual-stream tensor crosses each stage boundary per microbatch
    boundary_bytes = (float(cfg.d_model) * mb_batch * seq_len
                      * bytes_per_act)

    def cls(s: int) -> str:
        return f"stage{s}" if pinned else "accel"

    _bh_tmpl: Dict[Tuple[int, int], CostedOp] = {}
    _new = object.__new__

    def boundary_hop(nm: str, lo: int, recv: int,
                     deps: Tuple[str, ...]) -> CostedOp:
        """The stage-(lo)<->(lo+1) boundary tensor, placed on receiving
        stage ``recv``.  Stages sharing a chip (span tier 0) keep the
        legacy device-transfer modeling — which is what makes a
        single-tier fabric bit-identical to the pre-fabric simulator;
        stages on different chips/nodes ride the fabric tier their member
        sets span.  The op is identical across microbatches except for
        name/deps, so the tier resolution is cached per stage pair."""
        tmpl = _bh_tmpl.get((lo, recv))
        if tmpl is None:
            members = tp_members(lo) + tp_members(lo + 1)
            ti = fabric.span_tier(members)
            if ti == 0:
                tmpl = CostedOp(name="", bytes_in=boundary_bytes,
                                phase=f"s{recv}", device_class=cls(recv))
            else:
                tmpl = CostedOp(name="",
                                collective_bytes=boundary_bytes,
                                wire_bytes=boundary_bytes,
                                tier=fabric.tiers[ti].name,
                                lane=fabric.lane(members, ti),
                                phase=f"s{recv}",
                                device_class=cls(recv))
            _bh_tmpl[(lo, recv)] = tmpl
        c = _new(CostedOp)
        d = c.__dict__
        d.update(tmpl.__dict__)
        d["name"] = nm
        d["deps"] = deps
        return c

    ops: List[CostedOp] = []
    ops_append = ops.append
    for s in range(n_stages):
        prev: Tuple[str, ...] = ()      # serialization edge on this device
        ph = f"s{s}"
        dc = cls(s)

        def emit(op: CostedOp) -> None:
            nonlocal prev
            deps = tuple(op.deps)
            add = tuple(p for p in prev if p not in deps)
            c = _new(CostedOp)
            d = c.__dict__
            d.update(op.__dict__)
            d["deps"] = add + deps
            ops_append(c)
            prev = (op.name,)

        def emit_t(op: CostedOp, nm: str, deps: Tuple[str, ...]) -> None:
            """emit() of a per-stage template op restamped with
            name/deps/phase/device_class — the hot clone path."""
            nonlocal prev
            add = tuple(p for p in prev if p not in deps)
            c = _new(CostedOp)
            d = c.__dict__
            d.update(op.__dict__)
            d["name"] = nm
            d["deps"] = add + deps
            d["phase"] = ph
            d["device_class"] = dc
            ops_append(c)
            prev = (nm,)

        def emit_hops(seg, tag: str, roots: Tuple[str, ...]) -> None:
            """Clone a hop segment under ``tag``: internal deps rename
            with it, the segment's roots re-root on ``roots``.  Parallel
            branches (hierarchical sub-group chains) stay parallel — only
            the segment as a whole serializes with the device's schedule
            (via ``roots``/``prev``), matching a blocking collective."""
            nonlocal prev
            seg_ops, seg_sinks = seg
            at = "@" + tag
            for o, idep in seg_ops:
                c = _new(CostedOp)
                d = c.__dict__
                d.update(o.__dict__)
                d["name"] = o.name + at
                d["deps"] = (tuple(dp + at for dp in idep) or roots)
                d["phase"] = ph
                ops_append(c)
            prev = tuple(n + at for n in seg_sinks)

        for kind, m in schedule_order(schedule, s, n_stages,
                                      n_microbatches):
            if kind == "F":
                if s > 0:               # activation arrives from stage s-1
                    if fabric is None:
                        emit(CostedOp(name=f"xF/s{s}/m{m}",
                                      bytes_in=boundary_bytes,
                                      deps=(f"F/s{s-1}/m{m}",),
                                      phase=f"s{s}", device_class=cls(s)))
                    else:
                        emit(boundary_hop(f"xF/s{s}/m{m}", s - 1, s,
                                          f_out(s - 1, m)))
                emit_t(by_name[s]["train/fwd"], f"F/s{s}/m{m}", ())
                if tpf_seg[s][0]:
                    emit_hops(tpf_seg[s], f"s{s}m{m}", prev)
            else:
                if s < n_stages - 1:    # gradient arrives from stage s+1
                    if fabric is None:
                        emit(CostedOp(name=f"xB/s{s}/m{m}",
                                      bytes_in=boundary_bytes,
                                      deps=(f"B/s{s+1}/m{m}",),
                                      phase=f"s{s}", device_class=cls(s)))
                    else:
                        emit(boundary_hop(f"xB/s{s}/m{m}", s, s,
                                          b_out(s + 1, m)))
                emit_t(by_name[s]["train/bwd"], f"B/s{s}/m{m}",
                       (f"F/s{s}/m{m}",))
                if tpb_seg[s][0]:
                    emit_hops(tpb_seg[s], f"s{s}m{m}", prev)
        if "train/reduce" in by_name[s]:
            emit_t(by_name[s]["train/reduce"], f"R/s{s}", ())
        elif dp_seg[s][0]:
            # the stage's gradient all-reduce waits only for ITS last
            # backward — late stages reduce while earlier stages are
            # still in backward (DP/bwd overlap across the pipeline)
            emit_hops(dp_seg[s], f"s{s}", prev)
        emit_t(by_name[s]["train/update"], f"U/s{s}", ())

    tokens = float(global_batch) * float(seq_len)
    program = Program(
        ops, name=name or f"{getattr(cfg, 'name', 'model')}/train-"
        f"{schedule}-p{n_stages}m{n_microbatches}", source="training",
        meta={"schedule": schedule, "n_stages": n_stages,
              "n_microbatches": n_microbatches, "seq_len": seq_len,
              "global_batch": global_batch, "dp_degree": dp_degree,
              "tp_degree": tp_degree, "n_accel": n_accel,
              "collective_algo": collective_algo,
              "fabric": fabric.describe() if fabric is not None else None,
              "tokens": tokens})
    res = engine.run(program, run_config)

    # measured bubble: pipeline body only (first F start -> last B end),
    # forward/backward compute time only — the quantity the analytic
    # (p-1)/(m+p-1) bound describes
    t0 = t1 = None
    busy = 0.0
    busy_all: Dict[str, float] = {}   # per-worker busy (non-idle) seconds
    for e in res.timeline.events:
        k = e.kind
        if k != "idle":
            w = e.worker
            busy_all[w] = busy_all.get(w, 0.0) + e.duration
        if k != "compute":
            continue
        nm = e.name
        if nm.startswith("F/"):
            t0 = e.start if t0 is None or e.start < t0 else t0
            busy += e.duration
        elif nm.startswith("B/"):
            end = e.start + e.duration
            t1 = end if t1 is None or end > t1 else t1
            busy += e.duration
    span = (t1 - t0) if (t0 is not None and t1 is not None) else 0.0
    bubble = (1.0 - busy / (n_stages * span)) if span > 0.0 else 0.0

    # device_utilization() and the per-device busy map share the single
    # event pass above (same accumulation order -> bit-identical floats)
    mk = res.timeline.makespan
    util = {d.name: (busy_all.get(d.name, 0.0) / mk if mk else 0.0)
            for d in run_config.resolved_topology().devices}
    if pinned:
        stage_util = {d: util.get(d, 0.0) for d in stage_devs}
    else:
        stage_util = util
    busy_by_dev = {w: v for w, v in busy_all.items() if w in util}

    return TrainingResult(
        program=program, engine=res, schedule=schedule, n_stages=n_stages,
        n_microbatches=n_microbatches, step_time_s=res.makespan,
        tokens=tokens, per_stage_busy_s=busy_by_dev,
        per_stage_utilization=stage_util,
        bubble_fraction=bubble,
        bubble_bound=bubble_bound(n_stages, n_microbatches),
        config=run_config,
        meta={"seq_len": seq_len, "global_batch": global_batch,
              "bytes_per_param": bytes_per_param,
              "bytes_per_act": bytes_per_act, "dp_degree": dp_degree,
              "tp_degree": tp_degree, "n_accel": n_accel,
              "collective_algo": collective_algo,
              "fabric": (fabric.describe()
                         if fabric is not None else None)})
