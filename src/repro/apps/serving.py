"""Serving scenario app: one call from an architecture id to a simulated
served workload.

The apps layer composes scenario pieces the way ``apps.camera`` composes
the ISP with a DNN program: here the pieces are a ``ModelConfig`` from the
registry, a synthetic trace generator, a batching policy, and the serving
co-simulation — ``examples/serve_batch.py --simulate`` and ad-hoc DSE
scripts call this instead of wiring the four by hand.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.serve.policy import (BatchingPolicy, QueueDepthAutoscaler,
                                RouterPolicy, get_policy)
from repro.sim.engine import EngineConfig
from repro.sim.serving import (TRACE_GENERATORS, FleetResult,
                               ServingResult, simulate_fleet,
                               simulate_serving)


def serve_trace(arch: str = "gemma_2b",
                policy: Union[str, BatchingPolicy] = "continuous", *,
                rate_rps: float = 50.0, n_requests: int = 64,
                max_batch: int = 8, trace_kind: str = "poisson",
                seed: int = 0, smoke: bool = False,
                config: Optional[EngineConfig] = None,
                prompt_len=(16, 128), output_len=(8, 64)) -> ServingResult:
    """Simulate serving ``arch`` under a policy and a synthetic trace.

    ``policy`` is a name (``static`` | ``dynamic`` | ``continuous``) or a
    ready ``BatchingPolicy``; ``smoke`` selects the reduced registry config
    (useful when the full model's weights would dwarf the trace).  Returns
    the full ``ServingResult``; ``result.stats()`` has the TTFT/TPOT/
    throughput summary.
    """
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if isinstance(policy, str):
        policy = get_policy(policy, max_batch=max_batch)
    gen = TRACE_GENERATORS[trace_kind]
    trace = gen(n_requests, rate_rps, prompt_len=prompt_len,
                output_len=output_len, seed=seed)
    return simulate_serving(cfg, trace, policy,
                            config or EngineConfig(), name=f"{arch}/serve")


def serve_fleet(arch: str = "gemma_2b",
                policy: Union[str, BatchingPolicy] = "continuous", *,
                n_replicas: int = 2,
                router: Union[str, RouterPolicy] = "round_robin",
                autoscaler: Optional[QueueDepthAutoscaler] = None,
                rate_rps: float = 200.0, n_requests: int = 2000,
                max_batch: int = 8, trace_kind: str = "diurnal",
                seed: int = 0, smoke: bool = False,
                config: Optional[EngineConfig] = None,
                prompt_len=(16, 128), output_len=(8, 64)) -> FleetResult:
    """Simulate an N-replica serving fleet of ``arch`` under a router
    (``round_robin`` | ``least_outstanding`` | ``session_affinity``), an
    optional ``QueueDepthAutoscaler``, and a synthetic trace
    (``diurnal`` by default — the daily load wave autoscalers exist
    for).  The memoized replay path handles million-request traces;
    ``result.stats()`` has the SLO-attainment / cost-per-token roll-up.
    """
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if isinstance(policy, str):
        policy = get_policy(policy, max_batch=max_batch)
    gen = TRACE_GENERATORS[trace_kind]
    kw = {"arrays": True} if trace_kind == "diurnal" else {}
    trace = gen(n_requests, rate_rps, prompt_len=prompt_len,
                output_len=output_len, seed=seed, **kw)
    res = simulate_fleet(cfg, trace, policy, config or EngineConfig(),
                         n_replicas=n_replicas, router=router,
                         autoscaler=autoscaler, name=f"{arch}/fleet")
    res.meta.update({"rate_rps": rate_rps, "trace_kind": trace_kind,
                     "seed": seed})
    return res
