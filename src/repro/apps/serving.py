"""Serving scenario app: one call from an architecture id to a simulated
served workload.

The apps layer composes scenario pieces the way ``apps.camera`` composes
the ISP with a DNN program: here the pieces are a ``ModelConfig`` from the
registry, a synthetic trace generator, a batching policy, and the serving
co-simulation — ``examples/serve_batch.py --simulate`` and ad-hoc DSE
scripts call this instead of wiring the four by hand.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.serve.policy import BatchingPolicy, get_policy
from repro.sim.engine import EngineConfig
from repro.sim.serving import (TRACE_GENERATORS, ServingResult,
                               simulate_serving)


def serve_trace(arch: str = "gemma_2b",
                policy: Union[str, BatchingPolicy] = "continuous", *,
                rate_rps: float = 50.0, n_requests: int = 64,
                max_batch: int = 8, trace_kind: str = "poisson",
                seed: int = 0, smoke: bool = False,
                config: Optional[EngineConfig] = None,
                prompt_len=(16, 128), output_len=(8, 64)) -> ServingResult:
    """Simulate serving ``arch`` under a policy and a synthetic trace.

    ``policy`` is a name (``static`` | ``dynamic`` | ``continuous``) or a
    ready ``BatchingPolicy``; ``smoke`` selects the reduced registry config
    (useful when the full model's weights would dwarf the trace).  Returns
    the full ``ServingResult``; ``result.stats()`` has the TTFT/TPOT/
    throughput summary.
    """
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if isinstance(policy, str):
        policy = get_policy(policy, max_batch=max_batch)
    gen = TRACE_GENERATORS[trace_kind]
    trace = gen(n_requests, rate_rps, prompt_len=prompt_len,
                output_len=output_len, seed=seed)
    return simulate_serving(cfg, trace, policy,
                            config or EngineConfig(), name=f"{arch}/serve")
