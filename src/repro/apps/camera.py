"""Camera ISP pipeline (paper §V, Halide pipeline re-implemented in JAX).

Stages (matching the paper's description): hot-pixel suppression,
deinterleave (Bayer planes), demosaic (bilinear), white balance, color
correction, gamma, sharpen, and downsample to the DNN input size.

Raw input: (H, W) Bayer-mosaic (RGGB) sensor values in [0, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def hot_pixel_suppression(raw):
    """Clamp each pixel to the max/min of its 4 same-color neighbours."""
    p = jnp.pad(raw, 2, mode="edge")
    n = jnp.stack([p[:-4, 2:-2], p[4:, 2:-2], p[2:-2, :-4], p[2:-2, 4:]])
    return jnp.clip(raw, n.min(0), n.max(0))


def deinterleave(raw):
    """RGGB Bayer -> 4 half-res planes (r, g0, g1, b)."""
    return (raw[0::2, 0::2], raw[0::2, 1::2], raw[1::2, 0::2],
            raw[1::2, 1::2])


def demosaic(r, g0, g1, b):
    """Bilinear demosaic to full-res RGB (half-res planes upsampled)."""
    def up(x):
        x2 = jnp.repeat(jnp.repeat(x, 2, 0), 2, 1)
        k = jnp.array([[0.25, 0.5, 0.25]])
        x2 = jax.scipy.signal.convolve2d(x2, k.T @ k, mode="same") \
            / jax.scipy.signal.convolve2d(jnp.ones_like(x2), k.T @ k,
                                          mode="same")
        return x2
    g = (up(g0) + up(g1)) * 0.5
    return jnp.stack([up(r), g, up(b)], axis=-1)


def white_balance(rgb, gains=(2.0, 1.0, 1.6)):
    return rgb * jnp.asarray(gains)[None, None]


def color_correct(rgb):
    ccm = jnp.asarray([[1.6, -0.4, -0.2],
                       [-0.3, 1.5, -0.2],
                       [-0.1, -0.5, 1.6]])
    return jnp.clip(rgb @ ccm.T, 0.0, 1.0)


def gamma(rgb, g=2.2):
    return jnp.power(jnp.clip(rgb, 1e-6, 1.0), 1.0 / g)


def sharpen(rgb, amount=0.6):
    k = jnp.asarray([[0, -1, 0], [-1, 5.0, -1], [0, -1, 0]]) / 1.0

    def conv1(ch):
        return jax.scipy.signal.convolve2d(ch, k, mode="same")
    sharp = jnp.stack([conv1(rgb[..., i]) for i in range(3)], axis=-1)
    return jnp.clip((1 - amount) * rgb + amount * sharp, 0.0, 1.0)


def downsample(rgb, out_hw):
    H, W, _ = rgb.shape
    oh, ow = out_hw
    fh, fw = H // oh, W // ow
    return rgb[:oh * fh, :ow * fw].reshape(oh, fh, ow, fw, 3).mean((1, 3))


@functools.partial(jax.jit, static_argnames=("dnn_hw",))
def camera_pipeline(raw, dnn_hw=(32, 32)):
    """Full ISP: raw Bayer -> RGB frame + downsampled DNN input."""
    raw = hot_pixel_suppression(raw)
    planes = deinterleave(raw)
    rgb = demosaic(*planes)
    rgb = white_balance(rgb)
    rgb = color_correct(rgb)
    rgb = gamma(rgb)
    rgb = sharpen(rgb)
    return rgb, downsample(rgb, dnn_hw)


# ---------------------------------------------------------------------------
# engine lowering (Fig 19/20): the ISP stages as a repro.sim Program, so the
# camera case study composes with the DNN graph in ONE simulated execution
# (``camera_program(...).then(graph.program())``) instead of a bolt-on sum.
# The ISP ops are tagged for the SoC's frontend device (CPU by default, a
# DSP when the topology provides one), so on a heterogeneous SoCTopology
# the frontend genuinely runs beside — and contends with — the DNN
# accelerators instead of being folded into the same worker pool.


def camera_program(hw=(720, 1280), dnn_hw=(32, 32), device_class="cpu"):
    """Per-stage (flops, bytes) costs of the ISP at the given raw size.

    ``device_class`` places the stages on the SoC frontend (``"cpu"`` |
    ``"dsp"``); flat configs have no such device and fall back to the
    accelerator pool, which reproduces the pre-topology behavior."""
    from repro.sim.ir import BYTES_PER_ELEM, CostedOp, Program

    H, W = hw
    px = float(H * W)
    rgb = 3.0 * px
    # (name, flops, elems_in, elems_out); flops from the stage's arithmetic:
    # stencil stages count kernel taps, pointwise stages 1-2 ops/elem
    stages = [
        ("hot_pixel", 6.0 * px, px, px),            # 4-neighbour min/max+clip
        ("deinterleave", px, px, px),               # pure data movement
        ("demosaic", 2.0 * 9.0 * rgb, px, rgb),     # bilinear 3x3 upsample
        ("white_balance", rgb, rgb, rgb),
        ("color_correct", 2.0 * 9.0 * px, rgb, rgb),  # 3x3 CCM per pixel
        ("gamma", 2.0 * rgb, rgb, rgb),             # pow: transcendental
        ("sharpen", 2.0 * 9.0 * rgb, rgb, rgb),     # 3x3 stencil per channel
        ("downsample", rgb, rgb, 3.0 * dnn_hw[0] * dnn_hw[1]),
    ]
    ops = []
    prev = None
    for name, flops, ein, eout in stages:
        ops.append(CostedOp(
            name=f"isp/{name}",
            flops=flops,
            bytes_in=BYTES_PER_ELEM * ein,
            bytes_out=BYTES_PER_ELEM * eout,
            transcendentals=eout if name == "gamma" else 0.0,
            deps=(prev,) if prev else (),
            phase="isp",
            device_class=device_class))
        prev = f"isp/{name}"
    return Program(ops, name="camera_isp", source="custom",
                   meta={"hw": hw, "dnn_hw": dnn_hw,
                         "device_class": device_class})


# frontend peak flops per kind, embedded-SoC scale: an in-order CPU
# cluster vs a vector DSP (the camera ISP is stencil/pointwise code both
# can run; the DSP is the paper's specialized-frontend alternative)
FRONTEND_PEAK = {"cpu": 5e10, "dsp": 2e11}


def camera_soc(n_accels=4, frontend="cpu", *, link_ports=4.0,
               frontend_peak_flops=None, frontend_interface="acp",
               accel_peak_flops=None, accel_datapath_scale=None, name=""):
    """A camera SoC topology: one ``frontend`` device (``"cpu"`` |
    ``"dsp"``) feeding ``n_accels`` NN accelerators over one shared HBM
    link with ``link_ports`` ports — the object SMAUG's camera-SoC-tuning
    study sweeps.  The frontend defaults to the fused/resident ``acp``
    interface (ISP stencils stream through on-chip line buffers, Halide
    style) while the accelerators inherit the flat config's interface and
    stream their tiles over the shared link.  Accelerator fields left
    ``None`` inherit the flat ``EngineConfig`` (peak flops, datapath
    scale), so the same topology grid composes with the Fig-20 PE-size
    knobs."""
    from repro.sim.hw import Device, Link, SoCTopology

    fpeak = (FRONTEND_PEAK.get(frontend, FRONTEND_PEAK["cpu"])
             if frontend_peak_flops is None else frontend_peak_flops)
    devices = (Device(f"{frontend}0", kind=frontend, peak_flops=fpeak,
                      interface=frontend_interface),)
    devices += tuple(Device(f"acc{i}", peak_flops=accel_peak_flops,
                            datapath_scale=accel_datapath_scale)
                     for i in range(n_accels))
    return SoCTopology(
        devices=devices, links=(Link("hbm", ports=link_ports),),
        name=name or f"{frontend}+{n_accels}acc/p{link_ports:g}")


def frame_sweep(dnn_program, configs, hw=(720, 1280), dnn_hw=(32, 32),
                name="frame", frontend_class="cpu"):
    """Whole-frame design-space sweep: ISP program composed with the DNN
    program, evaluated under every SoC config through the batched
    ``repro.sim.sweep`` layer (one lowering + shared dependency plan).

    Returns ``(frame_program, [EngineResult per config])`` — the Fig 19/20
    accelerator-size study is one call with a PE-scaled config grid, and
    the camera-SoC-tuning study is the same call with topology-bearing
    configs (``EngineConfig(topology=camera_soc(...))``), where the ISP
    stages land on the frontend device and the DNN tiles on the
    accelerators in ONE simulated execution.
    """
    from repro.sim.sweep import sweep

    frame = camera_program(hw, dnn_hw, device_class=frontend_class) \
        .then(dnn_program, name=name)
    return frame, sweep(frame, configs)


def soc_frame_sweep(dnn_program, topologies, base_config=None,
                    hw=(720, 1280), dnn_hw=(32, 32), name="frame"):
    """Camera-SoC-tuning sweep over a grid of ``camera_soc`` topologies.

    The frontend class of each composed frame program follows the
    topology's frontend device kind, so a ``dsp`` SoC runs the ISP on its
    DSP.  Topologies sharing a frontend kind share one composed frame
    program, so the whole group goes through ``sweep`` as one batch (one
    lowering + one dependency plan per kind, not per cell).  Returns
    ``[(topology, frame_program, EngineResult)]`` in grid order — one
    genuinely heterogeneous simulated execution per SoC."""
    import dataclasses

    from repro.sim.engine import EngineConfig
    from repro.sim.sweep import sweep

    base = base_config if base_config is not None else EngineConfig()
    topologies = list(topologies)
    kinds = [next((d.kind for d in t.devices if d.kind in ("cpu", "dsp")),
                  "cpu") for t in topologies]
    out = [None] * len(topologies)
    for kind in dict.fromkeys(kinds):           # unique, grid order
        idxs = [i for i, k in enumerate(kinds) if k == kind]
        frame = camera_program(hw, dnn_hw, device_class=kind) \
            .then(dnn_program, name=f"{name}/{kind}")
        results = sweep(frame, [
            dataclasses.replace(base, topology=topologies[i])
            for i in idxs])
        for i, res in zip(idxs, results):
            out[i] = (topologies[i], frame, res)
    return out
