"""Camera ISP pipeline (paper §V, Halide pipeline re-implemented in JAX).

Stages (matching the paper's description): hot-pixel suppression,
deinterleave (Bayer planes), demosaic (bilinear), white balance, color
correction, gamma, sharpen, and downsample to the DNN input size.

Raw input: (H, W) Bayer-mosaic (RGGB) sensor values in [0, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def hot_pixel_suppression(raw):
    """Clamp each pixel to the max/min of its 4 same-color neighbours."""
    p = jnp.pad(raw, 2, mode="edge")
    n = jnp.stack([p[:-4, 2:-2], p[4:, 2:-2], p[2:-2, :-4], p[2:-2, 4:]])
    return jnp.clip(raw, n.min(0), n.max(0))


def deinterleave(raw):
    """RGGB Bayer -> 4 half-res planes (r, g0, g1, b)."""
    return (raw[0::2, 0::2], raw[0::2, 1::2], raw[1::2, 0::2],
            raw[1::2, 1::2])


def demosaic(r, g0, g1, b):
    """Bilinear demosaic to full-res RGB (half-res planes upsampled)."""
    def up(x):
        x2 = jnp.repeat(jnp.repeat(x, 2, 0), 2, 1)
        k = jnp.array([[0.25, 0.5, 0.25]])
        x2 = jax.scipy.signal.convolve2d(x2, k.T @ k, mode="same") \
            / jax.scipy.signal.convolve2d(jnp.ones_like(x2), k.T @ k,
                                          mode="same")
        return x2
    g = (up(g0) + up(g1)) * 0.5
    return jnp.stack([up(r), g, up(b)], axis=-1)


def white_balance(rgb, gains=(2.0, 1.0, 1.6)):
    return rgb * jnp.asarray(gains)[None, None]


def color_correct(rgb):
    ccm = jnp.asarray([[1.6, -0.4, -0.2],
                       [-0.3, 1.5, -0.2],
                       [-0.1, -0.5, 1.6]])
    return jnp.clip(rgb @ ccm.T, 0.0, 1.0)


def gamma(rgb, g=2.2):
    return jnp.power(jnp.clip(rgb, 1e-6, 1.0), 1.0 / g)


def sharpen(rgb, amount=0.6):
    k = jnp.asarray([[0, -1, 0], [-1, 5.0, -1], [0, -1, 0]]) / 1.0

    def conv1(ch):
        return jax.scipy.signal.convolve2d(ch, k, mode="same")
    sharp = jnp.stack([conv1(rgb[..., i]) for i in range(3)], axis=-1)
    return jnp.clip((1 - amount) * rgb + amount * sharp, 0.0, 1.0)


def downsample(rgb, out_hw):
    H, W, _ = rgb.shape
    oh, ow = out_hw
    fh, fw = H // oh, W // ow
    return rgb[:oh * fh, :ow * fw].reshape(oh, fh, ow, fw, 3).mean((1, 3))


@functools.partial(jax.jit, static_argnames=("dnn_hw",))
def camera_pipeline(raw, dnn_hw=(32, 32)):
    """Full ISP: raw Bayer -> RGB frame + downsampled DNN input."""
    raw = hot_pixel_suppression(raw)
    planes = deinterleave(raw)
    rgb = demosaic(*planes)
    rgb = white_balance(rgb)
    rgb = color_correct(rgb)
    rgb = gamma(rgb)
    rgb = sharpen(rgb)
    return rgb, downsample(rgb, dnn_hw)


# ---------------------------------------------------------------------------
# engine lowering (Fig 19/20): the ISP stages as a repro.sim Program, so the
# camera case study composes with the DNN graph in ONE simulated execution
# (``camera_program(...).then(graph.program())``) instead of a bolt-on sum.


def camera_program(hw=(720, 1280), dnn_hw=(32, 32)):
    """Per-stage (flops, bytes) costs of the ISP at the given raw size."""
    from repro.sim.ir import BYTES_PER_ELEM, CostedOp, Program

    H, W = hw
    px = float(H * W)
    rgb = 3.0 * px
    # (name, flops, elems_in, elems_out); flops from the stage's arithmetic:
    # stencil stages count kernel taps, pointwise stages 1-2 ops/elem
    stages = [
        ("hot_pixel", 6.0 * px, px, px),            # 4-neighbour min/max+clip
        ("deinterleave", px, px, px),               # pure data movement
        ("demosaic", 2.0 * 9.0 * rgb, px, rgb),     # bilinear 3x3 upsample
        ("white_balance", rgb, rgb, rgb),
        ("color_correct", 2.0 * 9.0 * px, rgb, rgb),  # 3x3 CCM per pixel
        ("gamma", 2.0 * rgb, rgb, rgb),             # pow: transcendental
        ("sharpen", 2.0 * 9.0 * rgb, rgb, rgb),     # 3x3 stencil per channel
        ("downsample", rgb, rgb, 3.0 * dnn_hw[0] * dnn_hw[1]),
    ]
    ops = []
    prev = None
    for name, flops, ein, eout in stages:
        ops.append(CostedOp(
            name=f"isp/{name}",
            flops=flops,
            bytes_in=BYTES_PER_ELEM * ein,
            bytes_out=BYTES_PER_ELEM * eout,
            transcendentals=eout if name == "gamma" else 0.0,
            deps=(prev,) if prev else (),
            phase="isp"))
        prev = f"isp/{name}"
    return Program(ops, name="camera_isp", source="custom",
                   meta={"hw": hw, "dnn_hw": dnn_hw})


def frame_sweep(dnn_program, configs, hw=(720, 1280), dnn_hw=(32, 32),
                name="frame"):
    """Whole-frame design-space sweep: ISP program composed with the DNN
    program, evaluated under every SoC config through the batched
    ``repro.sim.sweep`` layer (one lowering + shared dependency plan).

    Returns ``(frame_program, [EngineResult per config])`` — the Fig 19/20
    accelerator-size study is one call with a PE-scaled config grid.
    """
    from repro.sim.sweep import sweep

    frame = camera_program(hw, dnn_hw).then(dnn_program, name=name)
    return frame, sweep(frame, configs)
