"""Table-III paper networks as repro.core.graph Graphs."""
from __future__ import annotations

import numpy as np

from repro.core.graph import (Graph, batch_norm, convolution, flatten,
                              input_data, matmul, max_pool, weight)
from repro.configs.paper_nets import PaperNet


def build_paper_graph(net: PaperNet, batch: int = 1,
                      rng: np.random.Generator | None = None) -> Graph:
    """Build a Table-III network as a repro.core.graph Graph."""
    rng = rng or np.random.default_rng(0)
    h, w, c = net.input_shape
    with Graph(name=net.name, backend="mxu") as g:
        x = input_data("input", np.zeros((batch, h, w, c), np.float32))
        ci = 0
        cur_c = c
        flat = False
        for layer in net.layers:
            ci += 1
            kind = layer[0]
            if kind == "conv":
                _, cout, kh, kw, stride = layer
                wgt = weight(f"w{ci}", rng.standard_normal(
                    (kh, kw, cur_c, cout)) * (1.0 / np.sqrt(kh * kw * cur_c)))
                x = convolution(f"conv{ci}", x, wgt, stride=stride,
                                padding="same", activation="relu")
                cur_c = cout
            elif kind == "pool":
                x = max_pool(f"pool{ci}", x, layer[1])
            elif kind == "bn":
                x = batch_norm(f"bn{ci}", x)
            elif kind == "fc":
                if not flat:
                    x = flatten(f"flat{ci}", x)
                    flat = True
                cout = layer[1]
                wgt = weight(f"w{ci}", rng.standard_normal(
                    (x.shape[-1], cout)) * (1.0 / np.sqrt(x.shape[-1])))
                x = matmul(f"fc{ci}", x, wgt, activation="relu")
        # classifier head
        if not flat:
            x = flatten("flat_out", x)
        wgt = weight("w_out", rng.standard_normal(
            (x.shape[-1], net.n_classes)) * 0.05)
        matmul("logits", x, wgt)
    return g


