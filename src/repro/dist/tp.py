"""Tensor-parallel helpers.

``tp_project`` closes a TP region: the activation is sharded on its
contraction dimension (d_ff / heads_x_dim) over the 'model' axis, the down
projection produces partial sums, and the partials are reduced.  Under jit +
GSPMD the all-reduce is inserted by the partitioner, so the helper is just
the matmul; under an explicit shard_map (the 'model' axis is bound) it must
psum itself.
"""
from __future__ import annotations

import jax

from repro.dist import context as dist_ctx


def _axis_bound(name: str) -> bool:
    """True when ``name`` is a bound collective axis (inside shard_map)."""
    try:
        jax.lax.axis_index(name)
        return True
    except (NameError, KeyError):
        return False


def tp_project(x, w, axis_name: str = "model"):
    """x @ w, reduced over ``axis_name`` when that axis is explicitly bound."""
    out = x @ w
    if dist_ctx.mesh_axis_size(axis_name) > 1 and _axis_bound(axis_name):
        if dist_ctx.perf_flags().bf16_tp_collectives:
            import jax.numpy as jnp
            out = jax.lax.psum(out.astype(jnp.bfloat16),
                               axis_name).astype(x.dtype)
        else:
            out = jax.lax.psum(out, axis_name)
    return out
