"""Distribution layer: mesh/runtime context, sharding rule engine, tensor-
parallel helpers, gradient compression, and pipeline parallelism.

Modules:
  context   — process-global mesh + PerfFlags (the perf-ablation switches)
  sharding  — logical-axis -> mesh-axis rule engine with divisibility guards
  tp        — tensor-parallel projection helper (closes a TP region)
  compress  — int8 block-quantized gradient all-reduce with error feedback
  pipeline  — GPipe-style pipeline parallelism over a 'stage' mesh axis
"""
from repro.dist import context  # noqa: F401
