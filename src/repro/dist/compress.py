"""Gradient compression: block-wise int8 quantization with stochastic
rounding and error feedback, for bandwidth-bound DP all-reduces.

The quantizer is unbiased (stochastic rounding) and the residual of each
step is fed back into the next, so the running quantized sum tracks the true
sum (1-bit-Adam-style error feedback).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x, rng, block: int = BLOCK) -> Tuple[jnp.ndarray,
                                                       jnp.ndarray]:
    """Flatten, pad to ``block`` and quantize per-block to int8.

    Returns (q (n_blocks, block) int8, scale (n_blocks, 1) f32).  The LSB is
    ``max|block| / 127`` so the worst-case error is one LSB."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    xb = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    y = xb / scale
    # stochastic rounding: unbiased, error <= 1 LSB
    u = jax.random.uniform(rng, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + u), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape, size) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:size].reshape(shape)


def compressed_psum_grads(grads, mesh, axis: str, rng,
                          err: Optional[dict] = None):
    """Quantize-reduce-dequantize a gradient pytree over ``axis``.

    ``err`` is the previous step's residual pytree (error feedback); pass the
    returned residual back in on the next call.  When the mesh axis is absent
    or size 1 (single-shard tests) the collective is skipped but the
    quantize/dequantize round-trip — and therefore the residual dynamics —
    are identical."""
    ms = dict(mesh.shape) if mesh is not None else {}
    n_shards = int(ms.get(axis, 1))
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    err_leaves = (jax.tree_util.tree_flatten(err)[0] if err is not None
                  else [None] * len(leaves))
    rngs = jax.random.split(rng, len(leaves))
    out_leaves, res_leaves = [], []
    for g, e, r in zip(leaves, err_leaves, rngs):
        target = g if e is None else g + e
        q, scale = quantize_int8(target, r)
        deq = dequantize_int8(q, scale, g.shape, g.size)
        res_leaves.append(target - deq)
        if n_shards > 1:
            deq = jax.lax.psum(deq, axis) / n_shards
        out_leaves.append(deq.astype(g.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out_leaves),
            jax.tree_util.tree_unflatten(treedef, res_leaves))
