"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

``pipeline_apply`` runs S identical stages on S devices with M microbatches
in flight: stage 0 ingests a new microbatch every tick, activations rotate
stage->stage+1 via collective_permute, and the last stage emits a finished
microbatch per tick once the pipeline fills (total ticks = M + S - 1).

Stage partitioning is shared with the training simulator:
``partition_stages`` (re-exported from ``repro.sim.ir``) is the single
balanced-split rule, and ``stage_layer_slices`` turns it into the
``[start, stop)`` layer ranges a stage owns — so the layer shares
``repro.sim.training.simulate_training`` prices are exactly the shares
this module would execute.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.sim.ir import partition_stages  # noqa: F401  (shared rule)


def stage_layer_slices(n_layers: int, n_stages: int
                       ) -> List[Tuple[int, int]]:
    """``[start, stop)`` layer range per pipeline stage under the balanced
    ``partition_stages`` split (first ``n_layers % n_stages`` stages carry
    one extra layer)."""
    out: List[Tuple[int, int]] = []
    start = 0
    for n in partition_stages(n_layers, n_stages):
        out.append((start, start + n))
        start += n
    return out


def pipeline_apply(mesh, stage_fn, stage_params, x, n_microbatches: int):
    """Apply ``stage_fn(w, x)`` for each of S pipeline stages.

    stage_params: pytree with a leading stage dimension S (sharded over
    'stage'); x: (B, ...) global batch, B divisible by n_microbatches.
    Returns stage_fn applied S times in sequence, computed pipelined.
    """
    n_stages = int(dict(mesh.shape)["stage"])
    B = x.shape[0]
    assert B % n_microbatches == 0, (B, n_microbatches)
    mb = B // n_microbatches
    xs = x.reshape(n_microbatches, mb, *x.shape[1:])
    rotate = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(w, xs):
        w = jax.tree_util.tree_map(lambda t: t[0], w)  # local stage params
        stage = jax.lax.axis_index("stage")
        is_first = stage == 0
        is_last = stage == n_stages - 1
        buf = jnp.zeros(xs.shape[1:], xs.dtype)
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t; later stages consume the rotated
            # activation produced one tick earlier by their predecessor
            inp = jnp.where(is_first,
                            xs[jnp.clip(t, 0, n_microbatches - 1)], buf)
            y = stage_fn(w, inp)
            # the last stage drains microbatch t-(S-1) once the pipe is full
            j = t - (n_stages - 1)
            take = is_last & (j >= 0)
            outs = jnp.where(
                take,
                outs.at[jnp.clip(j, 0, n_microbatches - 1)].set(y),
                outs)
            buf = jax.lax.ppermute(y, "stage", rotate)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_microbatches + n_stages - 1, tick,
                                    (buf, outs))
        # replicate the drained result (resident on the last stage) to all
        return jax.lax.psum(jnp.where(is_last, outs, 0.0), "stage")

    out = shard_map(body, mesh=mesh,
                    in_specs=(P("stage"), P()),
                    out_specs=P())(stage_params, xs)
    return out.reshape(B, *x.shape[1:])
