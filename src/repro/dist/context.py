"""Process-global distribution context: active mesh + performance flags.

The launchers (``repro.launch.*``) install a mesh and a ``PerfFlags`` set
before tracing; model code reads them through the accessors here so the same
forward functions serve the baseline and every §Perf ablation without
threading flags through call signatures.

Everything defaults to "no mesh, baseline flags" so single-device tests and
benchmarks need no setup.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Optional, Tuple, Union


@dataclass(frozen=True)
class PerfFlags:
    """Beyond-paper optimization switches (all default to baseline).

    attn_remat_chunk      remat the online-softmax body (flash-style bwd)
    windowed_attention    static sliding-window paths for local:global archs
    seq_sharded_residual  Megatron-SP residual stream sharded over 'model'
    bf16_tp_collectives   cast TP collectives to bf16 on the wire
    ssm_impl              'scan' (recurrent) | 'chunked' (SSD-style blocks)
    moe_dispatch          'gather' (index dispatch) | 'einsum' (one-hot)
    """
    attn_remat_chunk: bool = False
    windowed_attention: bool = False
    seq_sharded_residual: bool = False
    bf16_tp_collectives: bool = False
    ssm_impl: str = "scan"
    moe_dispatch: str = "gather"

    def __post_init__(self):
        # CLI override strings ("ssm_impl=chunked", bare flags -> True) come
        # through as str; normalize bool-typed fields.
        for f in fields(self):
            v = getattr(self, f.name)
            if f.type == "bool" and isinstance(v, str):
                object.__setattr__(
                    self, f.name, v.lower() in ("1", "true", "yes", "on"))


_STATE = {"mesh": None, "flags": PerfFlags()}


def set_mesh(mesh) -> None:
    """Install (or clear, with ``None``) the active device mesh."""
    _STATE["mesh"] = mesh


def get_mesh():
    return _STATE["mesh"]


def set_perf_flags(flags: PerfFlags) -> None:
    _STATE["flags"] = flags


def perf_flags() -> PerfFlags:
    return _STATE["flags"]


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis; 1 when no mesh or the axis is absent."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return 1
    try:
        return int(dict(mesh.shape).get(name, 1))
    except TypeError:
        return 1


def dp_axes() -> Optional[Union[str, Tuple[str, ...]]]:
    """The data-parallel mesh axes (>1) in ('pod', 'data') order.

    Returns a bare name, a tuple, or None — directly usable as a
    PartitionSpec entry or a psum/pmean axis_name."""
    axes = tuple(a for a in ("pod", "data") if mesh_axis_size(a) > 1)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes
