"""Logical-axis sharding rule engine.

Parameters and activations carry *logical* axis names (``"d_ff"``,
``"heads_x_dim"``, ``"kv_seq"``...); a ``Rules`` table maps each logical axis
to a mesh axis (or a tuple of mesh axes, or None).  ``spec_for`` applies the
table with the safety guards that make the whole (arch x shape x mesh) sweep
lowerable:

  * a mesh axis of size 1 never shards anything,
  * a dimension is only sharded when its size is divisible by the mesh-axis
    product,
  * a mesh axis is used at most once per spec (first logical axis wins),
  * a spec with nothing sharded collapses to the replicated ``P()``.

``rules_for`` derives the per-cell table: data-parallel batch sharding when
the batch divides, sequence-parallel fallback when it cannot (long-context
decode), TP over heads with the MQA head_dim fallback, and expert/FFN
sharding over 'model'.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

from repro.core.config import ModelConfig, ShapeConfig

Entry = Union[str, Tuple[str, ...], None]


def _mesh_shape(mesh) -> Dict[str, int]:
    if mesh is None:
        return {}
    return {k: int(v) for k, v in dict(mesh.shape).items()}


@dataclass
class Rules:
    table: Dict[str, Entry]
    mesh: Any = None

    # -- spec construction ---------------------------------------------------
    def spec_for(self, axes: Sequence[Optional[str]],
                 shape: Sequence[int]):
        """PartitionSpec for a tensor with the given logical axes."""
        from jax.sharding import PartitionSpec as P
        ms = _mesh_shape(self.mesh)
        used: set = set()
        entries = []
        sharded = False
        for i, ax in enumerate(axes):
            entry = self.table.get(ax) if ax is not None else None
            if entry is None:
                entries.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            size = math.prod(ms.get(n, 1) for n in names)
            dim = shape[i] if i < len(shape) else 0
            if size <= 1 or any(n in used for n in names) \
                    or dim % size != 0:
                entries.append(None)
                continue
            used.update(names)
            entries.append(entry)
            sharded = True
        if not sharded:
            return P()
        return P(*entries)

    def tree_shardings(self, axes_tree, value_tree):
        """NamedShardings for a pytree whose axes-tree leaves are tuples of
        logical names (the ``Leaf.axes`` convention)."""
        import jax
        from jax.sharding import NamedSharding

        def _axes_leaf(x):
            return x is None or (isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x))

        ax_flat, treedef = jax.tree_util.tree_flatten(
            axes_tree, is_leaf=_axes_leaf)
        val_flat = treedef.flatten_up_to(value_tree)
        out = [NamedSharding(self.mesh,
                             self.spec_for(a or (), tuple(v.shape)))
               for a, v in zip(ax_flat, val_flat)]
        return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# rule derivation


def default_rules(mesh) -> Rules:
    """Generic table: DP batch, TP everything wide, no sequence parallelism."""
    ms = _mesh_shape(mesh)
    dp = tuple(a for a in ("pod", "data") if ms.get(a, 1) > 1)
    batch: Entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    return Rules(table={
        "batch": batch,
        "vocab": "model",
        "d_model": None,
        "d_ff": "model",
        "d_inner": "model",
        "heads_x_dim": "model",
        "kv_heads_x_dim": "model",
        "kv_heads": "model",
        "head_dim": None,
        "experts": "model",
        "kv_seq": None,
        "seq_model": "model",
        "layers": None,
        "kv_lora": None,
        "ssm_heads": None,
    }, mesh=mesh)


def rules_for(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Rules:
    """Per-cell rule table (divisibility-guarded; see module docstring)."""
    ms = _mesh_shape(mesh)
    model = ms.get("model", 1)
    data = ms.get("data", 1)
    dp_names = tuple(a for a in ("pod", "data") if ms.get(a, 1) > 1)
    dp = math.prod(ms.get(a, 1) for a in dp_names) if dp_names else 1
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim

    table: Dict[str, Entry] = {
        "d_model": None, "layers": None, "kv_lora": None, "ssm_heads": None,
    }

    # batch: DP when it divides; otherwise replicated and SP takes over
    if dp_names and dp > 1 and B % dp == 0:
        table["batch"] = dp_names if len(dp_names) > 1 else dp_names[0]
    else:
        table["batch"] = None

    # sequence parallelism over 'data' when the batch could not use it
    # (single-sequence long-context decode — the KV cache is the big tensor)
    if table["batch"] is None and data > 1 and S % data == 0:
        table["kv_seq"] = "data"
    else:
        table["kv_seq"] = None

    # tensor parallelism over 'model'
    def tp(n: int) -> Entry:
        return "model" if model > 1 and n % model == 0 else None

    table["heads_x_dim"] = tp(cfg.n_heads)
    table["kv_heads_x_dim"] = tp(cfg.n_kv_heads)
    table["kv_heads"] = table["kv_heads_x_dim"]
    # MQA/GQA fallback: too few KV heads for the model axis -> shard the
    # head_dim of the cache instead so long-context decode still distributes
    table["head_dim"] = tp(hd) if table["kv_heads"] is None else None
    table["d_ff"] = tp(cfg.d_ff)
    table["vocab"] = tp(cfg.vocab)
    table["seq_model"] = "model" if model > 1 and S % model == 0 else None
    if cfg.ssm is not None:
        table["d_inner"] = tp(cfg.ssm.expand * cfg.d_model)
    else:
        table["d_inner"] = None
    if cfg.moe is not None:
        table["experts"] = tp(cfg.moe.n_experts)
    else:
        table["experts"] = None
    return Rules(table=table, mesh=mesh)


# ---------------------------------------------------------------------------
# active-rules global (installed by the launchers, read by ``constrain``)

_ACTIVE: Dict[str, Optional[Rules]] = {"rules": None}


def set_active_rules(rules: Optional[Rules]) -> None:
    _ACTIVE["rules"] = rules


def active_rules() -> Optional[Rules]:
    return _ACTIVE["rules"]


def constrain(x, axes: Sequence[Optional[str]]):
    """Sharding-constrain ``x`` per the active rules; identity when no rules
    or no real mesh are installed (single-device tests)."""
    rules = _ACTIVE["rules"]
    if rules is None or rules.mesh is None \
            or not hasattr(rules.mesh, "devices"):
        return x
    import jax
    from jax.sharding import NamedSharding
    spec = rules.spec_for(axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
