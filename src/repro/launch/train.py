"""Production training launcher: builds the mesh, shards state per the rule
engine, and runs the train loop with fault-tolerant checkpointing.

On real hardware:
  python -m repro.launch.train --arch tinyllama_1_1b --shape train_4k
On this container it runs reduced configs on the single local device
(``--smoke``); the production mesh path is exercised (lower+compile) by
``repro.launch.dryrun``.

Fault-tolerance posture (DESIGN.md §4): resume from the newest committed
checkpoint (``--resume``), async saves off the training thread, elastic
restore onto whatever mesh this launch built (checkpoints are mesh-
agnostic), preemption-safe atomic commits.

``--dry-run`` skips the JAX path entirely and prices the SAME
(arch x shape x microbatches) cell through the training simulator
(``repro.sim.training``): predicted step time, tokens/s, per-stage
utilization and pipeline bubble under GPipe and 1F1B at ``--stages``
pipeline stages — the pre-launch sanity check for a schedule choice.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core.config import SHAPE_BY_NAME
from repro.data import DataPipeline
from repro.dist import context as dist_ctx
from repro.dist.sharding import rules_for, set_active_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train import TrainConfig, make_train_step


def dry_run(arch: str, shape_name: str, *, n_stages: int = 1,
            n_microbatches: int = 1, schedule: str = "both",
            smoke: bool = False, emit=print):
    """Price the (arch x shape x microbatches) training cell through the
    simulator instead of launching it; returns the ``TrainingResult``
    list (one per schedule)."""
    from repro.sim.training import SCHEDULES, simulate_training
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    batch, seq = (4, 64) if smoke else (shape.global_batch, shape.seq_len)
    schedules = SCHEDULES if schedule == "both" else (schedule,)
    out = []
    for sched in schedules:
        r = simulate_training(cfg, n_stages=n_stages,
                              n_microbatches=n_microbatches,
                              schedule=sched, seq_len=seq,
                              global_batch=batch)
        out.append(r)
        utils = " ".join(f"{k}={v:.2f}"
                         for k, v in r.per_stage_utilization.items())
        emit(f"[dry-run] {arch}/{shape_name} {sched} p={n_stages} "
             f"m={n_microbatches}: step={r.step_time_s*1e3:.3f}ms "
             f"({r.tokens_per_s:.0f} tok/s) "
             f"bubble={r.bubble_fraction:.3f} "
             f"(bound {r.bubble_bound:.3f}) {utils}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dry-run", action="store_true",
                    help="simulate the step instead of launching it")
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages for --dry-run")
    ap.add_argument("--schedule", default="both",
                    choices=("gpipe", "1f1b", "both"),
                    help="pipeline schedule(s) for --dry-run")
    args = ap.parse_args()

    if args.dry_run:
        dry_run(args.arch, args.shape, n_stages=args.stages,
                n_microbatches=args.microbatches, schedule=args.schedule,
                smoke=args.smoke)
        return

    shape = SHAPE_BY_NAME[args.shape]
    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_host_mesh(1, 1)
        batch, seq = 4, 64
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        batch, seq = shape.global_batch, shape.seq_len

    rules = rules_for(cfg, shape, mesh)
    set_active_rules(rules)
    dist_ctx.set_mesh(mesh)

    params, axes = T.init_params(cfg, jax.random.PRNGKey(0))
    param_sh = rules.tree_shardings(
        axes, jax.tree_util.tree_map(lambda x: x, params))
    params = jax.device_put(params, param_sh)
    opt = jax.device_put(adamw_init(params), {
        "m": param_sh, "v": param_sh,
        "count": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())})

    tc = TrainConfig(total_steps=args.steps,
                     n_microbatches=args.microbatches)
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        out = mgr.restore(template={"params": params, "opt": opt},
                          shardings={"params": param_sh,
                                     "opt": {"m": param_sh, "v": param_sh,
                                             "count": None}})
        params, opt = out["tree"]["params"], out["tree"]["opt"]
        start = out["step"] + 1
        print(f"[restore] resumed at step {start}")

    pipe = DataPipeline(cfg, batch, seq, n_workers=2, prefetch=2)
    try:
        t0 = time.time()
        for i in range(start, args.steps):
            b = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt, metrics = step_fn(params, opt, b,
                                           jnp.asarray(i, jnp.int32))
            if i % 10 == 0:
                print(f"step {i} loss={float(metrics['loss']):.3f} "
                      f"({(i-start+1)*batch*seq/(time.time()-t0):.0f} tok/s)",
                      flush=True)
            if i and i % args.ckpt_every == 0:
                mgr.save_async(i, {"params": params, "opt": opt})
        mgr.save_async(args.steps - 1, {"params": params, "opt": opt})
        mgr.wait()
    finally:
        pipe.stop()
        set_active_rules(None)
        dist_ctx.set_mesh(None)


if __name__ == "__main__":
    main()
