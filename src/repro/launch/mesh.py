"""Production mesh builders.

Functions (not module-level constants) so importing this module never touches
jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax
(see launch/dryrun.py) so these meshes can be built on a CPU-only host.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e); 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    import numpy as np
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests / examples)."""
    import numpy as np
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(data, model),
                             ("data", "model"))
