import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Hillclimb driver (§Perf): lower one cell with a PerfFlags combo, analyze,
and append the roofline terms to experiments/perf_iters.json.

  PYTHONPATH=src python -m repro.launch.perf_iter --arch gemma3_1b \
      --shape train_4k --perf attn_remat_chunk,windowed_attention
"""
import argparse
import json
import time
from pathlib import Path

from repro.configs import get_config
from repro.core.config import SHAPE_BY_NAME
from repro.core.hlo import analyze_hlo
from repro.core.simulator import roofline
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--perf", default="")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="experiments/perf_iters.json")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPE_BY_NAME[args.shape]
    mesh = make_production_mesh()
    t0 = time.time()
    lowered, rules = lower_cell(cfg, shape, mesh, perf=args.perf,
                                n_microbatches=args.microbatches)
    compiled = lowered.compile()
    hlo = analyze_hlo(compiled.as_text())
    rl = roofline(hlo, cfg, shape, 256)
    mem = compiled.memory_analysis()
    rec = {"arch": args.arch, "shape": args.shape, "perf": args.perf,
           "microbatches": args.microbatches,
           "wall_s": round(time.time() - t0, 1),
           "temp_bytes": mem.temp_size_in_bytes,
           "hlo": {k: hlo[k] for k in ("flops", "dot_flops", "bytes",
                                       "collective_bytes", "wire_bytes")},
           "collectives": hlo["collectives"],
           "roofline": rl.to_dict()}
    out = Path(args.out)
    data = json.loads(out.read_text()) if out.exists() else {}
    key = f"{args.arch}|{args.shape}|{args.perf}|mb{args.microbatches}"
    data[key] = rec
    out.write_text(json.dumps(data, indent=1))
    r = rl.to_dict()
    print(f"{key}\n  compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
          f"collective={r['collective_s']:.3f}s bound={r['bound']} "
          f"useful={r['useful_ratio']*100:.0f}% "
          f"rl_frac={r['roofline_fraction']*100:.2f}% "
          f"temp={mem.temp_size_in_bytes/1e9:.1f}GB")


if __name__ == "__main__":
    main()
