import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: jax locks the device
#   count on first init.  Do not set this anywhere global (tests/benches see
#   one device).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, print memory/cost analysis, and record roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape NAME]
      [--mesh single|multi|both] [--out experiments/dryrun]

Results (memory analysis, cost analysis, parsed collective bytes, HLO loop
tree) are appended incrementally to <out>/results.json so the sweep is
resumable; cells already present are skipped unless --force.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.config import SHAPES, ModelConfig, ShapeConfig, cell_is_runnable
from repro.dist import context as dist_ctx
from repro.dist.sharding import Rules, rules_for, set_active_rules
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# abstract inputs


def abstract_params(cfg: ModelConfig):
    """(params as ShapeDtypeStructs, logical-axes pytree) — no allocation.
    The axes tree is static python data, captured via a side cell while
    eval_shape traces the array part."""
    holder = {}

    def f(k):
        params, axes = T.init_params(cfg, k)
        holder["axes"] = axes
        return params

    params = jax.eval_shape(f, jax.random.PRNGKey(0))
    return params, holder["axes"]


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    holder = {}

    def f():
        cache, axes = T.init_cache(cfg, batch, max_seq)
        holder["axes"] = axes
        return cache

    cache = jax.eval_shape(f)
    return cache, holder["axes"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules):
    """ShapeDtypeStruct stand-ins for every model input of this cell, plus
    their NamedShardings.  No device allocation happens here."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch_spec = rules.spec_for(("batch", None), (B, S))

    def sharded(spec_axes, struct):
        return NamedSharding(rules.mesh,
                             rules.spec_for(spec_axes, struct.shape)), struct

    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        bshard = {"tokens": NamedSharding(rules.mesh, batch_spec),
                  "labels": NamedSharding(rules.mesh, batch_spec)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder.n_ctx, cfg.d_model),
                                  jnp.float32)
            bshard["frames"] = NamedSharding(
                rules.mesh, rules.spec_for(("batch", None, None),
                                           batch["frames"].shape))
        if cfg.family == "vlm":
            batch["patches"] = sds((B, cfg.n_patches, cfg.d_model),
                                   jnp.float32)
            bshard["patches"] = NamedSharding(
                rules.mesh, rules.spec_for(("batch", None, None),
                                           batch["patches"].shape))
        return batch, bshard

    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
        bshard = {"tokens": NamedSharding(rules.mesh, batch_spec)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder.n_ctx, cfg.d_model),
                                  jnp.float32)
            bshard["frames"] = NamedSharding(
                rules.mesh, rules.spec_for(("batch", None, None),
                                           batch["frames"].shape))
        if cfg.family == "vlm":
            batch["patches"] = sds((B, cfg.n_patches, cfg.d_model),
                                   jnp.float32)
            bshard["patches"] = NamedSharding(
                rules.mesh, rules.spec_for(("batch", None, None),
                                           batch["patches"].shape))
        return batch, bshard

    # decode: cache + one token
    cache, cache_axes = abstract_cache(cfg, B, S)
    cache_sh = rules.tree_shardings(cache_axes, cache)
    tokens = sds((B, 1), jnp.int32)
    tok_sh = NamedSharding(rules.mesh, rules.spec_for(("batch", None),
                                                      (B, 1)))
    return (cache, tokens), (cache_sh, tok_sh)


# ---------------------------------------------------------------------------
# lowering per cell


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               n_microbatches: int = 1, donate: bool = True,
               perf: str = ""):
    """Returns (lowered, rules).  Raises on sharding/lowering failure.

    ``perf``: comma-separated PerfFlags overrides, e.g.
    "attn_remat_chunk,bf16_tp_collectives,windowed_attention,ssm_impl=chunked"
    """
    rules = rules_for(cfg, shape, mesh)
    set_active_rules(rules)
    dist_ctx.set_mesh(mesh)
    kw = {}
    for item in filter(None, perf.split(",")):
        if "=" in item:
            k, v = item.split("=", 1)
            kw[k] = v
        else:
            kw[item] = True
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags(**kw))
    params, axes = abstract_params(cfg)
    param_sh = rules.tree_shardings(axes, params)

    if shape.kind == "train":
        from repro.optim import adamw_init
        tc = TrainConfig(n_microbatches=n_microbatches)
        step_fn = make_train_step(cfg, tc)
        opt = jax.eval_shape(adamw_init, params)
        opt_sh = {"m": param_sh, "v": param_sh,
                  "count": NamedSharding(mesh, P())}
        batch, batch_sh = input_specs(cfg, shape, rules)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        f = jax.jit(step_fn,
                    in_shardings=(param_sh, opt_sh, batch_sh,
                                  NamedSharding(mesh, P())),
                    out_shardings=(param_sh, opt_sh, None),
                    donate_argnums=(0, 1) if donate else ())
        return f.lower(params, opt, batch, step_sds), rules

    if shape.kind == "prefill":
        from repro.serve import make_prefill_step
        step_fn = make_prefill_step(cfg, max_seq=shape.seq_len)
        batch, batch_sh = input_specs(cfg, shape, rules)
        f = jax.jit(step_fn, in_shardings=(param_sh, batch_sh))
        return f.lower(params, batch), rules

    # decode
    from repro.serve import make_decode_step
    step_fn = make_decode_step(cfg)
    (cache, tokens), (cache_sh, tok_sh) = input_specs(cfg, shape, rules)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    f = jax.jit(step_fn,
                in_shardings=(param_sh, cache_sh, tok_sh,
                              NamedSharding(mesh, P())),
                out_shardings=(tok_sh, cache_sh),
                donate_argnums=(1,) if donate else ())
    return f.lower(params, cache, tokens, pos_sds), rules


def run_cell(arch: str, shape: ShapeConfig, mesh, mesh_name: str,
             out_dir: Path, *, save_hlo: bool = False,
             n_microbatches: int = 1, perf: str = ""):
    """Lower + compile one cell; return the result record."""
    cfg = get_config(arch)
    runnable, why = cell_is_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
           "kind": shape.kind, "perf": perf, "timestamp": time.time()}
    if not runnable:
        rec.update(status="skip", reason=why)
        return rec
    t0 = time.time()
    try:
        lowered, rules = lower_cell(cfg, shape, mesh,
                                    n_microbatches=n_microbatches,
                                    perf=perf)
        t_lower = time.time() - t0
        print(f"  lowered in {t_lower:.1f}s", flush=True)
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        print(f"  compiled in {t_compile:.1f}s", flush=True)
        mem = compiled.memory_analysis()
        print("  memory_analysis done", flush=True)
        from repro.core.compat import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        print("  cost_analysis done", flush=True)
        hlo_text = compiled.as_text()
        print(f"  as_text done ({len(hlo_text)/1e6:.1f} MB)", flush=True)
        from repro.core.hlo import analyze_hlo
        hlo = analyze_hlo(hlo_text)
        print("  hlo analyzed", flush=True)
        if save_hlo:
            (out_dir / f"{arch}.{shape.name}.{mesh_name}.hlo.txt").write_text(
                hlo_text)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            cost={k: v for k, v in cost.items()
                  if not k.startswith("utilization")},
            hlo=hlo,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    finally:
        set_active_rules(None)
        dist_ctx.set_mesh(None)
        dist_ctx.set_perf_flags(dist_ctx.PerfFlags())
    return rec


# ---------------------------------------------------------------------------
# sweep driver (resumable)


def load_results(path: Path):
    if path.exists():
        return json.loads(path.read_text())
    return {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--perf", default="",
                    help="PerfFlags list, e.g. attn_remat_chunk,"
                         "bf16_tp_collectives,windowed_attention,"
                         "ssm_impl=chunked")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    res_path = out_dir / "results.json"
    results = load_results(res_path)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = SHAPES if args.shape == "all" else [
        s for s in SHAPES if s.name == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape.name}|{mesh_name}"
                if args.microbatches > 1:
                    key += f"|mb{args.microbatches}"
                if args.perf:
                    key += f"|{args.perf}"
                if key in results and not args.force \
                        and results[key]["status"] in ("ok", "skip"):
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                print(f"[run] {key} ...", flush=True)
                rec = run_cell(arch, shape, mesh, mesh_name, out_dir,
                               save_hlo=args.save_hlo,
                               n_microbatches=args.microbatches,
                               perf=args.perf)
                results[key] = rec
                res_path.write_text(json.dumps(results, indent=1))
                status = rec["status"]
                extra = (f" compile={rec.get('compile_s')}s"
                         if status == "ok" else
                         f" {rec.get('reason') or rec.get('error')}")
                print(f"[done] {key}: {status}{extra}", flush=True)

    ok = sum(1 for r in results.values() if r["status"] == "ok")
    skip = sum(1 for r in results.values() if r["status"] == "skip")
    err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\nTOTAL ok={ok} skip={skip} error={err}")
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
