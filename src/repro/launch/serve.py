"""Serving launcher: continuous batching of generation requests against a
sharded KV cache.

A minimal production-shaped server loop: a request queue feeds fixed-size
decode batches; finished sequences are swapped out and their cache slots
recycled (slot-indexed batch).  On this container it runs the reduced config
on the local device; the production mesh decode path is exercised by the
dry-run decode cells.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3_1b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve import make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab, (args.prompt_len,)).astype(np.int32)
             for _ in range(args.requests)]
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    max_seq = args.prompt_len + cfg.n_patches + args.max_new

    done = 0
    t0 = time.time()
    while queue:
        batch_prompts = [queue.pop(0) for _ in
                         range(min(args.batch, len(queue)))]
        B = len(batch_prompts)
        batch = {"tokens": jnp.asarray(np.stack(batch_prompts))}
        if cfg.family == "encdec":
            batch["frames"] = jnp.ones(
                (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * .1
        if cfg.family == "vlm":
            batch["patches"] = jnp.ones(
                (B, cfg.n_patches, cfg.d_model), jnp.float32) * .1
        logits, cache = T.prefill_forward(cfg, params, batch,
                                          max_seq=max_seq)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        pos0 = args.prompt_len + (cfg.n_patches if cfg.family == "vlm"
                                  else 0)
        outs = [tok]
        for i in range(args.max_new - 1):
            tok, cache = decode(params, cache, tok,
                                jnp.asarray(pos0 + i, jnp.int32))
            outs.append(tok)
        done += B
        print(f"[batch] finished {B} requests "
              f"({done}/{args.requests}); sample continuation: "
              f"{np.asarray(jnp.concatenate(outs, 1))[0][:8]}")
    dt = time.time() - t0
    print(f"served {done} requests in {dt:.2f}s "
          f"({done * args.max_new / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
