from repro.optim.optimizers import (  # noqa: F401
    adamw_init, adamw_update, clip_by_global_norm, cosine_schedule,
    sgd_init, sgd_update)
