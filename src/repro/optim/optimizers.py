"""Optimizers (AdamW, SGD-momentum), gradient clipping, LR schedules.

Self-contained (no optax).  Optimizer states mirror the parameter pytree so
they inherit the parameter shardings 1:1.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads: Pytree, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_init(params: Pytree) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_p = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def sgd_init(params: Pytree) -> Dict[str, Any]:
    return {"mom": jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32)}


def sgd_update(grads, state, params, *, lr, momentum=0.9):
    def upd(g, m, p):
        m = momentum * m + g.astype(jnp.float32)
        return m, (p.astype(jnp.float32) - lr * m).astype(p.dtype)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["mom"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
    return (jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
            {"mom": jax.tree_util.tree_unflatten(treedef,
                                                 [o[0] for o in out]),
             "count": state["count"] + 1})
