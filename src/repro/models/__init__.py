from repro.models.transformer import (  # noqa: F401
    decode_forward,
    init_cache,
    init_params,
    loss_fn,
    prefill_forward,
    train_forward,
)
