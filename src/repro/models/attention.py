"""Attention: GQA/MQA, MLA (DeepSeek), sliding-window, chunked online-softmax.

The chunked (flash-style) path is the default jnp implementation so that 32k+
prefill lowers with O(seq * chunk) live memory; the Pallas kernel in
repro.kernels.flash_attention implements the same dataflow for TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import MLAConfig, ModelConfig
from repro.models.layers import Leaf, dense_init, norm_init, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter init


def attn_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        r = jax.random.split(rng, 4)
        return {
            "q": dense_init(r[0], d, H * (m.qk_nope_dim + m.qk_rope_dim),
                            ("d_model", "heads_x_dim")),
            "kv_a": dense_init(r[1], d, m.kv_lora_rank + m.qk_rope_dim,
                               ("d_model", None)),
            "kv_norm": norm_init(m.kv_lora_rank),
            "kv_b": dense_init(r[2], m.kv_lora_rank,
                               H * (m.qk_nope_dim + m.v_head_dim),
                               (None, "heads_x_dim")),
            "o": dense_init(r[3], H * m.v_head_dim, d,
                            ("heads_x_dim", "d_model")),
        }
    r = jax.random.split(rng, 4)
    return {
        "q": dense_init(r[0], d, H * hd, ("d_model", "heads_x_dim")),
        "k": dense_init(r[1], d, Hkv * hd, ("d_model", "kv_heads_x_dim")),
        "v": dense_init(r[2], d, Hkv * hd, ("d_model", "kv_heads_x_dim")),
        "o": dense_init(r[3], H * hd, d, ("heads_x_dim", "d_model")),
    }


# ---------------------------------------------------------------------------
# chunked online-softmax attention (prefill / train)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      kv_valid=None, chunk=512):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D).  Returns (B, H, Sq, D).

    Scans over KV chunks with an online-softmax carry so live memory is
    O(Sq * chunk) rather than O(Sq * Skv).
    """
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = H // Hkv
    scale = D ** -0.5
    chunk = min(chunk, Skv)
    if Skv % chunk:  # pad KV to a chunk multiple; padded keys are masked out
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if kv_valid is None:
            kv_valid = Skv
        Skv = Skv + pad
    n_chunks = Skv // chunk

    # NOTE: q stays (B, H, Sq, D) so TP head-sharding is preserved even when
    # Hkv < tp; KV chunks are broadcast to full heads INSIDE the body (free —
    # fused into the einsum).  A (B, Hkv, G, ...) reshape here would force
    # XLA to replicate q across the model axis (observed: +2.1 GB/device of
    # fp32 traffic per layer on tinyllama train_4k).
    q_pos = q_offset + jnp.arange(Sq)
    kc = k.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    idx = jnp.arange(n_chunks)
    qf = q.astype(jnp.float32)

    def expand(t):  # (B, Hkv, c, D) -> (B, H, c, D), fusable broadcast
        if G == 1:
            return t
        return jnp.broadcast_to(
            t[:, :, None], (B, Hkv, G, chunk, D)).reshape(B, H, chunk, D)

    def body(carry, xs):
        m, l, acc = carry
        i, k_i, v_i = xs
        k_pos = i * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhcd->bhqc", qf,
                       expand(k_i).astype(jnp.float32)) * scale
        mask = jnp.ones((Sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None and not (isinstance(window, int) and window == 0):
            # trace-safe: window may be a scalar array; 0 means unlimited
            w_eff = jnp.where(window > 0, window, Sq + Skv + 1)
            mask &= (q_pos[:, None] - k_pos[None, :]) < w_eff
        if kv_valid is not None:
            mask &= (k_pos < kv_valid)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p, expand(v_i).astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    from repro.dist import context as dist_ctx
    if dist_ctx.perf_flags().attn_remat_chunk:
        # flash-style backward: recompute the (Sq, chunk) score tile in the
        # bwd pass instead of stacking it per chunk (§Perf: removes the
        # n_chunks x B x H x Sq x chunk fp32 residual the autodiff of the
        # plain scan materializes)
        body = jax.checkpoint(body)

    m0 = jnp.full((B, H, Sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, H, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (idx, kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def windowed_attention(q, k, v, *, window: int, chunk: int = 512,
                       q_offset=0):
    """Sliding-window attention with STATIC window: each query chunk
    attends only to its own and the previous KV chunk (requires
    window <= chunk), so compute and traffic scale with O(S * window)
    instead of O(S^2) — the gemma3 local-layer path (§Perf).

    q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D), Sq == Skv.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = H // Hkv
    assert window <= chunk, (window, chunk)
    chunk = min(chunk, Sq)
    assert Sq % chunk == 0
    nq = Sq // chunk
    scale = D ** -0.5
    # pad one chunk of zeros on the left so every q-chunk sees 2 chunks
    kp = jnp.pad(k, ((0, 0), (0, 0), (chunk, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (chunk, 0), (0, 0)))
    qc = q.reshape(B, H, nq, chunk, D).transpose(2, 0, 1, 3, 4)

    def expand(t, c):
        if G == 1:
            return t
        return jnp.broadcast_to(t[:, :, None], (B, Hkv, G, c, D)) \
            .reshape(B, H, c, D)

    def body(_, xs):
        j, q_j = xs
        k_j = jax.lax.dynamic_slice_in_dim(kp, j * chunk, 2 * chunk, 2)
        v_j = jax.lax.dynamic_slice_in_dim(vp, j * chunk, 2 * chunk, 2)
        q_pos = q_offset + j * chunk + jnp.arange(chunk)
        k_pos = q_offset + (j - 1) * chunk + jnp.arange(2 * chunk)
        s = jnp.einsum("bhqd,bhcd->bhqc", q_j.astype(jnp.float32),
                       expand(k_j, 2 * chunk).astype(jnp.float32)) * scale
        mask = (q_pos[:, None] >= k_pos[None, :]) \
            & ((q_pos[:, None] - k_pos[None, :]) < window) \
            & (k_pos >= 0)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqc,bhcd->bhqd", p,
                       expand(v_j, 2 * chunk).astype(jnp.float32))
        return (), o.astype(q.dtype)

    _, outs = jax.lax.scan(body, (), (jnp.arange(nq), qc))
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D)


def decode_attention(q, k_cache, v_cache, *, pos, window=0, k_pos=None):
    """Single-token decode.  q: (B, H, 1, D); caches: (B, Hkv, S, D).

    ``pos`` is the current (scalar) position; keys at index > pos are masked.
    ``k_pos``: optional global positions of the cache slice (windowed path).
    """
    B, H, _, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    G = H // Hkv
    scale = D ** -0.5

    def expand(t):  # (B, Hkv, S, D) -> (B, H, S, D) broadcast (fused)
        if G == 1:
            return t
        return jnp.broadcast_to(
            t[:, :, None], (B, Hkv, G, S, D)).reshape(B, H, S, D)

    s = jnp.einsum("bhd,bhsd->bhs", q[:, :, 0].astype(jnp.float32),
                   expand(k_cache).astype(jnp.float32)) * scale
    if k_pos is None:
        k_pos = jnp.arange(S)
    mask = k_pos <= pos
    if window is not None and not (isinstance(window, int) and window == 0):
        w_eff = jnp.where(window > 0, window, S + 1)
        mask &= (pos - k_pos) < w_eff
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", w, expand(v_cache).astype(jnp.float32))
    return out[:, :, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# standard (GQA) attention layer forward


def gqa_forward(p, x, cos, sin, *, cfg: ModelConfig, causal=True, window=0,
                q_offset=0, xa=None, static_window=None):
    """Full-sequence attention (train/prefill).  Returns (out, (k, v)).

    ``xa``: encoder output for cross attention (k/v from xa, no causal mask).
    ``static_window``: compile-time window -> O(S*window) windowed path.
    """
    from repro.dist.tp import tp_project
    B, S, d = x.shape
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    kv_src = xa if xa is not None else x
    Skv = kv_src.shape[1]
    q = (x @ p["q"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (kv_src @ p["k"]).reshape(B, Skv, Hkv, hd).transpose(0, 2, 1, 3)
    v = (kv_src @ p["v"]).reshape(B, Skv, Hkv, hd).transpose(0, 2, 1, 3)
    if cos is not None and xa is None:
        q = _rope_heads(q, cos, sin)
        k = _rope_heads(k, cos, sin)
    if static_window and xa is None:
        out = windowed_attention(q, k, v, window=static_window,
                                 q_offset=q_offset)
    else:
        out = chunked_attention(q, k, v, causal=causal and xa is None,
                                window=window, q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return tp_project(out, p["o"]), (k, v)


def gqa_decode(p, x, cache_k, cache_v, cos, sin, *, cfg: ModelConfig, pos,
               window=0, xa_kv=None, static_window=None):
    """One-token decode.  x: (B, 1, d).  cache_[kv]: (B, Hkv, S, hd).

    ``static_window``: compile-time window — the attention reads only a
    window-sized SLICE of the cache (O(window) instead of O(S) per token;
    the gemma3 local-layer decode path, §Perf)."""
    B, _, d = x.shape
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    q = (x @ p["q"]).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    if xa_kv is not None:
        k, v = xa_kv  # cross-attention: precomputed encoder KV
        out = decode_attention(q, k, v, pos=k.shape[2] - 1)
        out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
        return out @ p["o"], cache_k, cache_v
    k_new = (x @ p["k"]).reshape(B, 1, Hkv, hd).transpose(0, 2, 1, 3)
    v_new = (x @ p["v"]).reshape(B, 1, Hkv, hd).transpose(0, 2, 1, 3)
    if cos is not None:
        q = _rope_heads(q, cos, sin)
        k_new = _rope_heads(k_new, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                           (0, 0, pos, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                           (0, 0, pos, 0))
    if static_window:
        S = cache_k.shape[2]
        w = min(static_window, S)
        start = jnp.clip(pos - w + 1, 0, S - w)
        k_win = jax.lax.dynamic_slice_in_dim(cache_k, start, w, 2)
        v_win = jax.lax.dynamic_slice_in_dim(cache_v, start, w, 2)
        out = decode_attention(q, k_win, v_win, pos=pos,
                               k_pos=start + jnp.arange(w))
    else:
        out = decode_attention(q, cache_k, cache_v, pos=pos, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return out @ p["o"], cache_k, cache_v


def _rope_heads(x, cos, sin):
    """x: (B, H, S, D); cos/sin: (S, D/2) or (1, D/2) for decode."""
    from repro.models.layers import apply_rope
    return apply_rope(x, cos[None, None], sin[None, None])


# ---------------------------------------------------------------------------
# MLA (DeepSeek V2) — compressed KV cache


def mla_forward(p, x, cos, sin, *, cfg: ModelConfig, q_offset=0):
    """Train/prefill MLA, naive (expanded) form.  Returns (out, (c_kv, k_rope))."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
    q = (x @ p["q"]).reshape(B, S, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = x @ p["kv_a"]
    c_kv = rmsnorm(kv[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = kv[..., m.kv_lora_rank:]                      # (B, S, dr) shared
    q_rope = _rope_heads(q_rope, cos, sin)
    k_rope = _rope_heads(k_rope[:, None], cos, sin)[:, 0]  # rope on shared key
    # expand compressed kv
    kvb = (c_kv @ p["kv_b"]).reshape(B, S, H, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (B, H, S, dr))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v head dim to qk dim for the shared kernel, then slice back
    out = chunked_attention(qf, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                               (0, dn + dr - dv))),
                            causal=True, q_offset=q_offset)[..., :dv]
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    return out @ p["o"], (c_kv, k_rope)


def mla_decode(p, x, cache_ckv, cache_krope, cos, sin, *, cfg: ModelConfig, pos):
    """Absorbed-matmul MLA decode: attention runs in the compressed space.
    cache_ckv: (B, S, lora); cache_krope: (B, S, dr)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv, R = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    scale = (dn + dr) ** -0.5
    q = (x @ p["q"]).reshape(B, 1, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], _rope_heads(q[..., dn:], cos, sin)
    kv = x @ p["kv_a"]
    c_new = rmsnorm(kv[..., :R], p["kv_norm"])             # (B, 1, R)
    kr_new = _rope_heads(kv[:, None, :, R:], cos, sin)[:, 0]
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_new.astype(cache_ckv.dtype), (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, kr_new.astype(cache_krope.dtype), (0, pos, 0))
    wkb = p["kv_b"].reshape(R, H, dn + dv)
    w_k, w_v = wkb[..., :dn], wkb[..., dn:]
    # absorb: q into compressed space
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(jnp.float32),
                     w_k.astype(jnp.float32))
    s = (jnp.einsum("bhr,bsr->bhs", q_c, cache_ckv.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0].astype(jnp.float32),
                      cache_krope.astype(jnp.float32))) * scale
    mask = jnp.arange(cache_ckv.shape[1]) <= pos
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhs,bsr->bhr", w, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhv->bhv", ctx_c, w_v.astype(jnp.float32))
    out = out.reshape(B, 1, H * dv).astype(x.dtype)
    return out @ p["o"], cache_ckv, cache_krope
