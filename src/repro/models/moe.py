"""Mixture-of-Experts with expert parallelism.

Design (see DESIGN.md §2, "multi-accelerator worker pool" row): experts are
sharded over the ``model`` mesh axis.  Routing is computed redundantly on
every model-rank for its local batch shard; each rank gathers only the tokens
assigned to ITS experts into fixed-capacity buffers (the SMAUG command-queue
analogue: tiles whose partial results belong to one expert land on that
expert's queue), computes them, and the per-rank partial outputs are combined
with one psum over ``model`` — the same collective cost as the TP all-reduce
it replaces for a dense MLP.

Dispatch is gather/scatter-index based (no one-hot dispatch einsums), so HLO
FLOPs stay close to the useful expert FLOPs; this is the "beyond-paper"
default, with `dispatch="einsum"` kept as the naive baseline for the §Perf
comparison.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.dist import context as dist_ctx
from repro.models.layers import Leaf, dense_init


def moe_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    e = cfg.moe
    d, dff = cfg.d_model, e.d_ff_expert
    r = jax.random.split(rng, 5)
    scale = 1.0 / math.sqrt(d)

    def experts(rng_, n, in_d, out_d, axes):
        w = jax.random.normal(rng_, (n, in_d, out_d), jnp.float32) / math.sqrt(in_d)
        return Leaf(w.astype(dtype), axes)

    p = {
        "router": Leaf(jax.random.normal(r[0], (d, e.n_experts), jnp.float32)
                       * scale, ("d_model", None)),
        "gate": experts(r[1], e.n_experts, d, dff, ("experts", "d_model", None)),
        "up": experts(r[2], e.n_experts, d, dff, ("experts", "d_model", None)),
        "down": experts(r[3], e.n_experts, dff, d, ("experts", None, "d_model")),
    }
    if e.n_shared:
        # shared experts: always-on, TP-sharded like a dense MLP
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(r[4], d, e.n_shared * dff, "swiglu", dtype)
    return p


def _route(x32, router_w, n_experts, top_k):
    """Returns (weights (T,k) f32, experts (T,k) i32, aux dict)."""
    logits = x32 @ router_w                                # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style) + router z-loss
    T = x32.shape[0]
    me = jnp.mean(probs, axis=0)
    onehot = jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(onehot, axis=0)
    aux = {
        "load_balance": n_experts * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return w, idx, aux


def _dispatch_indices(e_idx, n_experts, e_start, e_local, capacity):
    """Compute capacity-buffer coordinates for the LOCAL expert shard.

    e_idx: (T, k) global expert assignment.  Returns:
      buf_token (e_local, capacity): token id feeding each buffer slot
        (sentinel T for empty slots),
      slot_of (T, k): flattened local buffer slot per assignment
        (sentinel e_local*capacity for non-local / overflowed).
    """
    T, k = e_idx.shape
    flat = e_idx.reshape(-1)                               # (T*k,) token-major
    onehot = (flat[:, None] == jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                   # position per expert
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]  # (T*k,)
    local = (flat >= e_start) & (flat < e_start + e_local) & (pos < capacity)
    e_loc = jnp.where(local, flat - e_start, e_local)      # OOB when not ours
    slot_of = jnp.where(local, e_loc * capacity + pos, e_local * capacity)
    token_of = jnp.arange(T * k) // k
    buf_token = jnp.full((e_local * capacity,), T, dtype=jnp.int32)
    buf_token = buf_token.at[slot_of].set(
        jnp.where(local, token_of, T), mode="drop")
    return buf_token.reshape(e_local, capacity), slot_of.reshape(T, k)


def _expert_ffn(p_gate, p_up, p_down, xb, activation="swiglu"):
    """xb: (E_local, C, d) -> (E_local, C, d)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p_gate))
    u = jnp.einsum("ecd,edf->ecf", xb, p_up)
    return jnp.einsum("ecf,efd->ecd", g * u, p_down)


def _moe_local(p, x, cfg: ModelConfig, ep_rank, ep_size, psum_axis):
    """Per-shard MoE.  x: (T, d) local tokens.  Returns (out (T, d), aux)."""
    e = cfg.moe
    T, d = x.shape
    e_local = e.n_experts // ep_size
    e_start = ep_rank * e_local
    capacity = max(1, math.ceil(T * e.top_k * e.capacity_factor / e.n_experts))

    w, idx, aux = _route(x.astype(jnp.float32), p["router"], e.n_experts,
                         e.top_k)
    buf_token, slot_of = _dispatch_indices(idx, e.n_experts, e_start, e_local,
                                           capacity)
    xpad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xb = xpad[buf_token.reshape(-1)].reshape(e_local, capacity, d)
    gate_l = jax.lax.dynamic_slice_in_dim(p["gate"], e_start, e_local, 0)
    up_l = jax.lax.dynamic_slice_in_dim(p["up"], e_start, e_local, 0)
    down_l = jax.lax.dynamic_slice_in_dim(p["down"], e_start, e_local, 0)
    yb = _expert_ffn(gate_l, up_l, down_l, xb).reshape(e_local * capacity, d)
    ypad = jnp.concatenate([yb, jnp.zeros((1, d), yb.dtype)], axis=0)
    out = jnp.zeros((T, d), jnp.float32)
    for j in range(e.top_k):
        out = out + w[:, j:j + 1] * ypad[slot_of[:, j]].astype(jnp.float32)
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out.astype(x.dtype), aux


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B, S, d), aux losses dict).

    Uses shard_map EP over the 'model' axis when a mesh with a non-trivial
    'model' axis is active and divides n_experts; otherwise single-shard.
    """
    B, S, d = x.shape
    e = cfg.moe
    if dist_ctx.perf_flags().moe_dispatch == "einsum":
        return moe_apply_einsum(p, x, cfg)  # ablation baseline
    mesh = dist_ctx.get_mesh()
    tp = dist_ctx.mesh_axis_size("model")
    use_ep = (mesh is not None and tp > 1 and e.n_experts % tp == 0)

    if use_ep:
        from jax.sharding import PartitionSpec as P
        dp = dist_ctx.dp_axes()
        xspec = P(dp if dp else None, None, None)
        espec = P(None, "model", None, None)

        def inner(xl, router_w, gate, up, down):
            rank = jax.lax.axis_index("model")
            pl = {"router": router_w, "gate": gate[0], "up": up[0],
                  "down": down[0]}
            # note: inside shard_map the expert leading dim is already local,
            # so treat the shard as the full expert set with offset rank.
            T = xl.shape[0] * xl.shape[1]
            out, aux = _moe_local_shard(pl, xl.reshape(T, d), cfg, rank, tp,
                                        "model")
            lb, rz = aux["load_balance"], aux["router_z"]
            if dp:  # make aux scalars truly replicated across data shards
                lb = jax.lax.pmean(lb, dp)
                rz = jax.lax.pmean(rz, dp)
            return out.reshape(xl.shape), lb, rz

        from repro.core.compat import shard_map
        out, lb, rz = shard_map(
            inner, mesh=mesh,
            in_specs=(xspec, P(None, None), espec, espec, espec),
            out_specs=(xspec, P(), P()),
        )(x, p["router"], p["gate"][None], p["up"][None], p["down"][None])
        aux = {"load_balance": lb, "router_z": rz}
    else:
        out, aux = _moe_local(p, x.reshape(B * S, d), cfg, 0, 1, None)
        out = out.reshape(B, S, d)

    if "shared" in p:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(p["shared"], x, "swiglu")
    return out, aux


def _moe_local_shard(p, x, cfg, ep_rank, ep_size, psum_axis):
    """Like _moe_local but expert params are ALREADY the local shard."""
    e = cfg.moe
    T, d = x.shape
    e_local = e.n_experts // ep_size
    e_start = ep_rank * e_local
    capacity = max(1, math.ceil(T * e.top_k * e.capacity_factor / e.n_experts))
    w, idx, aux = _route(x.astype(jnp.float32), p["router"], e.n_experts,
                         e.top_k)
    buf_token, slot_of = _dispatch_indices(idx, e.n_experts, e_start, e_local,
                                           capacity)
    xpad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xb = xpad[buf_token.reshape(-1)].reshape(e_local, capacity, d)
    yb = _expert_ffn(p["gate"], p["up"], p["down"], xb)
    ypad = jnp.concatenate([yb.reshape(e_local * capacity, d),
                            jnp.zeros((1, d), yb.dtype)], axis=0)
    out = jnp.zeros((T, d), jnp.float32)
    for j in range(e.top_k):
        out = out + w[:, j:j + 1] * ypad[slot_of[:, j]].astype(jnp.float32)
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# naive einsum dispatch (paper-faithful "simple" baseline for §Perf)


def moe_apply_einsum(p, x, cfg: ModelConfig):
    """One-hot dispatch-einsum MoE (mesh-tensorflow style).  Kept as the
    baseline the §Perf iteration improves on: its dispatch einsums dwarf the
    useful expert FLOPs at top_k>2."""
    B, S, d = x.shape
    e = cfg.moe
    T = B * S
    xf = x.reshape(T, d)
    capacity = max(1, math.ceil(T * e.top_k * e.capacity_factor / e.n_experts))
    w, idx, aux = _route(xf.astype(jnp.float32), p["router"], e.n_experts,
                         e.top_k)
    # dispatch tensor (T, E, C)
    onehot_e = jax.nn.one_hot(idx, e.n_experts, dtype=jnp.float32)  # (T,k,E)
    pos = jnp.cumsum(onehot_e.reshape(T * e.top_k, e.n_experts), axis=0) - 1
    pos = pos.reshape(T, e.top_k, e.n_experts)
    pos_tk = jnp.sum(pos * onehot_e, axis=-1)              # (T, k)
    within = (pos_tk < capacity)[..., None]                # (T, k, 1)
    pos_onehot = jax.nn.one_hot(pos_tk, capacity, dtype=jnp.float32)
    disp = jnp.einsum("tke,tkc->tec", onehot_e * within, pos_onehot)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot_e * within, pos_onehot, w)
    xb = jnp.einsum("tec,td->ecd", disp, xf.astype(jnp.float32)).astype(x.dtype)
    yb = _expert_ffn(p["gate"], p["up"], p["down"], xb)
    out = jnp.einsum("tec,ecd->td", comb, yb.astype(jnp.float32))
    out = out.reshape(B, S, d).astype(x.dtype)
    if "shared" in p:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(p["shared"], x, "swiglu")
    return out, aux
