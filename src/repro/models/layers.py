"""Common layers: norms, embeddings, RoPE, MLPs.

Parameters are plain pytrees of jnp arrays.  Each init helper returns
``Leaf(value, axes)`` pairs where ``axes`` is a tuple of *logical* axis names
used by the sharding rule engine (repro.dist.sharding).  ``split_leaves``
separates a Leaf-tree into (params, axes) trees.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Leaf:
    value: Any
    axes: Tuple[Optional[str], ...]


def _is_leaf(x):
    return isinstance(x, Leaf)


def split_leaves(tree):
    params = jax.tree_util.tree_map(lambda l: l.value, tree, is_leaf=_is_leaf)
    axes = jax.tree_util.tree_map(lambda l: l.axes, tree, is_leaf=_is_leaf)
    return params, axes


def dense_init(rng, in_dim, out_dim, axes, dtype=jnp.bfloat16, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    w = jax.random.normal(rng, (in_dim, out_dim), dtype=jnp.float32) * scale
    return Leaf(w.astype(dtype), axes)


def embed_init(rng, vocab, d_model, dtype=jnp.bfloat16):
    w = jax.random.normal(rng, (vocab, d_model), dtype=jnp.float32) * 0.02
    return Leaf(w.astype(dtype), ("vocab", "d_model"))


def norm_init(d_model):
    # norm scales stay fp32 and replicated
    return Leaf(jnp.ones((d_model,), dtype=jnp.float32), (None,))


def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * scale
    return out.astype(x.dtype)


def layernorm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale
    return out.astype(x.dtype)


def apply_norm(kind, x, scale):
    return rmsnorm(x, scale) if kind == "rmsnorm" else layernorm(x, scale)


def rope_tables(positions, dim, theta):
    """positions: (...,) int32 -> cos/sin of shape positions.shape + (dim/2,)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, D); cos/sin: broadcastable (..., S, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(n_ctx, d_model):
    pos = np.arange(n_ctx)[:, None]
    dim = np.arange(0, d_model, 2)[None, :] / d_model
    ang = pos / (10_000.0 ** dim)
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# MLP


def mlp_init(rng, d_model, d_ff, activation, dtype=jnp.bfloat16):
    r1, r2, r3 = jax.random.split(rng, 3)
    gated = activation in ("swiglu", "geglu")
    p = {
        "up": dense_init(r1, d_model, d_ff, ("d_model", "d_ff"), dtype),
        "down": dense_init(r2, d_ff, d_model, ("d_ff", "d_model"), dtype),
    }
    if gated:
        p["gate"] = dense_init(r3, d_model, d_ff, ("d_model", "d_ff"), dtype)
    return p


def _act(name, x):
    if name in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.gelu(x, approximate=True)  # plain gelu


def mlp_apply(p, x, activation):
    from repro.dist.tp import tp_project
    up = x @ p["up"]
    if "gate" in p:
        up = _act(activation, x @ p["gate"]) * up
    else:
        up = _act(activation, up)
    return tp_project(up, p["down"])
