"""State-space models: Mamba1 (selective scan) and Mamba2 (SSD).

Two sequence-mixing implementations are provided for Mamba1:
  - ``scan``    : lax.scan over time (paper-faithful simple baseline; HBM
                  traffic O(seq) state round-trips — the memory-bound case
                  the §Perf iteration attacks),
  - ``chunked`` : lax.scan over chunks with an associative scan inside each
                  chunk (parallel depth O(log c)); the Pallas kernel in
                  repro.kernels.mamba_scan is the TPU realization.

Mamba2 uses the chunked SSD algorithm directly.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models.layers import Leaf, dense_init, norm_init, rmsnorm


# ---------------------------------------------------------------------------
# init


def mamba1_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = max(1, math.ceil(d / 16))
    N = s.d_state
    r = jax.random.split(rng, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (d_in, 1))
    return {
        "in_proj": dense_init(r[0], d, 2 * d_in, ("d_model", "d_inner")),
        "conv_w": Leaf(jax.random.normal(r[1], (d_in, s.d_conv), jnp.float32)
                       .astype(dtype) * 0.2, ("d_inner", None)),
        "conv_b": Leaf(jnp.zeros((d_in,), dtype), ("d_inner",)),
        "x_proj": dense_init(r[2], d_in, dt_rank + 2 * N, ("d_inner", None)),
        "dt_proj": dense_init(r[3], dt_rank, d_in, (None, "d_inner")),
        "dt_bias": Leaf(jnp.full((d_in,), -4.6, jnp.float32), ("d_inner",)),
        "A_log": Leaf(jnp.log(A), ("d_inner", None)),
        "D": Leaf(jnp.ones((d_in,), jnp.float32), ("d_inner",)),
        "out_proj": dense_init(r[4], d_in, d, ("d_inner", "d_model")),
    }


def mamba2_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = s.n_heads
    N = s.d_state
    assert H * s.head_dim == d_in, (H, s.head_dim, d_in)
    r = jax.random.split(rng, 4)
    conv_dim = d_in + 2 * N  # conv over (x, B, C)
    return {
        "in_proj": dense_init(r[0], d, 2 * d_in + 2 * N + H,
                              ("d_model", "d_inner")),
        "conv_w": Leaf(jax.random.normal(r[1], (conv_dim, s.d_conv),
                                         jnp.float32).astype(dtype) * 0.2,
                       ("d_inner", None)),
        "conv_b": Leaf(jnp.zeros((conv_dim,), dtype), ("d_inner",)),
        "A_log": Leaf(jnp.zeros((H,), jnp.float32), (None,)),
        "dt_bias": Leaf(jnp.full((H,), -4.6, jnp.float32), (None,)),
        "D": Leaf(jnp.ones((H,), jnp.float32), (None,)),
        "norm": norm_init(d_in),
        "out_proj": dense_init(r[2], d_in, d, ("d_inner", "d_model")),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv (kernel k, as sum of shifts — k is 4)


def causal_conv1d(x, w, b):
    """x: (B, S, C); w: (C, k); returns (B, S, C)."""
    k = w.shape[1]
    out = x * w[None, None, :, -1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[None, None, :, -1 - i]
    return out + b[None, None]


def conv1d_step(x_t, conv_state, w, b):
    """x_t: (B, C); conv_state: (B, C, k-1) past inputs.  Returns (y, state)."""
    k = w.shape[1]
    full = jnp.concatenate([conv_state, x_t[..., None]], axis=-1)  # (B,C,k)
    y = jnp.sum(full * w[None], axis=-1) + b[None]
    return y, full[..., 1:]


# ---------------------------------------------------------------------------
# mamba1 selective scan


def _ssm_coeffs1(p, xz, cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    N = s.d_state
    dt_rank = p["dt_proj"].shape[0]
    x, z = xz[..., :d_in], xz[..., d_in:]
    x = causal_conv1d(x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)   # (B,S,d_in)
    Bm = proj[..., dt_rank:dt_rank + N].astype(jnp.float32)    # (B,S,N)
    Cm = proj[..., dt_rank + N:].astype(jnp.float32)           # (B,S,N)
    A = -jnp.exp(p["A_log"])                                   # (d_in,N)
    return x, z, dt, Bm, Cm, A


def mamba1_forward(p, x_seq, cfg: ModelConfig, impl="scan", state=None):
    """x_seq: (B, S, d_model) -> (out, final_state dict(conv, ssm)).

    state (decode carry): dict(conv (B,d_in,k-1), ssm (B,d_in,N)).
    """
    s = cfg.ssm
    B, S, _ = x_seq.shape
    d_in = s.expand * cfg.d_model
    N = s.d_state
    xz = x_seq @ p["in_proj"]
    # conv tail = last (k-1) pre-conv inputs, for decode continuation
    conv_tail = xz[:, -(s.d_conv - 1):, :d_in].transpose(0, 2, 1)
    x, z, dt, Bm, Cm, A = _ssm_coeffs1(p, xz, cfg)
    xf = x.astype(jnp.float32)

    da = jnp.exp(dt[..., None] * A[None, None])                # (B,S,d_in,N)
    dbx = dt[..., None] * Bm[:, :, None, :] * xf[..., None]    # (B,S,d_in,N)

    h0 = (jnp.zeros((B, d_in, N), jnp.float32) if state is None
          else state["ssm"])

    if impl == "scan":
        def step(h, inp):
            da_t, dbx_t, C_t = inp
            h = da_t * h + dbx_t
            y = jnp.einsum("bdn,bn->bd", h, C_t)
            return h, y
        hT, ys = jax.lax.scan(
            step, h0,
            (da.transpose(1, 0, 2, 3), dbx.transpose(1, 0, 2, 3),
             Cm.transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2)                              # (B,S,d_in)
    elif impl.startswith("unroll"):
        # §Perf: U sequential steps per scan iteration — amortizes the
        # per-step state round-trip and slice/stack bookkeeping U-fold
        # while staying mathematically identical to the plain scan
        U = int(impl[len("unroll"):] or 8)
        assert S % U == 0, (S, U)
        shape_u = (B, S // U, U)

        def chunks_u(t):
            return t.reshape(*shape_u, *t.shape[2:]).transpose(
                1, 2, 0, *range(3, t.ndim + 1))

        da_u, dbx_u = chunks_u(da), chunks_u(dbx)
        C_u = chunks_u(Cm)

        def step(h, inp):
            da_i, dbx_i, C_i = inp           # (U,B,d,N),(U,B,d,N),(U,B,N)
            ys = []
            for t in range(U):
                h = da_i[t] * h + dbx_i[t]
                ys.append(jnp.einsum("bdn,bn->bd", h, C_i[t]))
            return h, jnp.stack(ys)
        hT, ys = jax.lax.scan(step, h0, (da_u, dbx_u, C_u))
        y = ys.transpose(2, 0, 1, 3).reshape(B, S, d_in)  # (S/U,U,B,d)->(B,S,d)
    else:  # chunked: associative scan within chunks, sequential across
        c = min(getattr(s, "chunk", 256), S)
        assert S % c == 0, (S, c)
        nc = S // c
        da_c = da.reshape(B, nc, c, d_in, N).transpose(1, 0, 2, 3, 4)
        dbx_c = dbx.reshape(B, nc, c, d_in, N).transpose(1, 0, 2, 3, 4)
        C_c = Cm.reshape(B, nc, c, N).transpose(1, 0, 2, 3)

        def chunk_step(h, inp):
            da_i, dbx_i, C_i = inp                 # (B,c,d,N),(B,c,d,N),(B,c,N)
            # h contributes da-prefix-scaled; combine with intra-chunk scan
            def comb(l, r):
                return (l[0] * r[0], l[1] * r[0] + r[1])
            pa, pb = jax.lax.associative_scan(comb, (da_i, dbx_i), axis=1)
            hs = pa * h[:, None] + pb              # (B,c,d,N) states
            y = jnp.einsum("bcdn,bcn->bcd", hs, C_i)
            return hs[:, -1], y
        hT, ys = jax.lax.scan(chunk_step, h0, (da_c, dbx_c, C_c))
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, d_in)

    y = y + p["D"][None, None] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_seq.dtype)
    return y @ p["out_proj"], {"ssm": hT,
                               "conv": conv_tail.astype(jnp.bfloat16)}


def mamba1_decode(p, x_t, state, cfg: ModelConfig):
    """One-token decode.  x_t: (B, 1, d).  state: dict(conv, ssm)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    N = s.d_state
    dt_rank = p["dt_proj"].shape[0]
    xz = (x_t[:, 0] @ p["in_proj"])
    x, z = xz[..., :d_in], xz[..., d_in:]
    xc, conv_state = conv1d_step(x, state["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    proj = xc @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)   # (B,d_in)
    Bm = proj[..., dt_rank:dt_rank + N].astype(jnp.float32)
    Cm = proj[..., dt_rank + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    xf = xc.astype(jnp.float32)
    h = state["ssm"]
    h = jnp.exp(dt[..., None] * A[None]) * h \
        + dt[..., None] * Bm[:, None, :] * xf[..., None]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + p["D"][None] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    return (y @ p["out_proj"])[:, None], {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# mamba2 (SSD, chunked)


def mamba2_forward(p, x_seq, cfg: ModelConfig, state=None):
    """x_seq: (B, S, d_model) -> (out, final ssm state (B,H,P,N))."""
    s = cfg.ssm
    B, S, _ = x_seq.shape
    d_in = s.expand * cfg.d_model
    H, P, N = s.n_heads, s.head_dim, s.d_state
    c = min(s.chunk, S)
    assert S % c == 0
    nc = S // c

    zxbcdt = x_seq @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    conv_tail = xbc[:, -(s.d_conv - 1):].transpose(0, 2, 1)
    dt = jax.nn.softplus(
        zxbcdt[..., -H:].astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
    x = xbc[..., :d_in].reshape(B, S, H, P)
    Bm = xbc[..., d_in:d_in + N].astype(jnp.float32)           # (B,S,N)
    Cm = xbc[..., d_in + N:].astype(jnp.float32)               # (B,S,N)
    A = -jnp.exp(p["A_log"])                                   # (H,)

    loga = dt * A[None, None]                                  # (B,S,H) <=0
    xf = x.astype(jnp.float32)

    # chunk views: (nc, B, c, ...)
    def chunks(t):
        return t.reshape(B, nc, c, *t.shape[2:]).transpose(1, 0, 2,
                                                           *range(3, t.ndim + 1))
    loga_c, x_c, B_c, C_c, dt_c = map(chunks, (loga, xf, Bm, Cm, dt))

    def chunk_step(h, inp):
        la, xi, bi, ci, dti = inp   # (B,c,H),(B,c,H,P),(B,c,N),(B,c,N),(B,c,H)
        cs = jnp.cumsum(la, axis=1)                            # (B,c,H)
        # intra-chunk: decay matrix L[i,j] = exp(cs_i - cs_j) for i >= j
        diff = cs[:, :, None, :] - cs[:, None, :, :]           # (B,c,c,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bin,bjn->bij", ci, bi)                # (B,c,c)
        w = cb[:, :, :, None] * L                              # (B,c,c,H)
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", w, dti, xi)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", ci, h, jnp.exp(cs))
        # state update
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)             # (B,c,H)
        dx = dti[..., None] * xi * decay_to_end[..., None]     # (B,c,H,P)
        h_new = h * jnp.exp(cs[:, -1])[:, :, None, None] \
            + jnp.einsum("bchp,bcn->bhpn", dx, bi)
        return h_new, y_intra + y_inter

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if state is None
          else state["ssm"])
    hT, ys = jax.lax.scan(chunk_step, h0, (loga_c, x_c, B_c, C_c, dt_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * xf
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y.astype(x_seq.dtype), p["norm"])
    y = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    return y.astype(x_seq.dtype) @ p["out_proj"], \
        {"ssm": hT, "conv": conv_tail.astype(jnp.bfloat16)}


def mamba2_decode(p, x_t, state, cfg: ModelConfig):
    """One-token decode.  state: dict(conv (B,conv_dim,k-1), ssm (B,H,P,N))."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H, P, N = s.n_heads, s.head_dim, s.d_state
    zxbcdt = x_t[:, 0] @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt = jax.nn.softplus(zxbcdt[..., -H:].astype(jnp.float32) + p["dt_bias"])
    xc, conv_state = conv1d_step(xbc, state["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    x = xc[..., :d_in].reshape(-1, H, P).astype(jnp.float32)
    Bm = xc[..., d_in:d_in + N].astype(jnp.float32)
    Cm = xc[..., d_in + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None])                                  # (B,H)
    h = state["ssm"] * a[..., None, None] \
        + jnp.einsum("bh,bhp,bn->bhpn", dt, x, Bm)
    y = jnp.einsum("bhpn,bn->bhp", h, Cm) + p["D"][None, :, None] * x
    y = y.reshape(-1, d_in)
    y = rmsnorm(y.astype(x_t.dtype), p["norm"])
    y = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    return (y.astype(x_t.dtype) @ p["out_proj"])[:, None], \
        {"conv": conv_state, "ssm": h}
