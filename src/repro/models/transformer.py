"""Model builder: decoder-only / enc-dec / SSM / hybrid transformers.

All architectures share the same entry points:
  init_params(cfg, rng)                  -> (params, axes)
  train_forward(cfg, params, batch)      -> (logits, aux)
  loss_fn(cfg, params, batch)            -> (loss, metrics)
  init_cache(cfg, batch, max_seq)        -> (cache, cache_axes)
  prefill_forward(cfg, params, batch)    -> (logits_last, cache)
  decode_forward(cfg, params, cache, tokens, pos) -> (logits, cache)

Layers are STACKED along a leading axis and executed with lax.scan (+remat),
which keeps HLO size O(1) in depth and forms the loop tree the SMAUG-style
sampled simulator unsamples through (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (Leaf, apply_norm, embed_init, mlp_apply,
                                 mlp_init, norm_init, rope_tables,
                                 sinusoid_positions, split_leaves)

Pytree = Any


# ---------------------------------------------------------------------------
# init


def _block_init(rng, cfg: ModelConfig, kind: str):
    """kind: attn_mlp | attn_moe | xattn (encdec decoder) | mamba1 | mamba2"""
    r = jax.random.split(rng, 6)
    if kind == "mamba1":
        return {"norm1": norm_init(cfg.d_model),
                "ssm": ssm_mod.mamba1_init(r[0], cfg)}
    if kind == "mamba2":
        return {"norm1": norm_init(cfg.d_model),
                "ssm": ssm_mod.mamba2_init(r[0], cfg)}
    p = {"norm1": norm_init(cfg.d_model),
         "attn": attn.attn_init(r[0], cfg),
         "norm2": norm_init(cfg.d_model)}
    if kind == "attn_moe":
        p["moe"] = moe_mod.moe_init(r[1], cfg)
    else:
        p["mlp"] = mlp_init(r[1], cfg.d_model, cfg.d_ff, cfg.activation)
    if kind == "xattn":
        p["norm_x"] = norm_init(cfg.d_model)
        p["xattn"] = attn.attn_init(r[2], cfg)
        p["mlp"] = mlp_init(r[3], cfg.d_model, cfg.d_ff, cfg.activation)
    return p


def _layer_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "mamba1" if cfg.ssm.version == 1 else "mamba2"
    if cfg.family == "hybrid":
        return "mamba2" if cfg.ssm.version == 2 else "mamba1"
    if cfg.family == "moe":
        return "attn_moe"
    if cfg.family == "encdec":
        return "xattn"
    return "attn_mlp"


def _stack_init(rng, cfg: ModelConfig, kind: str, n: int):
    rngs = jax.random.split(rng, n)
    leaves = [_block_init(r, cfg, kind) for r in rngs]

    def is_leaf(x):
        return isinstance(x, Leaf)

    def stack(*ls):
        return Leaf(jnp.stack([l.value for l in ls]),
                    ("layers",) + ls[0].axes)
    return jax.tree_util.tree_map(stack, *leaves, is_leaf=is_leaf)


def init_params(cfg: ModelConfig, rng) -> Tuple[Pytree, Pytree]:
    """Returns (params, logical-axes tree)."""
    r = jax.random.split(rng, 6)
    kind = _layer_kind(cfg)
    p: Dict[str, Any] = {"embed": embed_init(r[0], cfg.vocab, cfg.d_model)}
    p["layers"] = _stack_init(r[1], cfg, kind, cfg.n_layers)
    p["final_norm"] = norm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        from repro.models.layers import dense_init
        p["lm_head"] = dense_init(r[2], cfg.d_model, cfg.vocab,
                                  ("d_model", "vocab"))
    if cfg.family == "encdec":
        p["encoder"] = {
            "layers": _stack_init(r[3], cfg, "attn_mlp", cfg.encoder.n_layers),
            "final_norm": norm_init(cfg.d_model),
        }
        n_pos = min(cfg.max_seq, 32_768)
        p["pos"] = Leaf(
            (jax.random.normal(r[4], (n_pos, cfg.d_model), jnp.float32)
             * 0.01).astype(jnp.bfloat16), (None, "d_model"))
    if cfg.family == "hybrid":
        p["shared_attn"] = _block_init(r[5], cfg, "attn_mlp")
    return split_leaves(p)


# ---------------------------------------------------------------------------
# helpers


ZERO_AUX = lambda: (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))


def _window_schedule(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer window sizes; 0 = full attention."""
    L = cfg.n_layers
    if cfg.local_global_ratio > 0:
        k = cfg.local_global_ratio + 1
        return jnp.array([0 if (i + 1) % k == 0 else cfg.window
                          for i in range(L)], jnp.int32)
    return jnp.full((L,), cfg.window, jnp.int32)


def _rope_for(cfg: ModelConfig, positions):
    if cfg.rope_theta <= 0:
        return None, None
    dim = cfg.mla.qk_rope_dim if cfg.mla is not None else cfg.resolved_head_dim
    return rope_tables(positions, dim, cfg.rope_theta)


def _embed_tokens(cfg: ModelConfig, p, tokens, pos_offset=0):
    x = p["embed"][tokens]
    if cfg.family == "encdec":
        pe = jax.lax.dynamic_slice_in_dim(p["pos"], pos_offset,
                                          tokens.shape[1], 0)
        x = x + pe[None]
    if cfg.family in ("dense", "vlm", "moe") and cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)  # gemma embeds are scaled
    return x.astype(jnp.bfloat16)


def _logits(cfg: ModelConfig, p, x):
    x = apply_norm(cfg.norm, x, p["final_norm"])
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["embed"])
    return x @ p["lm_head"]


def _encoder_forward(cfg: ModelConfig, p, frames):
    """frames: (B, n_ctx, d) precomputed (frontend stub).  Whisper encoder."""
    x = frames.astype(jnp.float32) \
        + sinusoid_positions(frames.shape[1], cfg.d_model)[None]
    x = x.astype(jnp.bfloat16)

    def body(x, pl):
        h, _ = attn.gqa_forward(pl["attn"],
                                apply_norm(cfg.norm, x, pl["norm1"]),
                                None, None, cfg=cfg, causal=False)
        x = x + h
        h = mlp_apply(pl["mlp"], apply_norm(cfg.norm, x, pl["norm2"]),
                      cfg.activation)
        return x + h, ()

    x, _ = jax.lax.scan(jax.checkpoint(body), x, p["encoder"]["layers"])
    return apply_norm(cfg.norm, x, p["encoder"]["final_norm"])


# ---------------------------------------------------------------------------
# backbone (full-sequence; train and prefill)


def _backbone(cfg: ModelConfig, p, x, positions, xa=None, collect=False):
    """Returns (x, aux(lb, rz), collected-states dict or None)."""
    cos, sin = _rope_for(cfg, positions)

    if cfg.family == "ssm":
        from repro.dist import context as dist_ctx
        impl = dist_ctx.perf_flags().ssm_impl
        sp_on = dist_ctx.perf_flags().seq_sharded_residual

        def fwd(pp, xx, cc):
            if cfg.ssm.version == 1:
                return ssm_mod.mamba1_forward(pp, xx, cc, impl=impl)
            return ssm_mod.mamba2_forward(pp, xx, cc)

        def body(x, pl):
            if sp_on:  # Megatron-SP residual (see dense branch)
                from repro.dist.sharding import constrain
                x = constrain(x, ("batch", "seq_model", None))
            h, st = fwd(pl["ssm"], apply_norm(cfg.norm, x, pl["norm1"]), cfg)
            return x + h, (st if collect else ())
        x, sts = jax.lax.scan(jax.checkpoint(body), x, p["layers"])
        return x, ZERO_AUX(), (sts if collect else None)

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        nsb = cfg.n_layers // k
        shared = p["shared_attn"]

        def superblock(x, pls):
            def mamba_body(x, pl):
                h, st = ssm_mod.mamba2_forward(
                    pl["ssm"], apply_norm(cfg.norm, x, pl["norm1"]), cfg)
                return x + h, (st if collect else ())
            x, sts = jax.lax.scan(jax.checkpoint(mamba_body), x, pls)
            h, kv = attn.gqa_forward(shared["attn"],
                                     apply_norm(cfg.norm, x, shared["norm1"]),
                                     cos, sin, cfg=cfg, causal=True)
            x = x + h
            h = mlp_apply(shared["mlp"],
                          apply_norm(cfg.norm, x, shared["norm2"]),
                          cfg.activation)
            return x + h, ((sts, kv) if collect else ())

        pls = jax.tree_util.tree_map(
            lambda t: t.reshape(nsb, k, *t.shape[1:]), p["layers"])
        x, ys = jax.lax.scan(jax.checkpoint(superblock), x, pls)
        return x, ZERO_AUX(), (ys if collect else None)

    windows = _window_schedule(cfg)

    # §Perf: static-window grouped scan for local:global archs (gemma3) —
    # unrolls each (ratio local + 1 global) group so local layers take the
    # O(S*window) windowed-attention path instead of masked full attention
    from repro.dist import context as dist_ctx
    flags = dist_ctx.perf_flags()
    if (cfg.local_global_ratio > 0 and flags.windowed_attention
            and cfg.mla is None and xa is None and cfg.window > 0):
        grp = cfg.local_global_ratio + 1
        nsb = cfg.n_layers // grp
        tail = cfg.n_layers - nsb * grp
        win_sched = [0 if (i + 1) % grp == 0 else cfg.window
                     for i in range(cfg.n_layers)]  # static python ints

        def one_layer(x, lb, rz, pl, sw):
            h_in = apply_norm(cfg.norm, x, pl["norm1"])
            h, kv = attn.gqa_forward(pl["attn"], h_in, cos, sin, cfg=cfg,
                                     causal=True, static_window=sw)
            x = x + h
            h_in = apply_norm(cfg.norm, x, pl["norm2"])
            if "moe" in pl:
                h, aux = moe_mod.moe_apply(pl["moe"], h_in, cfg)
                lb, rz = lb + aux["load_balance"], rz + aux["router_z"]
            else:
                h = mlp_apply(pl["mlp"], h_in, cfg.activation)
            return x + h, lb, rz, kv

        def group_body(carry, pls):
            x, lb, rz = carry
            kvs = []
            for i in range(grp):
                pl = jax.tree_util.tree_map(lambda t: t[i], pls)
                sw = win_sched[i] or None  # schedule is periodic per group
                x, lb, rz, kv = one_layer(x, lb, rz, pl, sw)
                kvs.append(kv)
            ys = ()
            if collect:
                ys = (jnp.stack([k for k, _ in kvs]),
                      jnp.stack([v for _, v in kvs]))
            return (x, lb, rz), ys

        head = jax.tree_util.tree_map(
            lambda t: t[:nsb * grp].reshape(nsb, grp, *t.shape[1:]),
            p["layers"])
        lb0, rz0 = ZERO_AUX()
        (x, lb, rz), ys = jax.lax.scan(jax.checkpoint(group_body),
                                       (x, lb0, rz0), head)
        tail_kvs = []
        for j in range(tail):  # remainder layers (26 = 4*6 + 2 for gemma3)
            li = nsb * grp + j
            pl = jax.tree_util.tree_map(lambda t: t[li], p["layers"])
            x, lb, rz, kv = one_layer(x, lb, rz, pl, win_sched[li] or None)
            tail_kvs.append(kv)
        L = cfg.n_layers
        collected = None
        if collect:
            k_all = ys[0].reshape(nsb * grp, *ys[0].shape[2:])
            v_all = ys[1].reshape(nsb * grp, *ys[1].shape[2:])
            if tail_kvs:
                k_all = jnp.concatenate(
                    [k_all, jnp.stack([k for k, _ in tail_kvs])])
                v_all = jnp.concatenate(
                    [v_all, jnp.stack([v for _, v in tail_kvs])])
            collected = ((k_all, v_all), ())
        return x, (lb / L, rz / L), collected

    def _sp(x):
        """Megatron-SP (§Perf): keep the residual stream sequence-sharded
        over 'model' between blocks; XLA then emits reduce-scatter before
        the (sharded) norm/residual and all-gather after — same ring wire
        bytes as the all-reduce but norms/adds touch 1/tp of the bytes."""
        if not flags.seq_sharded_residual:
            return x
        from repro.dist.sharding import constrain
        return constrain(x, ("batch", "seq_model", None))

    def body(carry, xs):
        x, lb, rz = carry
        pl, window = xs
        x = _sp(x)
        h_in = apply_norm(cfg.norm, x, pl["norm1"])
        if cfg.mla is not None:
            h, kv = attn.mla_forward(pl["attn"], h_in, cos, sin, cfg=cfg)
        else:
            h, kv = attn.gqa_forward(pl["attn"], h_in, cos, sin, cfg=cfg,
                                     causal=True, window=window)
        x = x + h
        xkv = ()
        if xa is not None:
            h, xkv = attn.gqa_forward(pl["xattn"],
                                      apply_norm(cfg.norm, x, pl["norm_x"]),
                                      None, None, cfg=cfg, causal=False,
                                      xa=xa)
            x = x + h
        h_in = apply_norm(cfg.norm, x, pl["norm2"])
        if "moe" in pl:
            h, aux = moe_mod.moe_apply(pl["moe"], h_in, cfg)
            lb, rz = lb + aux["load_balance"], rz + aux["router_z"]
        else:
            h = mlp_apply(pl["mlp"], h_in, cfg.activation)
        return (x + h, lb, rz), ((kv, xkv) if collect else ())

    lb0, rz0 = ZERO_AUX()
    (x, lb, rz), ys = jax.lax.scan(jax.checkpoint(body), (x, lb0, rz0),
                                   (p["layers"], windows))
    L = cfg.n_layers
    return x, (lb / L, rz / L), (ys if collect else None)


# ---------------------------------------------------------------------------
# train


def _prepare_inputs(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    xa = None
    if cfg.family == "encdec":
        xa = _encoder_forward(cfg, params, batch["frames"])
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x, xa


def train_forward(cfg: ModelConfig, params, batch):
    x, xa = _prepare_inputs(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    x, (lb, rz), _ = _backbone(cfg, params, x, positions, xa=xa)
    if cfg.family == "vlm":
        x = x[:, cfg.n_patches:]
    return _logits(cfg, params, x), {"load_balance": lb, "router_z": rz}


def loss_fn(cfg: ModelConfig, params, batch):
    logits, aux = train_forward(cfg, params, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None],
                                      axis=-1)[..., 0]
    nll = jnp.mean(logz - label_logit)
    zloss = 1e-4 * jnp.mean(logz ** 2)
    moe_loss = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        moe_loss = (cfg.moe.aux_loss_coef * aux["load_balance"]
                    + cfg.moe.router_z_coef * aux["router_z"])
    loss = nll + zloss + moe_loss
    metrics = {"loss": loss, "nll": nll, "zloss": zloss, "moe_loss": moe_loss}
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Returns (cache, logical-axes tree)."""
    L, hd = cfg.n_layers, cfg.resolved_head_dim
    Hkv = cfg.n_kv_heads
    c: Dict[str, Any] = {}

    def kv_leaf(n_layers, seq, axes_seq="kv_seq"):
        # "head_dim" is shardable as the MQA fallback (see dist.sharding)
        return Leaf(jnp.zeros((n_layers, batch, Hkv, seq, hd), jnp.bfloat16),
                    ("layers", "batch", "kv_heads", axes_seq, "head_dim"))

    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        conv_dim = d_in if s.version == 1 else d_in + 2 * s.d_state
        c["conv"] = Leaf(jnp.zeros((L, batch, conv_dim, s.d_conv - 1),
                                   jnp.bfloat16),
                         ("layers", "batch", "d_inner", None))
        if s.version == 1:
            c["ssm"] = Leaf(jnp.zeros((L, batch, d_in, s.d_state),
                                      jnp.float32),
                            ("layers", "batch", "d_inner", None))
        else:
            c["ssm"] = Leaf(jnp.zeros((L, batch, s.n_heads, s.head_dim,
                                       s.d_state), jnp.float32),
                            ("layers", "batch", "ssm_heads", None, None))
    elif cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nsb = L // cfg.hybrid_attn_every
        conv_dim = d_in + 2 * s.d_state
        c["conv"] = Leaf(jnp.zeros((L, batch, conv_dim, s.d_conv - 1),
                                   jnp.bfloat16),
                         ("layers", "batch", "d_inner", None))
        c["ssm"] = Leaf(jnp.zeros((L, batch, s.n_heads, s.head_dim,
                                   s.d_state), jnp.float32),
                        ("layers", "batch", "ssm_heads", None, None))
        c["k"] = kv_leaf(nsb, max_seq)
        c["v"] = kv_leaf(nsb, max_seq)
    elif cfg.mla is not None:
        m = cfg.mla
        c["ckv"] = Leaf(jnp.zeros((L, batch, max_seq, m.kv_lora_rank),
                                  jnp.bfloat16),
                        ("layers", "batch", "kv_seq", "kv_lora"))
        c["krope"] = Leaf(jnp.zeros((L, batch, max_seq, m.qk_rope_dim),
                                    jnp.bfloat16),
                          ("layers", "batch", "kv_seq", None))
    else:
        c["k"] = kv_leaf(L, max_seq)
        c["v"] = kv_leaf(L, max_seq)
        if cfg.family == "encdec":
            c["xk"] = kv_leaf(L, cfg.encoder.n_ctx, axes_seq=None)
            c["xv"] = kv_leaf(L, cfg.encoder.n_ctx, axes_seq=None)
    return split_leaves(c)


def prefill_forward(cfg: ModelConfig, params, batch,
                    max_seq: Optional[int] = None):
    """Runs the full prompt, returns (last-token logits, filled cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    prompt_len = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    max_seq = max(max_seq or prompt_len, prompt_len)
    x, xa = _prepare_inputs(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    x, _, collected = _backbone(cfg, params, x, positions, xa=xa,
                                collect=True)
    cache, _ = init_cache(cfg, B, max_seq)

    if cfg.family == "ssm":
        cache["conv"] = collected["conv"].astype(cache["conv"].dtype)
        cache["ssm"] = collected["ssm"]
    elif cfg.family == "hybrid":
        sts, kv = collected
        cache["conv"] = sts["conv"].reshape(cache["conv"].shape).astype(
            cache["conv"].dtype)
        cache["ssm"] = sts["ssm"].reshape(cache["ssm"].shape)
        k, v = kv
        cache["k"] = _fill_kv(cache["k"], k)
        cache["v"] = _fill_kv(cache["v"], v)
    elif cfg.mla is not None:
        kv, _ = collected
        ckv, krope = kv                                # (L,B,S,·)
        cache["ckv"] = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0, 0))
        cache["krope"] = jax.lax.dynamic_update_slice(
            cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0, 0))
    else:
        kv, xkv = collected
        cache["k"] = _fill_kv(cache["k"], kv[0])
        cache["v"] = _fill_kv(cache["v"], kv[1])
        if cfg.family == "encdec":
            cache["xk"] = xkv[0].astype(cache["xk"].dtype)
            cache["xv"] = xkv[1].astype(cache["xv"].dtype)
    if cfg.family == "vlm":
        pass  # note: patch prefix occupies cache positions [0, n_patches)
    logits = _logits(cfg, params, x[:, -1:])
    return logits, cache


def _fill_kv(cache_kv, new):
    return jax.lax.dynamic_update_slice(
        cache_kv, new.astype(cache_kv.dtype), (0,) * cache_kv.ndim)


# ---------------------------------------------------------------------------
# decode


def decode_forward(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step.  tokens: (B, 1); pos: scalar position (traced ok).
    Returns (logits (B, 1, V), new cache)."""
    x = _embed_tokens_decode(cfg, params, tokens, pos)
    positions = jnp.full((1,), pos)
    cos, sin = _rope_for(cfg, positions)

    if cfg.family == "ssm":
        dec = (ssm_mod.mamba1_decode if cfg.ssm.version == 1
               else ssm_mod.mamba2_decode)

        def body(x, xs):
            pl, conv, st = xs
            h, new = dec(pl["ssm"], apply_norm(cfg.norm, x, pl["norm1"]),
                         {"conv": conv, "ssm": st}, cfg)
            return x + h, (new["conv"], new["ssm"])
        x, (conv, st) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        return _logits(cfg, params, x), dict(cache, conv=conv, ssm=st)

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        nsb = cfg.n_layers // k
        shared = params["shared_attn"]

        def superblock(x, xs):
            pls, conv, st, ck, cv = xs

            def mamba_body(x, ys):
                pl, conv_i, st_i = ys
                h, new = ssm_mod.mamba2_decode(
                    pl["ssm"], apply_norm(cfg.norm, x, pl["norm1"]),
                    {"conv": conv_i, "ssm": st_i}, cfg)
                return x + h, (new["conv"], new["ssm"])
            x, (conv, st) = jax.lax.scan(mamba_body, x, (pls, conv, st))
            h, ck, cv = attn.gqa_decode(
                shared["attn"], apply_norm(cfg.norm, x, shared["norm1"]),
                ck, cv, cos, sin, cfg=cfg, pos=pos)
            x = x + h
            h = mlp_apply(shared["mlp"],
                          apply_norm(cfg.norm, x, shared["norm2"]),
                          cfg.activation)
            return x + h, (conv, st, ck, cv)

        pls = jax.tree_util.tree_map(
            lambda t: t.reshape(nsb, k, *t.shape[1:]), params["layers"])
        conv = cache["conv"].reshape(nsb, k, *cache["conv"].shape[1:])
        st = cache["ssm"].reshape(nsb, k, *cache["ssm"].shape[1:])
        x, (conv, st, ck, cv) = jax.lax.scan(
            superblock, x, (pls, conv, st, cache["k"], cache["v"]))
        cache = dict(cache, conv=conv.reshape(cache["conv"].shape),
                     ssm=st.reshape(cache["ssm"].shape), k=ck, v=cv)
        return _logits(cfg, params, x), cache

    windows = _window_schedule(cfg)

    # §Perf: unrolled decode for local:global archs — local layers read an
    # O(window) cache SLICE instead of sweeping the full S-long cache
    from repro.dist import context as _dctx
    if (_dctx.perf_flags().windowed_attention and cfg.mla is None
            and cfg.family != "encdec" and cfg.local_global_ratio > 0
            and cfg.window > 0):
        grp = cfg.local_global_ratio + 1
        win_sched = [0 if (i + 1) % grp == 0 else cfg.window
                     for i in range(cfg.n_layers)]
        cks, cvs = [], []
        for li in range(cfg.n_layers):
            pl = jax.tree_util.tree_map(lambda t: t[li], params["layers"])
            h, ck, cv = attn.gqa_decode(
                pl["attn"], apply_norm(cfg.norm, x, pl["norm1"]),
                cache["k"][li], cache["v"][li], cos, sin, cfg=cfg, pos=pos,
                static_window=win_sched[li] or None)
            x = x + h
            h_in = apply_norm(cfg.norm, x, pl["norm2"])
            if "moe" in pl:
                h, _ = moe_mod.moe_apply(pl["moe"], h_in, cfg)
            else:
                h = mlp_apply(pl["mlp"], h_in, cfg.activation)
            x = x + h
            cks.append(ck)
            cvs.append(cv)
        cache = dict(cache, k=jnp.stack(cks), v=jnp.stack(cvs))
        return _logits(cfg, params, x), cache

    if cfg.mla is not None:
        def body(x, xs):
            pl, ckv, krope, _w = xs
            h, ckv, krope = attn.mla_decode(
                pl["attn"], apply_norm(cfg.norm, x, pl["norm1"]),
                ckv, krope, cos, sin, cfg=cfg, pos=pos)
            x = x + h
            h_in = apply_norm(cfg.norm, x, pl["norm2"])
            if "moe" in pl:
                h, _ = moe_mod.moe_apply(pl["moe"], h_in, cfg)
            else:
                h = mlp_apply(pl["mlp"], h_in, cfg.activation)
            return x + h, (ckv, krope)
        x, (ckv, krope) = jax.lax.scan(
            body, x, (params["layers"], cache["ckv"], cache["krope"],
                      windows))
        return _logits(cfg, params, x), dict(cache, ckv=ckv, krope=krope)

    is_encdec = cfg.family == "encdec"

    def body(x, xs):
        if is_encdec:
            pl, ck, cv, window, xk, xv = xs
        else:
            pl, ck, cv, window = xs
        h, ck, cv = attn.gqa_decode(
            pl["attn"], apply_norm(cfg.norm, x, pl["norm1"]),
            ck, cv, cos, sin, cfg=cfg, pos=pos, window=window)
        x = x + h
        if is_encdec:
            h, _, _ = attn.gqa_decode(
                pl["xattn"], apply_norm(cfg.norm, x, pl["norm_x"]),
                None, None, None, None, cfg=cfg, pos=pos, xa_kv=(xk, xv))
            x = x + h
        h_in = apply_norm(cfg.norm, x, pl["norm2"])
        if "moe" in pl:
            h, _ = moe_mod.moe_apply(pl["moe"], h_in, cfg)
        else:
            h = mlp_apply(pl["mlp"], h_in, cfg.activation)
        return x + h, (ck, cv)

    if is_encdec:
        xs = (params["layers"], cache["k"], cache["v"], windows,
              cache["xk"], cache["xv"])
    else:
        xs = (params["layers"], cache["k"], cache["v"], windows)
    x, (ck, cv) = jax.lax.scan(body, x, xs)
    return _logits(cfg, params, x), dict(cache, k=ck, v=cv)


def _embed_tokens_decode(cfg: ModelConfig, p, tokens, pos):
    x = p["embed"][tokens]
    if cfg.family == "encdec":
        x = x + jax.lax.dynamic_slice_in_dim(p["pos"], pos, 1, 0)[None]
    if cfg.family in ("dense", "vlm", "moe") and cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)
    return x.astype(jnp.bfloat16)
