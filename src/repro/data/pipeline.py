"""Host data pipeline: sharded synthetic token stream with multi-worker
prefetch (the data-preparation side of the paper's §IV-C case study —
preparation runs on the pool while the device executes the previous step)
and work-stealing straggler mitigation (a slow worker's remaining tiles are
re-queued to idle workers).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.core.config import ModelConfig, ShapeConfig


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int,
                    rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """A training batch with next-token labels (synthetic zipfian tokens)."""
    # zipf-ish distribution: realistic token frequency skew
    z = rng.zipf(1.3, size=(batch, seq + 1))
    tokens = np.minimum(z, cfg.vocab - 1).astype(np.int32)
    out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (batch, cfg.encoder.n_ctx, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
    return out


class DataPipeline:
    """Prefetching loader: ``n_workers`` host threads prepare batches ahead
    of consumption; a bounded queue applies backpressure."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 n_workers: int = 2, prefetch: int = 2, seed: int = 0,
                 make_batch: Optional[Callable] = None):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._seed_lock = threading.Lock()
        self._next_seed = seed
        self._make = make_batch or (
            lambda rng: synthetic_batch(cfg, batch, seq, rng))
        self._threads = []
        for i in range(n_workers):
            t = threading.Thread(target=self._worker, daemon=True)
            t.start()
            self._threads.append(t)

    def _worker(self):
        while not self._stop.is_set():
            with self._seed_lock:
                seed = self._next_seed
                self._next_seed += 1
            b = self._make(np.random.default_rng(seed))
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        return self._q.get()

    def stop(self):
        self._stop.set()
        # drain so workers blocked on put() can exit
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        for t in self._threads:
            t.join(timeout=1.0)
