"""NVDLA-dataflow-adapted tiled matmul (Pallas, TPU target).

The NVDLA convolution engine (paper Fig 4) reduces partial products along the
CHANNEL dimension in 32-wide MACC blocks, with weights register-resident (L0
weight-stationary) and outputs accumulated in place in SRAM (L1 output-
stationary).  TPU adaptation (DESIGN.md §2): the channel dimension becomes
the contraction (K) dimension of a blocked matmul on the 128x128 MXU:

  grid = (M/bm, N/bn, K/bk)      k innermost — NVDLA's channel-block loop
  A/B tiles staged HBM->VMEM via BlockSpec
  fp32 accumulator in VMEM scratch — "outputs accumulated in-place in SRAM"
  out tile written once on the last k step

Block shapes come from the tiling optimizer (repro.core.tiling.
choose_matmul_tiling), which plays the role of SMAUG's per-dataflow tiling
optimizer for this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU contraction over this channel block (fp32 accumulate)
    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a, b, *, bm: int = 0, bn: int = 0, bk: int = 0,
           interpret: bool = False):
    """a: (M, K) @ b: (K, N) -> (M, N).  Block shapes default to the tiling
    optimizer's choice."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    if not (bm and bn and bk):
        from repro.core.tiling import choose_matmul_tiling
        t = choose_matmul_tiling(M, N, K, dtype_bytes=a.dtype.itemsize)
        bm, bn, bk = t.bm, t.bn, t.bk
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
