"""Flash attention (Pallas, TPU target) — the fused realization of
repro.models.attention.chunked_attention's dataflow.

The XLA-lowered chunked attention materializes ~10 elementwise passes of the
(Sq, chunk) score tile through HBM per KV chunk (measured in the dry-run —
see EXPERIMENTS.md §Perf); this kernel keeps scores, running max/sum and the
output accumulator in VMEM across the KV-block loop: grid =
(B*H, Sq/bq, Skv/bk) with k innermost, online softmax in scratch.

Supports causal masking and sliding windows (gemma3 local layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_k: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # skip fully-masked blocks (causal: kv block entirely after q block)
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (bq, D)
        k = k_ref[0].astype(jnp.float32)                   # (bk, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.iota(jnp.int32, bq)[:, None]
        kpos = k_start + jax.lax.iota(jnp.int32, bk)[None, :]
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 256, interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) with ``H % Hkv == 0``.

    GQA/MQA (``Hkv < H``) is handled *inside* the kernel: the KV block
    index maps resolve each query head's group head, so KV stays at its
    native ``(B, Hkv, S, D)`` — no broadcast materialization, and the
    kernel's operand traffic matches the model's GQA byte accounting.
    Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_k = S // bk
    grid = (B * H, S // bq, n_k)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * Hkv, S, D)
    vf = v.reshape(B * Hkv, S, D)

    def kv_index(h, i, j):
        # flat q-head h = b*H + hq maps to KV row b*Hkv + hq//G
        # (identity when G == 1: (h//H)*H + h%H == h)
        return ((h // H) * Hkv + (h % H) // G, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=n_k,
                          causal=causal, window=window, scale=D ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
