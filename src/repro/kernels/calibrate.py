"""Measured-vs-modeled calibration against the real Pallas kernels.

Closes the loop the paper's gem5 integration stands for: time the actual
JAX/Pallas kernels in ``repro/kernels/`` (``nvdla_matmul``,
``flash_attention``, ``mamba_scan``) across a shape grid with
timeit-style best-of-k, then fit cost-backend parameters by least
squares (:func:`repro.sim.backends.fit_linear_cost`) and build a
measured :class:`repro.sim.backends.TableBackend`.

On this CPU container the kernels run with ``interpret=True`` — the
measured times are Python-interpreter magnitudes, wildly off the TPU
roofline constants, which is exactly the point: the uncalibrated
roofline error is enormous and the fitted error is small, and the same
harness dropped onto a real TPU records ``backend="tpu"`` with honest
Mosaic timings.  Every record carries the JAX backend it was measured
on.

Used by ``tools/calibrate.py`` (CLI) and
``benchmarks/bench_calibration.py`` (the CI-gated artifact writer).
"""
from __future__ import annotations

import math
from time import perf_counter
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim import backends as sim_backends
from repro.sim import hw

BYTES = 4  # kernels are measured in fp32

# Shape grids.  Each kernel's shapes carry pairwise-distinct flop counts
# on purpose: the measured TableBackend keys its exact round-trip on
# (kind, flops), so two shapes with equal flops but different runtimes
# would make "reproduce your own samples" unsatisfiable.
# (M, N, K) matmul grid
MATMUL_GRID: Tuple[Tuple[int, int, int], ...] = (
    (128, 128, 128), (256, 128, 128), (256, 256, 128),
    (256, 256, 256), (512, 256, 256), (512, 512, 256))
# (B, H, Hkv, S, D) attention grid (GQA rows keep KV at Hkv heads)
ATTENTION_GRID: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 2, 2, 128, 32), (2, 2, 2, 128, 32), (1, 4, 2, 128, 64),
    (1, 2, 1, 256, 64), (2, 4, 2, 256, 32))
# (b, S, d, N) selective-scan grid
MAMBA_GRID: Tuple[Tuple[int, int, int, int], ...] = (
    (1, 32, 16, 8), (1, 64, 32, 8), (2, 64, 32, 16), (1, 128, 64, 16))

QUICK_GRIDS = {"matmul": MATMUL_GRID[:2], "attention": ATTENTION_GRID[:2],
               "mamba": MAMBA_GRID[:2]}
FULL_GRIDS = {"matmul": MATMUL_GRID, "attention": ATTENTION_GRID,
              "mamba": MAMBA_GRID}
KERNELS = tuple(FULL_GRIDS)


# ---------------------------------------------------------------------------
# analytic accounting: nominal (flops, bytes) per kernel invocation.
# Attention bytes charge KV at its native Hkv heads — the kernel indexes
# KV by group instead of materializing the broadcast, so measured and
# modeled traffic compare like with like.


def matmul_cost(M: int, N: int, K: int) -> Tuple[float, float]:
    return 2.0 * M * N * K, float(BYTES * (M * K + K * N + M * N))


def attention_cost(B: int, H: int, Hkv: int, S: int, D: int,
                   causal: bool = True) -> Tuple[float, float]:
    flops = 4.0 * B * H * S * S * D * (0.5 if causal else 1.0)
    bytes_ = BYTES * (2.0 * B * H * S * D + 2.0 * B * Hkv * S * D)
    return flops, bytes_


def mamba_cost(b: int, S: int, d: int, N: int) -> Tuple[float, float]:
    flops = 10.0 * b * S * d * N
    bytes_ = BYTES * (3.0 * b * S * d + 2.0 * b * S * N + d * N + d)
    return flops, bytes_


# ---------------------------------------------------------------------------
# measurement


def _best_of(fn, repeat: int) -> float:
    fn()                                    # warmup (jit/interpret trace)
    best = math.inf
    for _ in range(max(repeat, 1)):
        t0 = perf_counter()
        fn()
        best = min(best, perf_counter() - t0)
    return best


def _measure_kernel(kernel: str, shape: Sequence[int],
                    repeat: int) -> Dict:
    import jax
    from repro.kernels import ops

    rng = np.random.default_rng(hash((kernel,) + tuple(shape)) % 2**32)

    def rand(*s):
        return jax.numpy.asarray(
            rng.standard_normal(s).astype(np.float32))

    if kernel == "matmul":
        M, N, K = shape
        a, b = rand(M, K), rand(K, N)
        flops, bytes_ = matmul_cost(M, N, K)
        fn = lambda: ops.matmul(a, b).block_until_ready()  # noqa: E731
    elif kernel == "attention":
        B, H, Hkv, S, D = shape
        q = rand(B, H, S, D)
        k, v = rand(B, Hkv, S, D), rand(B, Hkv, S, D)
        flops, bytes_ = attention_cost(B, H, Hkv, S, D)
        fn = lambda: ops.flash_attention(  # noqa: E731
            q, k, v, bq=64, bk=64).block_until_ready()
    elif kernel == "mamba":
        b, S, d, N = shape
        x, dt = rand(b, S, d), rand(b, S, d)
        Bm, C = rand(b, S, N), rand(b, S, N)
        A, D = -jax.numpy.abs(rand(d, N)), rand(d)
        flops, bytes_ = mamba_cost(b, S, d, N)
        fn = lambda: ops.mamba_scan(  # noqa: E731
            x, dt, Bm, C, A, D).block_until_ready()
    else:
        raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")
    return {"kernel": kernel, "kind": kernel, "shape": list(shape),
            "flops": flops, "bytes": bytes_,
            "measured_s": _best_of(fn, repeat)}


def measure(grid: str = "full", repeat: int = 3,
            kernels: Sequence[str] = KERNELS) -> Tuple[List[Dict], Dict]:
    """Time the Pallas kernels over the named shape grid.

    Returns ``(records, meta)``: per-shape records with the analytic
    (flops, bytes) accounting and best-of-``repeat`` seconds, plus meta
    naming the JAX backend and interpret mode the samples came from."""
    import jax
    grids = QUICK_GRIDS if grid == "quick" else FULL_GRIDS
    records = [_measure_kernel(kernel, shape, repeat)
               for kernel in kernels for shape in grids[kernel]]
    backend = jax.default_backend()
    return records, {"backend": backend, "interpret": backend != "tpu",
                     "grid": grid, "repeat": repeat}


# ---------------------------------------------------------------------------
# fitting + error reporting


def roofline_pred(records: Sequence[Dict],
                  peak_flops: float = hw.PEAK_FLOPS,
                  hbm_bw: float = hw.HBM_BW) -> np.ndarray:
    """The uncalibrated roofline prediction at the canonical hardware
    constants: ``flops/peak + bytes/bw`` per record."""
    f = np.array([r["flops"] for r in records])
    b = np.array([r["bytes"] for r in records])
    return f / peak_flops + b / hbm_bw


def calibrate(records: Sequence[Dict]) -> Dict[str, Dict]:
    """Per-kernel least-squares fit + error summary.

    For each kernel: the fitted effective (peak, bandwidth, overhead)
    from :func:`repro.sim.backends.fit_linear_cost`, the fitted MAPE,
    the uncalibrated-roofline MAPE, and the measured-table round-trip
    error (0 by construction — asserted, not assumed)."""
    out: Dict[str, Dict] = {}
    for kernel in {r["kernel"] for r in records}:
        rs = [r for r in records if r["kernel"] == kernel]
        meas = np.array([r["measured_s"] for r in rs])
        fit = sim_backends.fit_linear_cost(
            [r["flops"] for r in rs], [r["bytes"] for r in rs], meas)
        roof = roofline_pred(rs)
        table = sim_backends.table_from_samples(rs)
        t_err = max(abs(table._lookup(r["kind"], r["flops"])
                        - r["measured_s"]) / r["measured_s"] for r in rs)
        # a dropped term fits as an infinite rate — JSON-encode it as
        # null rather than the non-standard Infinity literal
        fin = lambda v: float(v) if math.isfinite(v) else None  # noqa: E731
        out[kernel] = {
            "n_samples": len(rs),
            "roofline_mape": sim_backends.mape(roof, meas),
            "fitted_mape": fit["mape"],
            "fitted": {"peak_flops_eff": fin(fit["peak_flops_eff"]),
                       "bw_eff": fin(fit["bw_eff"]),
                       "overhead_s": fin(fit["overhead_s"])},
            "table_max_rel_err": t_err,
        }
    return out


def table_backend(records: Sequence[Dict]) -> "sim_backends.TableBackend":
    """A measured-sample :class:`TableBackend` over every record — drop
    it into ``EngineConfig(cost_backend=...)`` to simulate with measured
    per-op times (the GUIDE's calibrate-then-simulate recipe)."""
    return sim_backends.table_from_samples(records)


def build_report(records: Sequence[Dict], meta: Dict,
                 fits: Optional[Dict[str, Dict]] = None) -> Dict:
    """The ``BENCH_calibration.json`` payload (sans recorded/budget)."""
    fits = calibrate(records) if fits is None else fits
    improved = sorted(k for k, f in fits.items()
                      if f["fitted_mape"] < f["roofline_mape"])
    return {
        "backend": meta["backend"], "interpret": meta["interpret"],
        "grid": meta["grid"], "repeat": meta["repeat"],
        "samples": list(records),
        "kernels": {k: fits[k] for k in sorted(fits)},
        "improved": improved,
        "n_improved": len(improved),
    }
