"""Selective-scan (Mamba1) kernel (Pallas, TPU target).

The lax.scan baseline round-trips the (d_inner, N) state through HBM every
timestep — the memory-bound term the falcon-mamba §Perf iteration attacks.
This kernel keeps the state in VMEM across a whole sequence chunk:

  grid = (B, d_inner/bd, S/chunk)    chunk innermost, sequential
  state scratch (bd, N) persists across chunk steps (VMEM-resident)
  inside a chunk: fori_loop over timesteps (VREG/VMEM only)

B/C are shared across channels (per Mamba1), A is (d, N) channel-specific.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, h_ref, *,
                 chunk: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                                    # (bd, N) fp32
    dvec = d_ref[...]                                 # (1, bd)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)         # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)       # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)         # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)         # (N,)
        da = jnp.exp(dt_t[:, None] * a)               # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=-1) + dvec[0] * x_t
        o_ref[0, t] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def mamba_scan(x, dt, B, C, A, D, *, bd: int = 0, chunk: int = 0,
               interpret: bool = False):
    """x, dt: (b, S, d); B, C: (b, S, N); A: (d, N) fp32; D: (d,) fp32.
    Returns y: (b, S, d)."""
    bsz, S, d = x.shape
    N = B.shape[-1]
    bd = min(bd or min(d, 512), d)
    chunk = min(chunk or min(S, 128), S)
    assert d % bd == 0 and S % chunk == 0, (d, bd, S, chunk)
    grid = (bsz, d // bd, S // chunk)
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, i, s: (b, s, i)),  # x
            pl.BlockSpec((1, chunk, bd), lambda b, i, s: (b, s, i)),  # dt
            pl.BlockSpec((1, chunk, N), lambda b, i, s: (b, s, 0)),   # B
            pl.BlockSpec((1, chunk, N), lambda b, i, s: (b, s, 0)),   # C
            pl.BlockSpec((bd, N), lambda b, i, s: (i, 0)),            # A
            pl.BlockSpec((1, bd), lambda b, i, s: (0, i)),            # D
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, i, s: (b, s, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, S, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A, D.reshape(1, d))
