"""Jitted public wrappers for the Pallas kernels.

On a CPU container the kernels run with interpret=True (the kernel body
executes in Python for correctness validation); on a real TPU the same
calls compile to Mosaic.  The interpret default is resolved **per call**
(not at import time): selecting a backend after this module imports, or
running under a ``jax.default_device`` override, must flip the path.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.nvdla_matmul import matmul as _matmul


def _interpret() -> bool:
    """Whether pallas_call should interpret: anything but a real TPU."""
    return jax.default_backend() != "tpu"


def matmul(a, b, **kw):
    kw.setdefault("interpret", _interpret())
    return _matmul(a, b, **kw)


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    # GQA/MQA KV stays at its native (B, Hkv, S, D): the kernel's KV
    # block index maps resolve the group head, so no broadcast is
    # materialized here and measured bytes match the model's accounting
    kw.setdefault("interpret", _interpret())
    return _flash(q, k, v, causal=causal, window=window, **kw)


def mamba_scan(x, dt, B, C, A, D, **kw):
    kw.setdefault("interpret", _interpret())
    return _mamba(x, dt, B, C, A, D, **kw)
