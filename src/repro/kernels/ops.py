"""Jitted public wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python for correctness validation); on a real TPU the same calls
compile to Mosaic.  ``INTERPRET`` flips automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.nvdla_matmul import matmul as _matmul

INTERPRET = jax.default_backend() != "tpu"


def matmul(a, b, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _matmul(a, b, **kw)


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    kw.setdefault("interpret", INTERPRET)
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:  # GQA: broadcast KV to full heads (free at the kernel edge)
        G = H // Hkv
        k = jnp.broadcast_to(k[:, :, None], (B, Hkv, G, S, D)) \
            .reshape(B, H, S, D)
        v = jnp.broadcast_to(v[:, :, None], (B, Hkv, G, S, D)) \
            .reshape(B, H, S, D)
    return _flash(q, k, v, causal=causal, window=window, **kw)


def mamba_scan(x, dt, B, C, A, D, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _mamba(x, dt, B, C, A, D, **kw)
