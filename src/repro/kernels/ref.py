"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a, b):
    """a: (M, K), b: (K, N) -> (M, N) with fp32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)) \
        .astype(a.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q,k,v: (B, H, S, D) -> (B, H, S, D).  Dense softmax reference."""
    B, H, S, D = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan_ref(x, dt, B, C, A, D):
    """Selective scan, sequential reference.

    x, dt: (b, S, d); B, C: (b, S, N); A: (d, N); D: (d,)
    Returns y: (b, S, d).
    """
    bsz, S, d = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        da = jnp.exp(dt_t[..., None] * A[None])              # (b, d, N)
        h = da * h + dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((bsz, d, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
                          B.astype(jnp.float32).transpose(1, 0, 2),
                          C.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + D[None, None] * xf
    return y.astype(x.dtype)
