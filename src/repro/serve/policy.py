"""Batching policies shared by the measured and the simulated serving path.

A policy decides *when* waiting requests are admitted into the running
batch and *when* finished requests release their slot.  The same frozen
dataclasses drive both worlds:

  * ``examples/serve_batch.py`` sizes its real JAX prefill/decode batch
    from ``policy.max_batch`` (and, with ``--simulate``, feeds the policy
    to the model instead);
  * ``repro.sim.serving.simulate_serving`` replays a request trace against
    the policy through the event engine.

The three classic points on the serving design space:

``StaticBatching``
    Admission only between batches, and only when ``max_batch`` requests
    are waiting (or the trace is exhausted).  The formed batch runs
    padded to its formed size until the *longest* request finishes —
    early finishers keep burning their slot.  This is the throughput
    baseline continuous batching is measured against.

``DynamicBatching``
    Admission only between batches, but a batch also launches when the
    oldest waiting request has waited ``max_wait_s`` (the Triton-style
    max-queue-delay knob).  Finished requests are evicted at
    end-of-output, so the live batch shrinks — no padding waste — but
    free slots stay empty until the whole batch drains.

``ContinuousBatching``
    Iteration-level scheduling (Orca-style): every model step evicts
    finished requests and admits waiting ones into the freed slots, with
    the newcomers' prefill interleaved into the same step.  Slots never
    idle while work is queued.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, Dict, Type


@dataclass(frozen=True)
class BatchingPolicy:
    """Base policy: at most ``max_batch`` requests share the model batch."""
    max_batch: int = 8
    kind: ClassVar[str] = "base"

    def ready(self, n_waiting: int, oldest_wait_s: float,
              trace_done: bool) -> bool:
        """Whether a new batch may launch *between* batches (the live batch
        has fully drained).  Continuous batching never waits for this —
        it admits into free slots every step instead."""
        raise NotImplementedError

    def launch_deadline_s(self, oldest_arrival_s: float) -> float:
        """Absolute time by which a waiting batch must launch even if it
        is not full (``inf`` = wait for a full batch forever)."""
        return float("inf")


@dataclass(frozen=True)
class StaticBatching(BatchingPolicy):
    kind: ClassVar[str] = "static"

    def ready(self, n_waiting, oldest_wait_s, trace_done):
        return n_waiting >= self.max_batch or (trace_done and n_waiting > 0)


@dataclass(frozen=True)
class DynamicBatching(BatchingPolicy):
    """Static admission plus a max-wait escape hatch."""
    max_wait_s: float = 0.010
    kind: ClassVar[str] = "dynamic"

    def ready(self, n_waiting, oldest_wait_s, trace_done):
        if n_waiting <= 0:
            return False
        return (n_waiting >= self.max_batch or trace_done
                or oldest_wait_s >= self.max_wait_s)

    def launch_deadline_s(self, oldest_arrival_s):
        return oldest_arrival_s + self.max_wait_s


@dataclass(frozen=True)
class ContinuousBatching(BatchingPolicy):
    kind: ClassVar[str] = "continuous"

    def ready(self, n_waiting, oldest_wait_s, trace_done):
        return n_waiting > 0          # any waiting request fills a free slot


POLICIES: Dict[str, Type[BatchingPolicy]] = {
    "static": StaticBatching,
    "dynamic": DynamicBatching,
    "continuous": ContinuousBatching,
}


def get_policy(name: str, **kwargs) -> BatchingPolicy:
    """Policy by name (``static`` | ``dynamic`` | ``continuous``) with
    field overrides, e.g. ``get_policy("dynamic", max_batch=16,
    max_wait_s=0.005)``."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown batching policy {name!r}; "
                       f"one of {sorted(POLICIES)}") from None
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# fleet-level policies: a router spreads a trace across N replica
# schedulers (each running a batching policy above), an autoscaler moves N


@dataclass(frozen=True)
class RouterPolicy:
    """Base router: pick a replica for each arriving request.

    ``route`` returns an index into the *active* replica list.  Routers
    with ``stateful = True`` need every replica's live queue depth at the
    arrival instant, so the fleet driver drains all replicas up to each
    arrival before routing (slower but still O(steps)); stateless routers
    let the driver drain lazily, one replica at a time.
    """
    kind: ClassVar[str] = "base"
    stateful: ClassVar[bool] = False

    def route(self, rid: int, seq: int, outstanding) -> int:
        """Replica index for request ``rid``.  ``seq`` is the 0-based
        arrival ordinal, ``outstanding`` the per-active-replica count of
        queued + in-flight requests (empty for stateless routers)."""
        raise NotImplementedError


@dataclass(frozen=True)
class RoundRobin(RouterPolicy):
    """Arrival k goes to replica k mod N — the stateless baseline."""
    kind: ClassVar[str] = "round_robin"

    def route(self, rid, seq, outstanding):
        return seq


@dataclass(frozen=True)
class LeastOutstanding(RouterPolicy):
    """Join-the-shortest-queue: the replica with the fewest queued +
    in-flight requests at the arrival instant (ties to the lowest
    index).  Needs live depths, hence stateful."""
    kind: ClassVar[str] = "least_outstanding"
    stateful: ClassVar[bool] = True

    def route(self, rid, seq, outstanding):
        return min(range(len(outstanding)), key=outstanding.__getitem__)


@dataclass(frozen=True)
class SessionAffinity(RouterPolicy):
    """Deterministic hash of the request id (Knuth multiplicative), so a
    session's requests always land on the same replica — the sticky
    routing KV-cache reuse wants."""
    kind: ClassVar[str] = "session_affinity"

    def route(self, rid, seq, outstanding):
        return (rid * 2654435761) >> 12


ROUTERS: Dict[str, Type[RouterPolicy]] = {
    "round_robin": RoundRobin,
    "least_outstanding": LeastOutstanding,
    "session_affinity": SessionAffinity,
}


def get_router(name: str, **kwargs) -> RouterPolicy:
    """Router by name (``round_robin`` | ``least_outstanding`` |
    ``session_affinity``)."""
    try:
        cls = ROUTERS[name]
    except KeyError:
        raise KeyError(f"unknown router policy {name!r}; "
                       f"one of {sorted(ROUTERS)}") from None
    return cls(**kwargs)


@dataclass(frozen=True)
class QueueDepthAutoscaler:
    """Queue-depth autoscaling: at each arrival, compare the mean
    outstanding requests per active replica against the scale-up /
    scale-down thresholds, honoring a cooldown between actions.  The
    fleet driver spawns a fresh replica on +1 and retires (drains, no new
    routes) the emptiest replica on -1."""
    min_replicas: int = 1
    max_replicas: int = 8
    scale_up_depth: float = 16.0
    scale_down_depth: float = 2.0
    cooldown_s: float = 1.0

    def decide(self, n_active: int, mean_depth: float, t_s: float,
               last_change_s: float) -> int:
        """-1 / 0 / +1 replica delta at arrival time ``t_s``."""
        if t_s - last_change_s < self.cooldown_s:
            return 0
        if mean_depth >= self.scale_up_depth \
                and n_active < self.max_replicas:
            return 1
        if mean_depth <= self.scale_down_depth \
                and n_active > self.min_replicas:
            return -1
        return 0
