"""Batching policies shared by the measured and the simulated serving path.

A policy decides *when* waiting requests are admitted into the running
batch and *when* finished requests release their slot.  The same frozen
dataclasses drive both worlds:

  * ``examples/serve_batch.py`` sizes its real JAX prefill/decode batch
    from ``policy.max_batch`` (and, with ``--simulate``, feeds the policy
    to the model instead);
  * ``repro.sim.serving.simulate_serving`` replays a request trace against
    the policy through the event engine.

The three classic points on the serving design space:

``StaticBatching``
    Admission only between batches, and only when ``max_batch`` requests
    are waiting (or the trace is exhausted).  The formed batch runs
    padded to its formed size until the *longest* request finishes —
    early finishers keep burning their slot.  This is the throughput
    baseline continuous batching is measured against.

``DynamicBatching``
    Admission only between batches, but a batch also launches when the
    oldest waiting request has waited ``max_wait_s`` (the Triton-style
    max-queue-delay knob).  Finished requests are evicted at
    end-of-output, so the live batch shrinks — no padding waste — but
    free slots stay empty until the whole batch drains.

``ContinuousBatching``
    Iteration-level scheduling (Orca-style): every model step evicts
    finished requests and admits waiting ones into the freed slots, with
    the newcomers' prefill interleaved into the same step.  Slots never
    idle while work is queued.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, Dict, Type


@dataclass(frozen=True)
class BatchingPolicy:
    """Base policy: at most ``max_batch`` requests share the model batch."""
    max_batch: int = 8
    kind: ClassVar[str] = "base"

    def ready(self, n_waiting: int, oldest_wait_s: float,
              trace_done: bool) -> bool:
        """Whether a new batch may launch *between* batches (the live batch
        has fully drained).  Continuous batching never waits for this —
        it admits into free slots every step instead."""
        raise NotImplementedError

    def launch_deadline_s(self, oldest_arrival_s: float) -> float:
        """Absolute time by which a waiting batch must launch even if it
        is not full (``inf`` = wait for a full batch forever)."""
        return float("inf")


@dataclass(frozen=True)
class StaticBatching(BatchingPolicy):
    kind: ClassVar[str] = "static"

    def ready(self, n_waiting, oldest_wait_s, trace_done):
        return n_waiting >= self.max_batch or (trace_done and n_waiting > 0)


@dataclass(frozen=True)
class DynamicBatching(BatchingPolicy):
    """Static admission plus a max-wait escape hatch."""
    max_wait_s: float = 0.010
    kind: ClassVar[str] = "dynamic"

    def ready(self, n_waiting, oldest_wait_s, trace_done):
        if n_waiting <= 0:
            return False
        return (n_waiting >= self.max_batch or trace_done
                or oldest_wait_s >= self.max_wait_s)

    def launch_deadline_s(self, oldest_arrival_s):
        return oldest_arrival_s + self.max_wait_s


@dataclass(frozen=True)
class ContinuousBatching(BatchingPolicy):
    kind: ClassVar[str] = "continuous"

    def ready(self, n_waiting, oldest_wait_s, trace_done):
        return n_waiting > 0          # any waiting request fills a free slot


POLICIES: Dict[str, Type[BatchingPolicy]] = {
    "static": StaticBatching,
    "dynamic": DynamicBatching,
    "continuous": ContinuousBatching,
}


def get_policy(name: str, **kwargs) -> BatchingPolicy:
    """Policy by name (``static`` | ``dynamic`` | ``continuous``) with
    field overrides, e.g. ``get_policy("dynamic", max_batch=16,
    max_wait_s=0.005)``."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown batching policy {name!r}; "
                       f"one of {sorted(POLICIES)}") from None
    return cls(**kwargs)
