"""Serving layer: real JAX prefill/decode steps (``repro.serve.step``) and
the batching policies (``repro.serve.policy``) shared with the simulated
serving scenario in ``repro.sim.serving``.

The step factories are re-exported lazily: ``repro.serve.step`` imports
JAX and the model stack, while the policy dataclasses are dependency-free
— the simulator must be able to import them without paying for (or even
having) JAX.
"""
from repro.serve.policy import (BatchingPolicy, ContinuousBatching,  # noqa: F401,E501
                                DynamicBatching, StaticBatching, get_policy)

_STEP_EXPORTS = ("make_decode_step", "make_prefill_step")


def __getattr__(name):
    if name in _STEP_EXPORTS:
        from repro.serve import step
        return getattr(step, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
