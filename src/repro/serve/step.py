"""Serving steps: batched prefill and single-token decode with a sharded
KV / state cache.  ``serve_step`` for the dry-run decode shapes = one
decode_forward call (one new token against a seq_len cache).

Batch sizing and admission semantics live in ``repro.serve.policy`` —
the same ``BatchingPolicy`` dataclasses drive this real JAX path (see
``examples/serve_batch.py``) and the trace-driven simulator
(``repro.sim.serving``), so measured and modeled serving agree on what
"static" / "dynamic" / "continuous" batching means.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import transformer as T


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        return T.prefill_forward(cfg, params, batch, max_seq=max_seq)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, greedy: bool = True):
    def decode_step(params, cache, tokens, pos):
        logits, cache = T.decode_forward(cfg, params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache
    return decode_step
