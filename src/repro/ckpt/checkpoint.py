"""Fault-tolerant checkpointing.

Design for 1000+-node operation (DESIGN.md §4):
  * checkpoints are MESH-AGNOSTIC: leaves are saved as full logical arrays
    (npz shards per leaf-group), so restore can reshard onto ANY divisible
    mesh — elastic scaling after node loss;
  * atomic commit: write to <dir>.tmp, fsync, rename — a crash mid-save
    never corrupts the latest checkpoint;
  * async save: the device->host gather happens on the caller thread (cheap,
    sharded), serialization happens on a writer thread so training continues;
  * retention: keep the last K checkpoints, delete older ones only AFTER the
    newest commit succeeds.

On a multi-controller deployment each host writes only its addressable
shards; here (single controller) we write the full arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    extra: Optional[Dict] = None) -> Path:
    """Synchronous atomic save.  Returns the committed path."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:010d}"
    tmp = d / f".tmp_step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {"step": step, "time": time.time(),
            "keys": sorted(arrays.keys()), "extra": extra or {}}
    (tmp / "meta.json").write_text(json.dumps(meta))
    with open(tmp / "meta.json") as f:  # fsync the metadata
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def load_checkpoint(directory: str, step: Optional[int] = None,
                    mesh=None, shardings: Optional[Pytree] = None,
                    template: Optional[Pytree] = None) -> Dict:
    """Load the latest (or given) step.  If ``shardings``+``template`` are
    given, leaves are device_put with those shardings — restoring onto a
    DIFFERENT mesh than the one that saved (elastic reshard) just works
    because saved arrays are full logical values."""
    d = Path(directory)
    ckpts = sorted(p for p in d.glob("step_*") if p.is_dir())
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {d}")
    if step is None:
        path = ckpts[-1]
    else:
        path = d / f"step_{step:010d}"
    meta = json.loads((path / "meta.json").read_text())
    arrays = dict(np.load(path / "arrays.npz"))
    if template is not None:
        flat_t = _flatten(template)
        restored_flat = {}
        shard_flat = _flatten(shardings) if shardings is not None else {}
        for k, tmpl in flat_t.items():
            a = arrays[k]
            if k in shard_flat:
                a = jax.device_put(a, shard_flat[k])
            restored_flat[k] = a
        # rebuild tree in template structure
        leaves_paths = jax.tree_util.tree_leaves_with_path(template)
        treedef = jax.tree_util.tree_structure(template)
        ordered = []
        for p, _ in leaves_paths:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            ordered.append(restored_flat[key])
        tree = jax.tree_util.tree_unflatten(treedef, ordered)
        return {"step": meta["step"], "tree": tree, "extra": meta["extra"]}
    return {"step": meta["step"], "arrays": arrays, "extra": meta["extra"]}


class CheckpointManager:
    """Async save + retention + crash recovery."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save_async(self, step: int, tree: Pytree,
                   extra: Optional[Dict] = None):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # D2H now

        def work():
            try:
                save_checkpoint(str(self.dir), step, host_tree, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._err = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_*"))
        return int(ckpts[-1].name.split("_")[1]) if ckpts else None

    def restore(self, **kw):
        return load_checkpoint(str(self.dir), **kw)

    def _gc(self):
        ckpts = sorted(p for p in self.dir.glob("step_*") if p.is_dir())
        for p in ckpts[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
