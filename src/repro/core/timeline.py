"""Execution timeline (paper Fig 14 / Fig 19 analogue).

Collects (worker, name, start, duration, kind) events from the scheduler /
simulator, renders an ASCII utilization view and exports Chrome trace JSON.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Event:
    worker: str
    name: str
    start: float
    duration: float
    kind: str = "compute"   # compute | transfer | host | collective | idle
    phase: str = ""         # reporting group (falls back to name prefix)

    @property
    def end(self) -> float:
        return self.start + self.duration


@dataclass
class Timeline:
    events: List[Event] = field(default_factory=list)
    # set by the engine once a run's events are final (the makespan fold
    # is O(events) and every post-run metric asks for it); ``add``
    # invalidates it, so incrementally-built timelines stay correct
    _mk_cache: Optional[float] = field(default=None, repr=False,
                                       compare=False)

    def add(self, worker, name, start, duration, kind="compute", phase=""):
        self._mk_cache = None
        self.events.append(Event(worker, name, start, duration, kind, phase))

    @property
    def makespan(self) -> float:
        mk = self._mk_cache
        if mk is not None:
            return mk
        return max((e.end for e in self.events), default=0.0)

    def utilization(self, worker: Optional[str] = None) -> float:
        evs = [e for e in self.events
               if (worker is None or e.worker == worker)
               and e.kind != "idle"]
        busy = sum(e.duration for e in evs)
        workers = {e.worker for e in self.events} if worker is None \
            else {worker}
        total = self.makespan * max(len(workers), 1)
        return busy / total if total else 0.0

    def per_kind(self) -> Dict[str, float]:
        from repro.sim.report import aggregate  # single aggregation home
        return aggregate(self.events, "kind")

    def per_phase(self) -> Dict[str, float]:
        from repro.sim.report import aggregate
        return aggregate(self.events, "phase")

    def to_chrome_trace(self) -> str:
        evs = [{"name": e.name, "ph": "X", "ts": e.start * 1e6,
                "dur": e.duration * 1e6, "pid": 0, "tid": e.worker,
                "args": {"kind": e.kind}} for e in self.events]
        return json.dumps({"traceEvents": evs})

    def ascii(self, width: int = 78) -> str:
        """Per-worker busy/idle bar chart."""
        span = self.makespan or 1.0
        workers = sorted({e.worker for e in self.events})
        sym = {"compute": "#", "transfer": "~", "host": "h",
               "collective": "c", "idle": "."}
        lines = []
        for w in workers:
            row = ["."] * width
            for e in self.events:
                if e.worker != w:
                    continue
                a = int(e.start / span * width)
                b = max(a + 1, int(e.end / span * width))
                for i in range(a, min(b, width)):
                    row[i] = sym.get(e.kind, "#")
            lines.append(f"{w:>12s} |{''.join(row)}|")
        lines.append(f"{'':>12s}  0{'':{width-10}}{span*1e3:.2f} ms")
        return "\n".join(lines)
