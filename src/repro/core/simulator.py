"""Full-stack simulator — stable API over the unified engine (repro.sim).

This module used to hold a closed-form roofline/breakdown path that never
talked to the tile scheduler or the interface models.  It is now a thin
wrapper: ``roofline()`` / ``breakdown()`` / ``energy()`` lower the analyzed
HLO dict to a ``repro.sim`` Program and read the terms off one engine run,
so the same simulated execution also yields the Timeline and energy (see
``repro.sim.engine.run`` for the full result).

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI — canonical values live in ``repro.sim.hw``.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.config import ModelConfig, ShapeConfig
from repro.core.energy import DEFAULT_ENERGY, EnergyModel
from repro.sim.hw import (HBM_BW, HOST_OVERHEAD_S, ICI_BW,  # noqa: F401
                          PEAK_FLOPS)
from repro.sim.report import Breakdown, Roofline  # noqa: F401  (re-export)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode counts the
    one generated token; prefill/train count the full sequence.  Inference
    shapes use the 2·N·D forward-only form."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one new token per sequence; attention reads the full KV cache —
    # add the 2·(kv re-read compute) term: 2 * 2 * L * d_kv * S per token
    tokens = shape.global_batch
    base = 2.0 * n * tokens
    if cfg.n_kv_heads and cfg.family not in ("ssm",):
        kv_dim = cfg.n_kv_heads * cfg.resolved_head_dim
        n_attn_layers = (cfg.n_layers // cfg.hybrid_attn_every
                         if cfg.family == "hybrid" else cfg.n_layers)
        base += 4.0 * n_attn_layers * kv_dim * shape.seq_len * tokens
    return base


def _engine_run(hlo: Dict, *, host_s: float, mf: float = 0.0,
                n_chips: int = 1):
    from repro.sim import engine, ir
    prog = ir.from_hlo(hlo)
    cfg = engine.EngineConfig(n_workers=1, interface="hbm",
                              host_floor_s=host_s, n_chips=n_chips)
    return engine.run(prog, cfg, model_flops=mf)


def roofline(hlo: Dict, cfg: Optional[ModelConfig],
             shape: Optional[ShapeConfig], n_chips: int, *,
             host_s: float = HOST_OVERHEAD_S) -> Roofline:
    """hlo: output of repro.core.hlo.analyze_hlo (PER-DEVICE module)."""
    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    return _engine_run(hlo, host_s=host_s, mf=mf, n_chips=n_chips).roofline


def breakdown(hlo: Dict, *, host_prep_s: float = 0.0,
              serialize_transfers: bool = True) -> Breakdown:
    """Decompose the analyzed step into SMAUG's Fig-1 phases.

    accelerator = compute time of the step's flops; transfer = HBM traffic
    beyond what the MXU stream hides behind the dots; collective = ICI time;
    host = modelled framework time.  All four are aggregations of one engine
    run's timeline (``serialize_transfers`` is kept for API compatibility —
    the engine's "hbm" interface is the serialized baseline)."""
    res = _engine_run(hlo, host_s=host_prep_s + HOST_OVERHEAD_S)
    return res.breakdown


def energy(hlo: Dict, seconds: float, n_chips: int = 1,
           em: EnergyModel = DEFAULT_ENERGY) -> Dict[str, float]:
    e_comp = em.compute(hlo["flops"])
    e_hbm = em.hbm(hlo["bytes"])
    e_ici = em.ici(hlo["collective_bytes"])
    e_static = em.static(seconds, 1)
    return {"compute_j": e_comp, "hbm_j": e_hbm, "ici_j": e_ici,
            "static_j": e_static,
            "total_j": e_comp + e_hbm + e_ici + e_static,
            "total_j_all_chips": (e_comp + e_hbm + e_ici + e_static) * n_chips}
