"""Full-stack simulator: roofline terms + end-to-end breakdown + energy.

This is SMAUG's gem5-Aladdin role in our stack: given the analyzed compiled
artifact (repro.core.hlo) — the "trace" — plus hardware constants, produce:

  * the three roofline terms (compute / memory / collective), per device,
  * the dominant bottleneck,
  * the useful-FLOPs ratio MODEL_FLOPS / HLO_FLOPs,
  * an end-to-end phase breakdown (accelerator compute vs data transfer vs
    host/framework time — the Fig 1 analogue),
  * energy estimates (repro.core.energy).

Hardware (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.config import ModelConfig, ShapeConfig
from repro.core.energy import DEFAULT_ENERGY, EnergyModel

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HOST_OVERHEAD_S = 50e-6      # per-step launch/framework floor (host runtime)


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    bound: str
    step_s: float                # max of terms (+ host floor)
    roofline_fraction: float     # compute_s / step_s (how close to the
                                 # compute roof the step runs)
    detail: Dict = field(default_factory=dict)

    def to_dict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio, "bound": self.bound,
            "step_s": self.step_s,
            "roofline_fraction": self.roofline_fraction,
            **self.detail,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode counts the
    one generated token; prefill/train count the full sequence.  Inference
    shapes use the 2·N·D forward-only form."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one new token per sequence; attention reads the full KV cache —
    # add the 2·(kv re-read compute) term: 2 * 2 * L * d_kv * S per token
    tokens = shape.global_batch
    base = 2.0 * n * tokens
    if cfg.n_kv_heads and cfg.family not in ("ssm",):
        kv_dim = cfg.n_kv_heads * cfg.resolved_head_dim
        n_attn_layers = (cfg.n_layers // cfg.hybrid_attn_every
                         if cfg.family == "hybrid" else cfg.n_layers)
        base += 4.0 * n_attn_layers * kv_dim * shape.seq_len * tokens
    return base


def roofline(hlo: Dict, cfg: Optional[ModelConfig], shape: Optional[ShapeConfig],
             n_chips: int, *, host_s: float = HOST_OVERHEAD_S) -> Roofline:
    """hlo: output of repro.core.hlo.analyze_hlo (PER-DEVICE module)."""
    comp = hlo["flops"] / PEAK_FLOPS
    mem = hlo["bytes"] / HBM_BW
    # ring-model wire bytes when available; raw operand sum as fallback
    coll = hlo.get("wire_bytes", hlo["collective_bytes"]) / ICI_BW
    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    hlo_total = hlo["flops"] * n_chips
    useful = mf / hlo_total if hlo_total else 0.0
    terms = {"compute": comp, "memory": mem, "collective": coll}
    bound = max(terms, key=terms.get)
    step = max(comp, mem, coll) + host_s
    ideal = (mf / n_chips) / PEAK_FLOPS if n_chips else 0.0
    return Roofline(
        compute_s=comp, memory_s=mem, collective_s=coll,
        model_flops=mf, hlo_flops=hlo_total, useful_ratio=useful,
        bound=bound, step_s=step,
        roofline_fraction=(ideal / step) if step else 0.0,
        detail={"ideal_compute_s": ideal, "host_s": host_s,
                "n_chips": n_chips})


@dataclass
class Breakdown:
    """End-to-end phase breakdown (Fig 1 analogue)."""
    accelerator_s: float
    transfer_s: float
    host_s: float
    collective_s: float

    @property
    def total_s(self):
        return (self.accelerator_s + self.transfer_s + self.host_s
                + self.collective_s)

    def fractions(self):
        t = self.total_s or 1.0
        return {"accelerator": self.accelerator_s / t,
                "transfer": self.transfer_s / t,
                "host": self.host_s / t,
                "collective": self.collective_s / t}


def breakdown(hlo: Dict, *, host_prep_s: float = 0.0,
              serialize_transfers: bool = True) -> Breakdown:
    """Decompose the analyzed step into SMAUG's Fig-1 phases.

    accelerator = compute-roofline time of the dots/convs;
    transfer    = HBM traffic beyond the compute-resident working set;
    collective  = ICI time; host = measured/modelled framework time.
    When ``serialize_transfers`` (the DMA-like baseline) phases add up;
    an optimized system overlaps them (the case studies quantify the gap).
    """
    accel = hlo["dot_flops"] / PEAK_FLOPS
    other_flops = (hlo["flops"] - hlo["dot_flops"]) / PEAK_FLOPS
    mem = hlo["bytes"] / HBM_BW
    transfer = max(mem - accel, 0.0)
    coll = hlo["collective_bytes"] / ICI_BW
    return Breakdown(accelerator_s=accel + other_flops, transfer_s=transfer,
                     host_s=host_prep_s + HOST_OVERHEAD_S,
                     collective_s=coll)


def energy(hlo: Dict, seconds: float, n_chips: int = 1,
           em: EnergyModel = DEFAULT_ENERGY) -> Dict[str, float]:
    e_comp = em.compute(hlo["flops"])
    e_hbm = em.hbm(hlo["bytes"])
    e_ici = em.ici(hlo["collective_bytes"])
    e_static = em.static(seconds, 1)
    return {"compute_j": e_comp, "hbm_j": e_hbm, "ici_j": e_ici,
            "static_j": e_static,
            "total_j": e_comp + e_hbm + e_ici + e_static,
            "total_j_all_chips": (e_comp + e_hbm + e_ici + e_static) * n_chips}
