"""Compiled-HLO analyzer — the "trace reader" of the simulator.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body exactly ONCE
(verified empirically; see EXPERIMENTS.md §Dry-run), so for scan-over-layers
models it reports one layer, not L.  This module re-derives FLOPs / bytes /
collective-bytes from ``compiled.as_text()`` with **loop-tree unsampling**:
per-computation costs are computed bottom-up and ``while`` bodies are
multiplied by their trip counts — the direct analogue of SMAUG's
``setSamplingFactor`` + loop-tree unsampling (paper §II-E1): the compiled
HLO *is* the sampled trace, and the static loop tree restores the full run.

Costing model:
  flops            dot/conv: exact from shapes; elementwise/reduce: #elems
  transcendentals  exp/log/tanh/... element counts
  bytes            per top-level instruction: operand+output buffer sizes
                   (fusions are costed at their boundary, like XLA does)
  collective_bytes sum of operand sizes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute
                   (the assignment's definition), multiplied through loops
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "e4m3": 1,
    "e5m2": 1,
}

_TRANSCENDENTAL_OPS = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "power", "sine", "cosine", "logistic", "atan2", "erf",
    "cbrt",
}

_COLLECTIVE_OPS = {
    "all-gather", "all-gather-start", "all-reduce", "all-reduce-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start", "ragged-all-to-all",
}

_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "broadcast", "transpose", "convert", "copy", "copy-start", "copy-done",
    "slice", "dynamic-slice", "dynamic-update-slice", "pad", "reverse",
    "concatenate", "gather", "scatter", "rng-bit-generator",
    "rng-get-and-update-state", "opt-barrier", "custom-call", "bitcast-convert",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "send", "send-done", "recv", "recv-done", "domain", "add-dependency",
}
# ^ zero FLOP cost; bytes still counted (data movement is their real cost)


@dataclass
class Shape:
    bytes: int
    elems: int


@dataclass
class Instr:
    name: str
    op: str
    shape: Shape
    operands: List[str]
    attrs: str
    is_root: bool = False
    raw_args: str = ""


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    wire_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    n_while: int = 0
    custom_calls: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        for k, v in other.collectives.items():
            slot = self.collectives.setdefault(k, {"count": 0, "bytes": 0.0})
            slot["count"] += v["count"] * mult
            slot["bytes"] += v["bytes"] * mult
        self.n_while += int(other.n_while * mult)
        for k, v in other.custom_calls.items():
            self.custom_calls[k] = self.custom_calls.get(k, 0) + v

    def to_dict(self):
        return {
            "flops": self.flops, "dot_flops": self.dot_flops,
            "transcendentals": self.transcendentals, "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "wire_bytes": self.wire_bytes,
            "collectives": self.collectives, "n_while": self.n_while,
            "custom_calls": self.custom_calls,
        }


# ---------------------------------------------------------------------------
# type parsing


def _skip_ws_comments(s: str, pos: int) -> int:
    while pos < len(s):
        if s[pos] == " ":
            pos += 1
        elif s.startswith("/*", pos):
            end = s.find("*/", pos)
            pos = len(s) if end < 0 else end + 2
        else:
            break
    return pos


def _parse_type(s: str, pos: int = 0) -> Tuple[Shape, int]:
    """Parse a type at s[pos:]; returns (Shape, next position)."""
    if s[pos] == "(":
        total, elems = 0, 0
        pos += 1
        while pos < len(s) and s[pos] != ")":
            sh, new_pos = _parse_type(s, pos)
            total += sh.bytes
            elems += sh.elems
            pos = new_pos if new_pos > pos else pos + 1  # always progress
            pos = _skip_ws_comments(s, pos)
            if pos < len(s) and s[pos] == ",":
                pos = _skip_ws_comments(s, pos + 1)
        return Shape(total, elems), min(pos + 1, len(s))
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", s[pos:])
    if not m:
        return Shape(0, 0), pos  # token / unknown
    dtype, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    nbytes = _DTYPE_BYTES.get(dtype, 4) * n
    pos += m.end()
    if pos < len(s) and s[pos] == "{":  # layout
        depth = 0
        while pos < len(s):
            if s[pos] == "{":
                depth += 1
            elif s[pos] == "}":
                depth -= 1
                if depth == 0:
                    pos += 1
                    break
            pos += 1
    return Shape(nbytes, n), pos


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")


# ---------------------------------------------------------------------------
# costing


def _attr_ref(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def analyze_hlo(text: str) -> Dict:
    """Top-level entry: returns the unsampled cost dictionary."""
    comps, entry, dims_table, const_table = _parse_full(text)
    cache: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in cache:
            return cache[name]
        comp = comps[name]
        total = Cost()
        for ins in comp.instrs:
            total.add(_instr_cost(ins, comp, comp_cost))
        cache[name] = total
        return total

    def _instr_cost(ins: Instr, comp: Computation, rec) -> Cost:
        c = Cost()
        op = ins.op
        out_b = ins.shape.bytes
        out_e = ins.shape.elems
        opnd_b = sum(comp.table[o].shape.bytes for o in ins.operands
                     if o in comp.table)
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "reshape"):
            return c
        # ---- data-movement model --------------------------------------
        # slicing ops touch only the slice, not the full (possibly stacked-
        # over-layers) operand; counting full operands inside a while body
        # would multiply by the trip count and overstate HBM traffic by L^2.
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes = 2.0 * out_b
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = (comp.table[ins.operands[1]].shape.bytes
                   if len(ins.operands) > 1 and ins.operands[1] in comp.table
                   else out_b)
            c.bytes = 2.0 * upd
            return c
        c.bytes = out_b + opnd_b
        if op == "while":
            body = _attr_ref(ins.attrs, "body")
            cond = _attr_ref(ins.attrs, "condition")
            trip = const_table.get(cond, 1)
            inner = Cost()
            if body in comps:
                inner.add(rec(body))
            if cond in comps:
                inner.add(rec(cond))
            c.bytes = 0.0  # carry traffic belongs to producers + body ops
            c.add(inner, mult=max(trip, 1))
            c.n_while += 1
            return c
        if op == "conditional":
            branches = re.findall(r"(?:true_computation|false_computation|"
                                  r"branch_computations=\{)([^,}]+)",
                                  ins.attrs)
            sub = [rec(b.strip("% ")) for b in branches if b.strip("% ")
                   in comps]
            if sub:
                worst = max(sub, key=lambda s: s.flops)
                c.add(worst)
            return c
        if op in ("fusion", "call", "async-start"):
            target = _attr_ref(ins.attrs, "calls") or _attr_ref(ins.attrs,
                                                                "to_apply")
            if target in comps:
                inner = rec(target)
                # fusion: inner flops count, inner BYTES don't (VMEM-resident)
                c.flops += inner.flops
                c.dot_flops += inner.dot_flops
                c.transcendentals += inner.transcendentals
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.collectives.items():
                    slot = c.collectives.setdefault(
                        k, {"count": 0, "bytes": 0.0})
                    slot["count"] += v["count"]
                    slot["bytes"] += v["bytes"]
                # boundary bytes, slice-aware: a parameter whose only uses
                # inside the fusion are (dynamic-)slice/gather contributes the
                # slice size, not the full (often stacked-over-layers) operand
                c.bytes = _fusion_boundary_bytes(ins, comp, comps[target])
            return c
        if op in _COLLECTIVE_OPS:
            key = op.replace("-start", "")
            slot = c.collectives.setdefault(key, {"count": 0, "bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += opnd_b
            c.collective_bytes += opnd_b
            # ring-model wire bytes per device (used for the ICI roofline
            # term; the raw operand sum above is the assignment's metric)
            n = _group_size(ins.attrs)
            f = (n - 1) / n if n > 1 else 0.0
            if key == "all-reduce":
                c.wire_bytes += 2.0 * f * opnd_b
            elif key == "all-gather":
                c.wire_bytes += f * out_b
            elif key in ("reduce-scatter", "all-to-all",
                         "ragged-all-to-all"):
                c.wire_bytes += f * opnd_b
            else:  # collective-permute
                c.wire_bytes += opnd_b
            return c
        if op == "custom-call":
            m = re.search(r'custom_call_target="([^"]+)"', ins.attrs)
            tgt = m.group(1) if m else "?"
            c.custom_calls[tgt] = c.custom_calls.get(tgt, 0) + 1
            return c
        if op == "dot":
            k = 1
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
            ldims = dims_table.get((comp.name, ins.operands[0])) if \
                ins.operands else None
            if m and m.group(1) and ldims:
                for d in m.group(1).split(","):
                    if int(d) < len(ldims):
                        k *= ldims[int(d)]
            f = 2.0 * out_e * max(k, 1)
            c.flops += f
            c.dot_flops += f
            return c
        if op == "convolution":
            k = 1
            mw = re.search(r"window=\{size=([0-9x]+)", ins.attrs)
            if mw:
                for d in mw.group(1).split("x"):
                    k *= int(d)
            cin = 1
            md = re.search(r"dim_labels=([\w?]+)_([\w?]+)->", ins.attrs)
            if md and len(ins.operands) > 1:
                rdims = dims_table.get((comp.name, ins.operands[1]))
                i_pos = md.group(2).find("i")
                if rdims and 0 <= i_pos < len(rdims):
                    cin = rdims[i_pos]
            f = 2.0 * out_e * k * cin
            c.flops += f
            c.dot_flops += f
            return c
        if op in ("reduce", "reduce-window"):
            c.flops += sum(dims_and_elems(comp, o)
                           for o in ins.operands[:1]) or out_e
            return c
        if op == "sort":
            import math
            n = max(out_e, 2)
            c.flops += n * math.log2(n)
            return c
        if op in _ZERO_COST_OPS:
            return c
        # default: elementwise
        c.flops += out_e
        if op in _TRANSCENDENTAL_OPS:
            c.transcendentals += out_e
        return c

    def dims_and_elems(comp, opname):
        ins = comp.table.get(opname)
        return ins.shape.elems if ins else 0

    if entry is None:
        # pick the largest computation as entry fallback
        entry = max(comps, key=lambda k: len(comps[k].instrs))
    total = comp_cost(entry)
    d = total.to_dict()
    d["entry"] = entry
    d["n_computations"] = len(comps)
    return d


# ---------------------------------------------------------------------------
# full parse (adds per-instruction dims + while-condition constants)


def _parse_full(text: str):
    comps: Dict[str, Computation] = {}
    dims_table: Dict[Tuple[str, str], Tuple[int, ...]] = {}
    comp_consts: Dict[str, int] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        mc = _COMP_RE.match(line)
        if mc and ("=" not in line.split("(")[0]):
            cur = Computation(name=mc.group(2))
            comps[cur.name] = cur
            if mc.group(1):
                entry = cur.name
            # parameters appear in the signature for some printouts; the body
            # repeats them as instructions, which we rely on.
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        is_root = bool(mi.group(1))
        name = mi.group(2)
        rest = mi.group(3)
        shape, p = _parse_type(rest)
        # capture dims of the (first) array type for dot costing
        md = re.match(r"[a-z0-9]+\[([0-9,]*)\]", rest)
        if md is not None:
            dims = tuple(int(x) for x in md.group(1).split(",")) \
                if md.group(1) else ()
            dims_table[(cur.name, name)] = dims
        rest2 = rest[p:].strip()
        mo = re.match(r"([\w\-]+)\((.*)$", rest2)
        if not mo:
            continue
        op = mo.group(1)
        tail = mo.group(2)
        depth = 1
        arg_end = len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    arg_end = i
                    break
        args = tail[:arg_end]
        attrs = tail[arg_end + 1:]
        operands = re.findall(r"%([\w.\-]+)", args)
        if op == "constant":
            mval = re.match(r"\s*(-?\d+)\s*$", args)
            if mval and shape.elems <= 1:
                v = int(mval.group(1))
                comp_consts[cur.name] = max(comp_consts.get(cur.name, 0), v)
        ins = Instr(name=name, op=op, shape=shape, operands=operands,
                    attrs=attrs, is_root=is_root, raw_args=args)
        cur.instrs.append(ins)
        cur.table[name] = ins
    # while-condition trip counts: max int constant in the condition comp
    # (covers fused compare patterns: the limit constant stays at region level)
    const_table = comp_consts
    return comps, entry, dims_table, const_table


def _fusion_boundary_bytes(ins: Instr, comp: Computation,
                           fused: Computation) -> float:
    """HBM traffic at a fusion boundary with slice-awareness."""
    _SLICE = {"dynamic-slice", "slice", "gather"}
    # map parameter index -> instruction in fused computation
    params = {}
    for fi in fused.instrs:
        if fi.op == "parameter":
            m = re.match(r"\s*(\d+)", fi.raw_args)
            if m:
                params[int(m.group(1))] = fi
    root = next((fi for fi in fused.instrs if fi.is_root), None)
    total = 0.0
    for i, opname in enumerate(ins.operands):
        opnd = comp.table.get(opname)
        if opnd is None:
            continue
        pin = params.get(i)
        if pin is None:
            total += opnd.shape.bytes
            continue
        users = [fi for fi in fused.instrs if pin.name in fi.operands]
        if users and all(u.op in _SLICE for u in users):
            total += sum(u.shape.bytes for u in users)
        elif (root is not None and root.op == "dynamic-update-slice"
              and users == [root] and root.operands
              and root.operands[0] == pin.name):
            total += 0.0  # in-place DUS target: aliased, not read
        else:
            total += opnd.shape.bytes
    if root is not None and root.op in ("dynamic-update-slice", "scatter") \
            and len(root.operands) > 1:
        upd = fused.table.get(root.operands[1])
        total += 2.0 * (upd.shape.bytes if upd else ins.shape.bytes)
    else:
        total += ins.shape.bytes
    return total


def _group_size(attrs: str) -> int:
    """Collective group size from replica_groups=[G,N]<=[...] or {{...}}."""
    m = re.search(r"replica_groups=\[\d+,(\d+)\]", attrs)
    if m:
        return int(m.group(1))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 1
