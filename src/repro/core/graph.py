"""Declarative Python graph frontend (paper §II-A, Fig 2).

Networks are built inside a ``Graph`` context with deferred execution,
serialized (topology JSON + parameters npz), then executed by the runtime —
here a jnp executor with an operator-fusion pass (conv/matmul + bias +
elementwise, as the paper applies automatically) — or mapped to tile tasks
for the multi-accelerator scheduler simulation.

Example (the paper's residual unit):

    with Graph(name="residual", backend="mxu") as g:
        act = input_data("input", np.random.rand(1, 32, 32, 8))
        f0 = weight("f0", np.random.rand(3, 3, 8, 64))
        x = convolution("conv0", act, f0, stride=1, padding="same",
                        activation="relu")
        ...
        add("add", x, act, activation="relu")
    g.write_graph("residual")
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

_CURRENT: List["Graph"] = []


@dataclass
class Node:
    name: str
    op: str
    inputs: List[str]
    attrs: Dict = field(default_factory=dict)
    shape: Tuple[int, ...] = ()


class GraphTensor:
    def __init__(self, name: str, shape, graph: "Graph"):
        self.name = name
        self.shape = tuple(shape)
        self.graph = graph


class Graph:
    def __init__(self, name: str, backend: str = "mxu"):
        self.name = name
        self.backend = backend
        self.nodes: Dict[str, Node] = {}
        self.order: List[str] = []
        self.params: Dict[str, np.ndarray] = {}
        self.inputs: List[str] = []
        self.outputs: List[str] = []

    # -- context manager ----------------------------------------------------
    def __enter__(self):
        _CURRENT.append(self)
        return self

    def __exit__(self, *exc):
        _CURRENT.pop()
        # outputs = nodes nobody consumes
        consumed = {i for n in self.nodes.values() for i in n.inputs}
        self.outputs = [n for n in self.order if n not in consumed]
        return False

    def add_node(self, node: Node) -> GraphTensor:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self.nodes[node.name] = node
        self.order.append(node.name)
        return GraphTensor(node.name, node.shape, self)

    # -- serialization ------------------------------------------------------
    def write_graph(self, path: str):
        p = Path(path)
        topo = {"name": self.name, "backend": self.backend,
                "inputs": self.inputs, "outputs": self.outputs,
                "nodes": [{"name": n.name, "op": n.op, "inputs": n.inputs,
                           "attrs": n.attrs, "shape": list(n.shape)}
                          for n in (self.nodes[k] for k in self.order)]}
        p.with_suffix(".json").write_text(json.dumps(topo, indent=1))
        # parameters stored separately so they can be swapped (paper §II-A)
        np.savez(p.with_suffix(".npz"), **self.params)
        return p

    @classmethod
    def read_graph(cls, path: str) -> "Graph":
        p = Path(path)
        topo = json.loads(p.with_suffix(".json").read_text())
        g = cls(topo["name"], topo["backend"])
        for nd in topo["nodes"]:
            g.add_node(Node(nd["name"], nd["op"], nd["inputs"], nd["attrs"],
                            tuple(nd["shape"])))
        g.inputs = topo["inputs"]
        g.outputs = topo["outputs"]
        if p.with_suffix(".npz").exists():
            g.params = dict(np.load(p.with_suffix(".npz")))
        return g

    # -- execution ----------------------------------------------------------
    def execute(self, feeds: Dict[str, np.ndarray], fuse: bool = True):
        """Topological jnp execution with the automatic fusion pass."""
        import jax.numpy as jnp
        from repro.core import graph_ops as ops
        vals: Dict[str, jnp.ndarray] = {}
        fused_into: Dict[str, str] = self.fusion_plan() if fuse else {}
        for name in self.order:
            n = self.nodes[name]
            if n.op == "input":
                vals[name] = jnp.asarray(feeds[name])
                continue
            if n.op == "weight":
                vals[name] = jnp.asarray(self.params[name])
                continue
            if name in fused_into:      # consumed by its fused producer
                continue
            vals[name] = ops.run_node(self, n, vals, fused_into)
        return {o: np.asarray(vals[o]) for o in self.outputs if o in vals}

    def fusion_plan(self) -> Dict[str, str]:
        """conv/matmul + following elementwise (relu/add-bias) fusion: maps
        fused-consumer name -> producer it is folded into."""
        plan: Dict[str, str] = {}
        consumers: Dict[str, List[str]] = {}
        for n in self.nodes.values():
            for i in n.inputs:
                consumers.setdefault(i, []).append(n.name)
        for n in self.nodes.values():
            if n.op in ("convolution", "matmul") and \
                    not n.attrs.get("activation"):
                cons = consumers.get(n.name, [])
                if len(cons) == 1:
                    c = self.nodes[cons[0]]
                    if c.op in ("relu", "gelu"):
                        plan[c.name] = n.name
        return plan

    # -- simulation views ----------------------------------------------------
    def program(self, batch: int = 1, max_tile_elems: int = 16384):
        """Lower to a ``repro.sim`` Program (the unified engine's IR)."""
        from repro.sim.ir import from_graph
        return from_graph(self, batch=batch, max_tile_elems=max_tile_elems)

    def tile_tasks(self, batch: int = 1, max_tile_elems: int = 16384):
        """Legacy TileTask view of :meth:`program` (scheduler compat)."""
        from repro.core.scheduler import TileTask
        from repro.sim import hw
        return [TileTask(name=op.name,
                         duration=max(op.flops / hw.PEAK_FLOPS, 1e-9),
                         transfer=op.bytes / hw.HBM_BW,
                         affinity=op.affinity, deps=op.deps)
                for op in self.program(batch, max_tile_elems).ops]


def current_graph() -> Graph:
    if not _CURRENT:
        raise RuntimeError("no active Graph context")
    return _CURRENT[-1]


# ---------------------------------------------------------------------------
# builder API (paper Fig 2 style)


def input_data(name: str, array) -> GraphTensor:
    g = current_graph()
    arr = np.asarray(array)
    g.inputs.append(name)
    return g.add_node(Node(name, "input", [], {}, arr.shape))


def weight(name: str, array) -> GraphTensor:
    g = current_graph()
    arr = np.asarray(array, dtype=np.float32)
    g.params[name] = arr
    return g.add_node(Node(name, "weight", [], {}, arr.shape))


def convolution(name, x: GraphTensor, w: GraphTensor, *, stride=1,
                padding="same", activation=None) -> GraphTensor:
    g = current_graph()
    kh, kw, cin, cout = w.shape
    n, h, ww_, c = x.shape
    if padding == "same":
        oh, ow = (h + stride - 1) // stride, (ww_ + stride - 1) // stride
    else:
        oh, ow = (h - kh) // stride + 1, (ww_ - kw) // stride + 1
    return g.add_node(Node(name, "convolution", [x.name, w.name],
                           {"stride": stride, "padding": padding,
                            "activation": activation}, (n, oh, ow, cout)))


def matmul(name, x: GraphTensor, w: GraphTensor, *, activation=None):
    g = current_graph()
    shape = (*x.shape[:-1], w.shape[-1])
    return g.add_node(Node(name, "matmul", [x.name, w.name],
                           {"activation": activation}, shape))


def add(name, a: GraphTensor, b: GraphTensor, *, activation=None):
    g = current_graph()
    return g.add_node(Node(name, "add", [a.name, b.name],
                           {"activation": activation}, a.shape))


def relu(name, x: GraphTensor):
    g = current_graph()
    return g.add_node(Node(name, "relu", [x.name], {}, x.shape))


def max_pool(name, x: GraphTensor, k: int = 2):
    g = current_graph()
    n, h, w, c = x.shape
    return g.add_node(Node(name, "max_pool", [x.name], {"k": k},
                           (n, h // k, w // k, c)))


def batch_norm(name, x: GraphTensor):
    g = current_graph()
    gr = current_graph()
    gr.params[name + "_scale"] = np.ones((x.shape[-1],), np.float32)
    gr.params[name + "_bias"] = np.zeros((x.shape[-1],), np.float32)
    return g.add_node(Node(name, "batch_norm", [x.name], {}, x.shape))


def flatten(name, x: GraphTensor):
    g = current_graph()
    n = x.shape[0]
    rest = int(np.prod(x.shape[1:]))
    return g.add_node(Node(name, "flatten", [x.name], {}, (n, rest)))
