"""Model / shape configuration system.

Every assigned architecture is described by a ``ModelConfig``; every
benchmark input shape by a ``ShapeConfig``.  Configs are plain frozen
dataclasses so they can be hashed, serialized, and diffed.  The registry in
``repro.configs`` maps ``--arch <id>`` strings to full and reduced (smoke)
configs.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0          # routed experts
    top_k: int = 0
    n_shared: int = 0           # shared (always-on) experts
    d_ff_expert: int = 0        # per-expert hidden dim
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0        # 0 => no q compression (V2-Lite)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    version: int = 1            # 1 = mamba1 selective scan, 2 = mamba2 SSD
    n_heads: int = 0            # mamba2 heads (d_inner / head_dim)
    head_dim: int = 64
    chunk: int = 256            # SSD chunk length


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper).  Frontend is a stub:
    input_specs() provides precomputed frame/patch embeddings."""
    n_layers: int = 12
    n_ctx: int = 1500           # audio frames after conv stub


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 => d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    max_seq: int = 131_072
    # attention pattern
    window: int = 0             # sliding window size (0 = full)
    local_global_ratio: int = 0 # e.g. 5 => 5 local : 1 global (gemma3)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid (zamba2): attention block shared & inserted every k ssm blocks
    hybrid_attn_every: int = 0
    # vlm: number of prefix patch embeddings supplied by the (stub) vision tower
    n_patches: int = 0
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_attention(self) -> bool:
        """True if long-context decode (long_500k) is runnable."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.local_global_ratio > 0 or self.window > 0

    def param_count(self) -> int:
        """Analytical parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += self.vocab * d  # lm head
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per_layer = (d * 2 * d_in        # in_proj (x, z)
                         + d_in * s.d_conv   # depthwise conv
                         + d_in * (s.d_state * 2 + 1)  # B,C,dt proj (approx)
                         + d_in * s.d_state  # A
                         + d_in * d)         # out_proj
            n += L * (per_layer + d)  # + norm
            return n
        # attention params
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        if self.mla is not None:
            m = self.mla
            q = d * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            kv = d * (m.kv_lora_rank + m.qk_rope_dim) \
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            o = self.n_heads * m.v_head_dim * d
        attn = q + kv + o
        # mlp params
        gates = 2 if self.activation in ("swiglu", "geglu") else 1
        if self.moe is not None:
            e = self.moe
            mlp = (e.n_experts + e.n_shared) * (gates + 1) * d * e.d_ff_expert \
                + d * e.n_experts  # router
        else:
            mlp = (gates + 1) * d * self.d_ff
        if self.family == "hybrid":
            # zamba2: mamba blocks everywhere + ONE shared attention+mlp block
            s = self.ssm
            d_in = s.expand * d
            mamba = (d * 2 * d_in + d_in * s.d_conv
                     + d_in * (2 * s.d_state + 1) + s.n_heads
                     + d_in * d)
            n += L * (mamba + d)
            n += attn + mlp + 2 * d  # shared block, counted once
            return n
        n += L * (attn + mlp + 2 * d)
        if self.encoder is not None:
            # encoder layers: self-attn + mlp ; decoder adds cross-attn
            n += self.encoder.n_layers * (attn + mlp + 2 * d)
            n += L * (attn + d)  # cross attention in decoder
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        gates = 2 if self.activation in ("swiglu", "geglu") else 1
        full_mlp = (e.n_experts + e.n_shared) * (gates + 1) * self.d_model * e.d_ff_expert
        act_mlp = (e.top_k + e.n_shared) * (gates + 1) * self.d_model * e.d_ff_expert
        return self.param_count() - self.n_layers * (full_mlp - act_mlp)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode
    # decode shapes: seq_len is the KV-cache length; one new token is produced

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not model.has_subquadratic_attention:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""
