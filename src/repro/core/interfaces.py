"""Accelerator<->memory-system data-path models (paper §IV-A, TPU-adapted).

SMAUG's case study compared DMA (software-managed scratchpad fills with
explicit cache flush/invalidate cost) against ACP (one-way coherent port into
the LLC: no SW coherency management, DRAM hits become LLC hits).

On a TPU the analogous end-to-end choice is how an intermediate tensor moves
between producer and consumer ops:

  dma   : producer writes HBM, framework-level boundary (layout change /
          tiling pass) with per-transfer launch overhead, consumer re-reads
          HBM — the "every op round-trips HBM + host manages staging" model.
  acp   : fused/resident path — producer output stays in VMEM for the
          consumer (one-way coherent: no host staging, no flush analogue);
          only first read + last write touch HBM.

Both are cost models evaluated over the op graph; the Fig 11 analogue
(benchmarks/bench_interfaces.py) sweeps them per network.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.energy import DEFAULT_ENERGY, EnergyModel
from repro.sim.hw import HBM_BW, VMEM_BW  # noqa: F401  (single home)

DMA_LAUNCH_S = 2e-6    # per-transfer software+descriptor overhead
FLUSH_PER_BYTE = 6e-12 # SW coherency-management analogue (staging/copy mgmt)


@dataclass(frozen=True)
class TransferCost:
    seconds: float
    energy_j: float


def dma_transfer(nbytes: float, n_transfers: int = 1,
                 em: EnergyModel = DEFAULT_ENERGY,
                 hbm_bw: Optional[float] = None) -> TransferCost:
    """HBM round-trip with SW-managed staging (DMA analogue)."""
    bw = hbm_bw or HBM_BW
    t = (2 * nbytes / bw              # write + re-read
         + n_transfers * DMA_LAUNCH_S
         + nbytes * FLUSH_PER_BYTE)   # staging management
    e = em.hbm(2 * nbytes) + em.host(nbytes * 0.05)
    return TransferCost(t, e)


def acp_transfer(nbytes: float, resident_fraction: float = 1.0,
                 em: EnergyModel = DEFAULT_ENERGY,
                 hbm_bw: Optional[float] = None,
                 vmem_bw: Optional[float] = None) -> TransferCost:
    """Fused / VMEM-resident path (coherent-port analogue).

    resident_fraction: share of the tensor that stays on-chip between
    producer and consumer (1.0 = fully fused; working sets larger than VMEM
    spill the remainder through HBM)."""
    spill = nbytes * (1.0 - resident_fraction)
    t = (nbytes * resident_fraction) / (vmem_bw or VMEM_BW) \
        + 2 * spill / (hbm_bw or HBM_BW)
    e = em.vmem(2 * nbytes * resident_fraction) + em.hbm(2 * spill)
    return TransferCost(t, e)
