"""TensorSpec with layout metadata — the unit the tiling optimizer reasons
about (paper §II-B: layout determines the memcpy pattern of a tiling).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
                "int32": 4, "fp8": 1}


@dataclass(frozen=True)
class TensorSpec:
    """shape with dimension tags, e.g. (1, 16, 16, 128) / "NHWC"."""
    shape: Tuple[int, ...]
    dims: str                     # one tag char per dim, e.g. "NHWC"
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)

    @property
    def n_elems(self) -> int:
        return int(np.prod(self.shape))

    @property
    def nbytes(self) -> int:
        return self.n_elems * _DTYPE_BYTES.get(self.dtype, 4)

    def dim(self, tag: str) -> int:
        return self.shape[self.dims.index(tag)]

    def with_dim(self, tag: str, size: int) -> "TensorSpec":
        s = list(self.shape)
        s[self.dims.index(tag)] = size
        return TensorSpec(tuple(s), self.dims, self.dtype)

    def contiguous_run(self, tile_shape: Sequence[int]) -> int:
        """Elements of one maximal contiguous memcpy when extracting a tile
        of ``tile_shape`` from this (row-major) tensor.

        The run extends over the trailing dims that are NOT tiled (tile dim
        == full dim), times the tile size of the first tiled dim.
        """
        run = 1
        for full, tile in zip(reversed(self.shape), reversed(tuple(tile_shape))):
            if tile == full:
                run *= full
            else:
                run *= tile
                break
        return run

    def n_memcpys(self, tile_shape: Sequence[int]) -> int:
        """Number of contiguous memcpys to materialize ALL tiles (Fig 5/6)."""
        run = self.contiguous_run(tile_shape)
        return max(1, self.n_elems // run)
