"""Sampled simulation with loop-tree unsampling (paper §II-E1, TPU-adapted).

Aladdin traces a few iterations of each loop (``setSamplingFactor``) and
"unsamples" latency up a loop tree.  Our analogue: the models are built as
scans (layers / KV-chunks / microbatches / scan-steps), so the compiled HLO
contains each loop body ONCE — it *is* the sampled trace.  ``LoopNode``
describes the static loop tree; ``unsample`` multiplies measured body costs
back to the full run; ``sampling_error`` validates sampled vs fully-unrolled
measurement (the Fig 8 analogue lives in benchmarks/bench_sampling.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence


@dataclass
class LoopNode:
    """A loop level: ``trips`` iterations, each costing ``body`` plus
    children.  ``sampled_trips`` = how many iterations were actually
    measured (>=1)."""
    name: str
    trips: int
    body_cost: float = 0.0            # per-iteration cost OUTSIDE children
    children: List["LoopNode"] = field(default_factory=list)
    sampled_trips: int = 1

    def sampled_cost(self) -> float:
        """Cost of the measured (sampled) execution."""
        inner = sum(c.sampled_cost() for c in self.children)
        return self.sampled_trips * (self.body_cost + inner)

    def unsampled_cost(self) -> float:
        """Cost propagated to the FULL trip counts (the unsampling pass)."""
        inner = sum(c.unsampled_cost() for c in self.children)
        return self.trips * (self.body_cost + inner)

    def sampling_factor(self) -> float:
        s = self.sampled_cost()
        return self.unsampled_cost() / s if s else float("inf")


def unsample(root: LoopNode) -> float:
    return root.unsampled_cost()


def sampling_error(estimated: float, measured: float) -> float:
    """Relative error of the sampled estimate vs ground truth."""
    return abs(estimated - measured) / max(abs(measured), 1e-30)


def measure_sampled(fn: Callable[[int], float], trips: int,
                    sample: int) -> LoopNode:
    """Run ``fn(n_iters)`` for ``sample`` iterations, build the node.

    fn returns measured cost of executing n iterations; pipelined loops need
    sample >= 2 (paper: two iterations to expose the pipeline latency), so we
    measure fn(sample) and fn(sample-1) and use the marginal cost when
    possible."""
    sample = max(1, min(sample, trips))
    if sample >= 2:
        # two-point measurement: marginal cost separates the pipeline/startup
        # latency from the steady-state per-iteration cost (paper: "at least
        # two loop iterations are required to determine the pipeline latency")
        c_k = fn(sample)
        c_k1 = fn(sample - 1)
        per_iter = max(c_k - c_k1, 1e-12)
        startup = max(c_k - sample * per_iter, 0.0)
        wrapper = LoopNode(name="run", trips=1, body_cost=startup)
        wrapper.children.append(LoopNode("iters", trips=trips,
                                         body_cost=per_iter,
                                         sampled_trips=sample))
        return wrapper
    cost = fn(1)
    return LoopNode(name="run", trips=1, body_cost=0.0,
                    children=[LoopNode("iters", trips=trips, body_cost=cost,
                                       sampled_trips=1)])


def model_loop_tree(cfg, shape_kind: str, *, n_chunks: int = 0,
                    n_microbatches: int = 1) -> LoopNode:
    """The static loop tree of one step for a ModelConfig (layers x chunks x
    scan steps) — what the HLO analyzer multiplies through."""
    layers = LoopNode("layers", trips=cfg.n_layers)
    if n_chunks:
        layers.children.append(LoopNode("kv_chunks", trips=n_chunks))
    if cfg.family in ("ssm", "hybrid") and shape_kind != "decode":
        layers.children.append(LoopNode("scan_chunks", trips=max(
            1, getattr(cfg.ssm, "chunk", 256))))
    root = LoopNode("step", trips=1, children=[
        LoopNode("microbatches", trips=n_microbatches, children=[layers])])
    return root
