"""Runtime scheduler (paper §II-C): accelerator worker pool + per-worker
command queues, tile-level parallelism, and reduction affinity.

Two modes:
  * ``simulate(...)``   — discrete-event simulation of the pool given tile
    durations (the multi-accelerator case study, Fig 12/14): tiles whose
    partial results must be reduced in place are pinned to one queue
    (affinity key), reproducing the under-utilization SMAUG observed on
    VGG16 layers 8/9.
  * ``ThreadPool``      — a real host-side worker pool used by the data
    pipeline for tile materialization / gathering (the multithreading case
    study, Fig 16): tasks run to completion, workers are woken only when
    work arrives (the gem5 quiesce workaround maps to a Condition variable).
"""
from __future__ import annotations

import heapq
import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.timeline import Timeline


@dataclass(frozen=True)
class TileTask:
    name: str
    duration: float                 # seconds (from the simulator/cost model)
    affinity: Optional[str] = None  # reduction-affinity key: same key ->
                                    # same worker queue (in-place partials)
    transfer: float = 0.0           # data-in time occupying the memory port
    deps: tuple = ()                # names that must complete first


def simulate(tasks: Sequence[TileTask], n_workers: int,
             shared_bw_penalty: float = 0.0) -> Timeline:
    """Discrete-event simulation of the worker pool.

    shared_bw_penalty: fractional slowdown of ``transfer`` phases per extra
    concurrently-transferring worker (memory-bandwidth contention model used
    in the Fig 13 analogue).
    """
    tl = Timeline()
    done: Dict[str, float] = {}
    pending = list(tasks)
    # per-worker available time; affinity map
    avail = [0.0] * n_workers
    affinity_worker: Dict[str, int] = {}

    def eligible(t: TileTask) -> bool:
        return all(d in done for d in t.deps)

    remaining = len(pending)
    while remaining:
        progressed = False
        ready = [t for t in pending if eligible(t)]
        for t in sorted(ready, key=lambda t: -t.duration):  # LPT heuristic
            if t.affinity is not None and t.affinity in affinity_worker:
                w = affinity_worker[t.affinity]
            else:
                w = min(range(n_workers), key=lambda i: avail[i])
                if t.affinity is not None:
                    affinity_worker[t.affinity] = w
            start = max(avail[w], max((done[d] for d in t.deps), default=0.0))
            n_conc = sum(1 for a in avail if a > start)  # crude concurrency
            xfer = t.transfer * (1.0 + shared_bw_penalty * max(n_conc - 1, 0))
            if xfer:
                tl.add(f"acc{w}", f"{t.name}:xfer", start, xfer, "transfer")
            tl.add(f"acc{w}", t.name, start + xfer, t.duration, "compute")
            avail[w] = start + xfer + t.duration
            done[t.name] = avail[w]
            pending.remove(t)
            remaining -= 1
            progressed = True
        if not progressed and pending:
            raise ValueError("dependency cycle in tile tasks")
    return tl


# ---------------------------------------------------------------------------
# real host-side worker pool (data preparation / finalization)


class ThreadPool:
    """Run-to-completion task pool with quiesced (condition-waiting) workers.

    The paper implements this inside gem5 because syscall-emulation has no
    kernel scheduler; here it is the host-side data-preparation pool.  NumPy
    memcpys release the GIL, so tiling/untiling tasks scale with workers.
    """

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        for i in range(n_workers):
            th = threading.Thread(target=self._worker, name=f"pool{i}",
                                  daemon=True)
            th.start()
            self._threads.append(th)

    def _worker(self):
        while not self._stop.is_set():
            try:
                fn, args, ev, out = self._q.get(timeout=0.1)
            except queue.Empty:
                continue  # quiesced wait
            try:
                out.append(fn(*args))
            except Exception as e:  # noqa: BLE001
                out.append(e)
            ev.set()
            self._q.task_done()

    def map(self, fn: Callable, items: Sequence) -> List:
        """Dispatch fn over items; blocks until all complete (join)."""
        slots = []
        for it in items:
            ev = threading.Event()
            out: List = []
            self._q.put((fn, (it,), ev, out))
            slots.append((ev, out))
        results = []
        for ev, out in slots:
            ev.wait()
            r = out[0]
            if isinstance(r, Exception):
                raise r
            results.append(r)
        return results

    def shutdown(self):
        self._stop.set()
        for th in self._threads:
            th.join(timeout=1.0)
