"""Runtime scheduler (paper §II-C): accelerator worker pool + per-worker
command queues, tile-level parallelism, and reduction affinity.

Two modes:
  * ``simulate(...)``   — discrete-event simulation of the pool given tile
    durations (the multi-accelerator case study, Fig 12/14): tiles whose
    partial results must be reduced in place are pinned to one queue
    (affinity key), reproducing the under-utilization SMAUG observed on
    VGG16 layers 8/9.
  * ``ThreadPool``      — a real host-side worker pool used by the data
    pipeline for tile materialization / gathering (the multithreading case
    study, Fig 16): tasks run to completion, workers are woken only when
    work arrives (the gem5 quiesce workaround maps to a Condition variable).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.timeline import Timeline


@dataclass(frozen=True)
class TileTask:
    name: str
    duration: float                 # seconds (from the simulator/cost model)
    affinity: Optional[str] = None  # reduction-affinity key: same key ->
                                    # same worker queue (in-place partials)
    transfer: float = 0.0           # data-in time occupying the memory port
    deps: tuple = ()                # names that must complete first


def simulate(tasks: Sequence[TileTask], n_workers: int,
             shared_bw_penalty: float = 0.0) -> Timeline:
    """Discrete-event simulation of the worker pool.

    Thin wrapper over the unified engine (``repro.sim.engine``): tasks lower
    to ``CostedOp``s with explicit durations and the engine schedules them
    (LPT, affinity queues, HBM-port contention).

    ``shared_bw_penalty`` is kept for API compatibility: the old per-extra-
    transfer fractional slowdown is translated into an equivalent HBM port
    count (worst-case slowdown ``1 + p*(n-1)`` == ``n_workers / ports``).
    """
    from repro.sim import engine, ir
    prog = ir.from_tasks(tasks, name="tiles")
    if shared_bw_penalty > 0.0 and n_workers > 1:
        # fractional ports keep the translation exact for every pool size
        # (integer rounding would erase the penalty for small n)
        ports = n_workers / (1.0 + shared_bw_penalty * (n_workers - 1))
    else:
        ports = 0  # one port per worker: no contention
    cfg = engine.EngineConfig(n_workers=n_workers, interface="hbm",
                              hbm_ports=ports)
    return engine.run(prog, cfg).timeline


# ---------------------------------------------------------------------------
# real host-side worker pool (data preparation / finalization)


class ThreadPool:
    """Run-to-completion task pool with quiesced (condition-waiting) workers.

    The paper implements this inside gem5 because syscall-emulation has no
    kernel scheduler; here it is the host-side data-preparation pool.  NumPy
    memcpys release the GIL, so tiling/untiling tasks scale with workers.
    """

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        for i in range(n_workers):
            th = threading.Thread(target=self._worker, name=f"pool{i}",
                                  daemon=True)
            th.start()
            self._threads.append(th)

    def _worker(self):
        while not self._stop.is_set():
            try:
                fn, args, ev, out = self._q.get(timeout=0.1)
            except queue.Empty:
                continue  # quiesced wait
            try:
                out.append(fn(*args))
            except Exception as e:  # noqa: BLE001
                out.append(e)
            ev.set()
            self._q.task_done()

    def map(self, fn: Callable, items: Sequence) -> List:
        """Dispatch fn over items; blocks until all complete (join)."""
        slots = []
        for it in items:
            ev = threading.Event()
            out: List = []
            self._q.put((fn, (it,), ev, out))
            slots.append((ev, out))
        results = []
        for ev, out in slots:
            ev.wait()
            r = out[0]
            if isinstance(r, Exception):
                raise r
            results.append(r)
        return results

    def shutdown(self):
        self._stop.set()
        for th in self._threads:
            th.join(timeout=1.0)
