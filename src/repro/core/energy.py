"""Energy model (paper §III-D analogue).

SMAUG characterized 16nm functional units + SRAM compiler blocks + CACTI for
the LLC + DRAMPower for LP-DDR4.  Without silicon access we parameterize
per-op energies with published-ballpark constants for a 5nm-class TPU part
and HBM2e; all constants are overridable so studies can re-characterize.

Units: joules.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EnergyModel:
    pj_per_flop_bf16: float = 0.25     # MXU MAC (0.5 pJ/MAC -> /2 per flop)
    pj_per_byte_hbm: float = 40.0      # HBM2e access ≈ 5 pJ/bit
    pj_per_byte_vmem: float = 1.2      # on-chip SRAM
    pj_per_byte_ici: float = 10.0      # inter-chip link
    pj_per_byte_host: float = 60.0     # host DRAM + PCIe path
    static_w_per_chip: float = 60.0    # idle/leakage+clocking floor

    def compute(self, flops: float) -> float:
        return flops * self.pj_per_flop_bf16 * 1e-12

    def hbm(self, nbytes: float) -> float:
        return nbytes * self.pj_per_byte_hbm * 1e-12

    def vmem(self, nbytes: float) -> float:
        return nbytes * self.pj_per_byte_vmem * 1e-12

    def ici(self, nbytes: float) -> float:
        return nbytes * self.pj_per_byte_ici * 1e-12

    def host(self, nbytes: float) -> float:
        return nbytes * self.pj_per_byte_host * 1e-12

    def static(self, seconds: float, n_chips: int = 1) -> float:
        return self.static_w_per_chip * seconds * n_chips


DEFAULT_ENERGY = EnergyModel()
