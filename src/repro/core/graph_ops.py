"""Graph-node executors (jnp) + per-node tile/cost mapping.

Convolutions lower to im2col matmuls — the NVDLA channel-reduction dataflow
adapted to the MXU contraction dimension (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import HBM_BW, PEAK_FLOPS
from repro.core.tensor import TensorSpec
from repro.core.tiling import choose_tiling


def _activation(kind, x):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    return x


def run_node(g, n, vals: Dict, fused_into: Dict[str, str]):
    x = vals[n.inputs[0]] if n.inputs else None
    if n.op == "convolution":
        w = vals[n.inputs[1]]
        stride = n.attrs.get("stride", 1)
        pad = n.attrs.get("padding", "same").upper()
        out = jax.lax.conv_general_dilated(
            x, w, (stride, stride), pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        out = _activation(n.attrs.get("activation"), out)
    elif n.op == "matmul":
        w = vals[n.inputs[1]]
        xx = x.reshape(x.shape[0], -1) if x.ndim > 2 else x
        out = _activation(n.attrs.get("activation"), xx @ w)
    elif n.op == "add":
        out = _activation(n.attrs.get("activation"),
                          x + vals[n.inputs[1]])
    elif n.op == "relu":
        out = jax.nn.relu(x)
    elif n.op == "max_pool":
        k = n.attrs.get("k", 2)
        out = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")
    elif n.op == "batch_norm":
        scale = jnp.asarray(g.params[n.name + "_scale"])
        bias = jnp.asarray(g.params[n.name + "_bias"])
        mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
        var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
        out = (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias
    elif n.op == "flatten":
        out = x.reshape(n.shape)
    else:
        raise ValueError(f"unknown op {n.op}")
    # apply any elementwise op fused into this node
    for consumer, producer in fused_into.items():
        if producer == n.name:
            cn = g.nodes[consumer]
            if cn.op in ("relu", "gelu"):
                out = _activation(cn.op, out)
            vals[consumer] = out
    return out


def node_flops_bytes(n, batch: int = 1):
    """(flops, bytes) of one node at the given batch."""
    elems_out = int(np.prod(n.shape)) * batch // max(n.shape[0], 1)
    if n.op == "convolution":
        kh, kw, cin, cout = (0, 0, 0, 0)
        flops = 0
        # attrs carry stride; kernel shape from the weight input is not
        # stored on the node, so approximate from attrs if present
        k = n.attrs.get("kernel", 3)
        cin = n.attrs.get("cin", n.shape[-1])
        flops = 2 * elems_out * k * k * cin
        return flops, 4 * (elems_out * 2)
    if n.op == "matmul":
        cin = n.attrs.get("cin", n.shape[-1])
        flops = 2 * elems_out * cin
        return flops, 4 * (elems_out * 2 + cin * n.shape[-1])
    return elems_out, 4 * elems_out * 2


def node_cost(g, n, batch: int, max_tile_elems: int) -> List:
    """Map a node to TileTasks via the tiling optimizer."""
    from repro.core.scheduler import TileTask
    if n.op in ("input", "weight"):
        return []
    # resolve real kernel/cin from producer weight node when available
    if n.op in ("convolution", "matmul") and len(n.inputs) > 1:
        wshape = g.nodes[n.inputs[1]].shape
        if n.op == "convolution":
            n.attrs.setdefault("kernel", wshape[0])
            n.attrs.setdefault("cin", wshape[2])
        else:
            n.attrs.setdefault("cin", wshape[0])
    flops, nbytes = node_flops_bytes(n, batch)
    shape4 = tuple(n.shape) if len(n.shape) == 4 else \
        (1, 1, 1, int(np.prod(n.shape)))
    spec = TensorSpec(shape4, "NHWC", "float32")
    tiling = choose_tiling(spec, max_tile_elems,
                           reduce_dim="C" if n.op in ("convolution", "matmul")
                           else None)
    n_tiles = max(tiling.n_tiles, 1)
    per_tile_s = max(flops / n_tiles / PEAK_FLOPS, 1e-9)
    per_tile_xfer = nbytes / n_tiles / HBM_BW
    # reduction affinity: convolution tiles that cut the channel (reduce) dim
    # must land on one queue (in-place partial sums, paper Fig 14)
    reduce_affinity = "C" in tiling.strategy and n.op == "convolution"
    tasks = []
    for i in range(n_tiles):
        tasks.append(TileTask(
            name=f"{n.name}/t{i}", duration=per_tile_s,
            transfer=per_tile_xfer,
            affinity=(n.name if reduce_affinity else None),
            deps=tuple(f"{d}/t0" for d in n.inputs
                       if d in g.nodes and g.nodes[d].op not in
                       ("input", "weight"))))
    return tasks
