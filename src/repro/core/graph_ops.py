"""Graph-node executors (jnp) + per-node tile/cost mapping.

Convolutions lower to im2col matmuls — the NVDLA channel-reduction dataflow
adapted to the MXU contraction dimension (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp



def _activation(kind, x):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    return x


def run_node(g, n, vals: Dict, fused_into: Dict[str, str]):
    x = vals[n.inputs[0]] if n.inputs else None
    if n.op == "convolution":
        w = vals[n.inputs[1]]
        stride = n.attrs.get("stride", 1)
        pad = n.attrs.get("padding", "same").upper()
        out = jax.lax.conv_general_dilated(
            x, w, (stride, stride), pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        out = _activation(n.attrs.get("activation"), out)
    elif n.op == "matmul":
        w = vals[n.inputs[1]]
        xx = x.reshape(x.shape[0], -1) if x.ndim > 2 else x
        out = _activation(n.attrs.get("activation"), xx @ w)
    elif n.op == "add":
        out = _activation(n.attrs.get("activation"),
                          x + vals[n.inputs[1]])
    elif n.op == "relu":
        out = jax.nn.relu(x)
    elif n.op == "max_pool":
        k = n.attrs.get("k", 2)
        out = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")
    elif n.op == "batch_norm":
        scale = jnp.asarray(g.params[n.name + "_scale"])
        bias = jnp.asarray(g.params[n.name + "_bias"])
        mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
        var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
        out = (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias
    elif n.op == "flatten":
        out = x.reshape(n.shape)
    else:
        raise ValueError(f"unknown op {n.op}")
    # apply any elementwise op fused into this node
    for consumer, producer in fused_into.items():
        if producer == n.name:
            cn = g.nodes[consumer]
            if cn.op in ("relu", "gelu"):
                out = _activation(cn.op, out)
            vals[consumer] = out
    return out


# NOTE: the per-node tile/cost lowering that used to live here (node_cost /
# node_flops_bytes) moved to ``repro.sim.ir.from_graph`` — the unified
# engine's IR is the single place graph nodes become costed work.
