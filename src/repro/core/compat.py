"""JAX version compatibility shims.

The repo targets the newest public API (``jax.shard_map``, dict-returning
``cost_analysis``); the container may run an older jax.  All version probing
lives here so the rest of the code imports one stable surface.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (older jax returns a
    one-element list of dicts, newer returns the dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
