"""Dataflow-specialized tiling optimizer (paper §II-B, TPU-adapted).

SMAUG's insight: don't solve the general loop-nest problem — each accelerator
implements at most a few dataflows, so enumerate only the tiling strategies
compatible with THAT dataflow and search the narrow space exhaustively,
scoring by (a) functional-unit + scratchpad utilization and (b) the
host/HBM-side cost of materializing the tiles (layout contiguity).

TPU adaptation (DESIGN.md §2):
  scratchpad  -> VMEM budget per tile working set
  32-way MACC channel reduction (NVDLA) -> 128x128 MXU contraction tiles
  memcpy contiguity -> HBM burst contiguity (trailing-dim runs)

Outputs both abstract tile shapes (for the scheduler/simulator) and concrete
Pallas ``BlockSpec`` block shapes for the matmul kernel.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.tensor import TensorSpec

# hardware constants (TPU v5e)
VMEM_BYTES = 128 * 1024 * 1024      # per-core vector memory
MXU_DIM = 128                       # systolic array is 128x128
LANE = 128                          # last-dim register lane quantum
SUBLANE = 8                         # second-minor quantum (fp32)
HBM_LATENCY_US = 1.0                # per-transaction overhead (DMA-ish)
HBM_BW = 819e9                      # bytes/s


@dataclass(frozen=True)
class TilingChoice:
    """One evaluated tiling of a tensor."""
    strategy: str                    # e.g. "DimC", "DimHW", "DimNH"
    tile_shape: Tuple[int, ...]
    n_tiles: int
    n_memcpys: int
    contiguous_run: int              # elements per memcpy
    utilization: float               # fraction of compute-dim quantum used
    host_cost_s: float               # modeled tile-materialization time

    def __str__(self):
        return (f"{self.strategy}: tile={self.tile_shape} n={self.n_tiles} "
                f"memcpys={self.n_memcpys} run={self.contiguous_run} "
                f"util={self.utilization:.2f} host={self.host_cost_s*1e6:.1f}us")


def _host_cost(n_memcpys: int, total_bytes: int) -> float:
    """Tile-materialization cost: bandwidth term + per-memcpy overhead.
    Reproduces the Fig 6 effect: many tiny memcpys lose to few large ones."""
    return total_bytes / HBM_BW + n_memcpys * HBM_LATENCY_US * 1e-6


def enumerate_tilings(spec: TensorSpec, max_tile_elems: int,
                      reduce_dim: Optional[str] = None,
                      reduce_quantum: int = MXU_DIM) -> List[TilingChoice]:
    """All dataflow-compatible tilings of ``spec`` under the VMEM budget.

    ``reduce_dim``: the dimension the dataflow reduces over (NVDLA: channels;
    MXU matmul: the contraction dim).  Tiles keep it a multiple of
    ``reduce_quantum`` where possible (functional-unit utilization).
    """
    dims = spec.dims
    choices: List[TilingChoice] = []
    # all subsets of dims to tile (strategy DimXY... = dims being cut)
    for r in range(1, len(dims) + 1):
        for cut in itertools.combinations(range(len(dims)), r):
            strategy = "Dim" + "".join(dims[i] for i in cut)
            tile = _best_tile_for_cut(spec, cut, max_tile_elems,
                                      reduce_dim, reduce_quantum)
            if tile is None:
                continue
            n_elems_tile = math.prod(tile)
            if n_elems_tile > max_tile_elems:
                continue
            n_tiles = 1
            for full, t in zip(spec.shape, tile):
                n_tiles *= math.ceil(full / t)
            n_memcpys = spec.n_memcpys(tile)
            run = spec.contiguous_run(tile)
            util = 1.0
            if reduce_dim and reduce_dim in dims:
                rd = tile[dims.index(reduce_dim)]
                util = min(1.0, rd / reduce_quantum) if rd < reduce_quantum \
                    else (rd // reduce_quantum) * reduce_quantum / rd
            choices.append(TilingChoice(
                strategy=strategy, tile_shape=tuple(tile), n_tiles=n_tiles,
                n_memcpys=n_memcpys, contiguous_run=run, utilization=util,
                host_cost_s=_host_cost(n_memcpys, spec.nbytes)))
    return choices


def _best_tile_for_cut(spec, cut, max_tile_elems, reduce_dim, quantum):
    """Largest tile that fits when cutting exactly the dims in ``cut``."""
    tile = list(spec.shape)
    budget = max_tile_elems
    fixed = 1
    for i, d in enumerate(spec.shape):
        if i not in cut:
            fixed *= d
    if fixed > max_tile_elems:
        return None
    room = max_tile_elems // fixed
    # distribute ``room`` across cut dims: reduce dim first (functional-unit
    # quantum), then innermost-first to preserve trailing contiguity (the
    # paper's DimHW-over-DimCH effect)
    for i in sorted(cut, key=lambda i: (-(spec.dims[i] == (reduce_dim or "")),
                                        -i)):
        d = spec.shape[i]
        t = min(d, room)
        if reduce_dim and spec.dims[i] == reduce_dim and t < d:
            t = max(quantum * (t // quantum), min(d, quantum))
        t = max(1, t)
        tile[i] = t
        room = max(1, room // max(t, 1))
    if math.prod(tile) > max_tile_elems:
        # shrink the largest cut dim
        for i in sorted(cut, key=lambda i: -tile[i]):
            while math.prod(tile) > max_tile_elems and tile[i] > 1:
                tile[i] = max(1, tile[i] // 2)
    return tuple(tile)


def choose_tiling(spec: TensorSpec, max_tile_elems: int,
                  reduce_dim: Optional[str] = None,
                  w_util: float = 1.0, w_host: float = 1.0
                  ) -> TilingChoice:
    """The optimizer: exhaustively score the narrow strategy space.

    Score = utilization - normalized host cost (both effects the paper
    demonstrates; weights let case studies ablate them)."""
    cands = enumerate_tilings(spec, max_tile_elems, reduce_dim)
    if not cands:
        raise ValueError(f"no feasible tiling for {spec} within "
                         f"{max_tile_elems} elems")
    worst_host = max(c.host_cost_s for c in cands) or 1.0

    def score(c: TilingChoice) -> float:
        return w_util * c.utilization - w_host * (c.host_cost_s / worst_host)

    return max(cands, key=score)


# ---------------------------------------------------------------------------
# matmul tiling -> Pallas BlockSpec block shapes


@dataclass(frozen=True)
class MatmulTiling:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    util_m: float
    util_n: float
    util_k: float


def choose_matmul_tiling(M: int, N: int, K: int, dtype_bytes: int = 2,
                         vmem_budget: int = VMEM_BYTES // 2) -> MatmulTiling:
    """Block shapes for the NVDLA-adapted Pallas matmul kernel.

    Working set per grid step = bm*bk + bk*bn + bm*bn (acc fp32).  Blocks are
    MXU-aligned (multiples of 128 where the dim allows); the K (reduction)
    dimension mirrors NVDLA's channel-block loop.
    """
    def align(x, dim):
        if dim < MXU_DIM:
            return max(SUBLANE, 1 << (dim - 1).bit_length())  # pow2 pad
        return min(x - x % MXU_DIM, dim) or MXU_DIM

    best = None
    for bm in (128, 256, 512):
        for bn in (128, 256, 512):
            for bk in (128, 256, 512, 1024, 2048):
                tbm, tbn, tbk = (min(bm, M), min(bn, N), min(bk, K))
                ws = (tbm * tbk + tbk * tbn) * dtype_bytes + tbm * tbn * 4
                if ws > vmem_budget:
                    continue
                # prefer larger K blocks (fewer partial-sum round trips),
                # then larger tiles overall
                key = (tbk, tbm * tbn, -(tbm + tbn))
                if best is None or key > best[0]:
                    best = (key, MatmulTiling(
                        bm=tbm, bn=tbn, bk=tbk, vmem_bytes=ws,
                        util_m=_mxu_util(tbm), util_n=_mxu_util(tbn),
                        util_k=_mxu_util(tbk)))
    if best is None:
        return MatmulTiling(min(128, M), min(128, N), min(128, K),
                            0, 1.0, 1.0, 1.0)
    return best[1]


def _mxu_util(t: int) -> float:
    if t >= MXU_DIM:
        return (t // MXU_DIM) * MXU_DIM / t
    return t / MXU_DIM
