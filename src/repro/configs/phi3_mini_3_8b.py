"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064, RoPE + SwiGLU. [arXiv:2404.14219]
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="phi3_mini_3_8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_064,
    activation="swiglu",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="phi3_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab=256,
    activation="swiglu",
)
