"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000, llama2-style. [arXiv:2401.02385; hf]
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="tinyllama_1_1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32_000,
    activation="swiglu",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="tinyllama_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    activation="swiglu",
)
