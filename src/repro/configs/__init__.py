"""Architecture config registry.

``get_config(arch_id)`` returns the FULL assigned config; ``get_smoke_config``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.core.config import ModelConfig

ARCH_IDS: List[str] = [
    "whisper_small",
    "granite_moe_1b_a400m",
    "deepseek_v2_lite_16b",
    "gemma3_1b",
    "tinyllama_1_1b",
    "gemma_2b",
    "phi3_mini_3_8b",
    "internvl2_26b",
    "zamba2_2_7b",
    "falcon_mamba_7b",
]

# accept dashed ids on the CLI
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def _module(arch_id: str):
    arch_id = _ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).FULL


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
