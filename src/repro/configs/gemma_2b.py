"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256. [arXiv:2403.08295; hf]
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="gemma_2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab=256_000,
    activation="geglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="gemma_2b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab=256,
    activation="geglu",
    tie_embeddings=True,
)
