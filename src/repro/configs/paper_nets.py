"""The paper's own workloads (Table III), used by the SMAUG case-study
benchmarks.  These are small CNN/MLP image classifiers; convolutions lower to
im2col matmuls on the MXU (the conv engine adaptation — see DESIGN.md §2).

Each net is described as a list of ops for the repro.core.graph API:
  ("conv", out_ch, kh, kw, stride)  ("pool", k)  ("fc", out)  ("bn",)
"""
from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class PaperNet:
    name: str
    input_shape: Tuple[int, int, int]   # H, W, C
    layers: Tuple[tuple, ...]
    n_classes: int


MINERVA = PaperNet(
    "minerva", (28, 28, 1),
    (("fc", 256), ("fc", 256), ("fc", 256)), 10)

LENET5 = PaperNet(
    "lenet5", (28, 28, 1),
    (("conv", 32, 3, 3, 1), ("conv", 32, 3, 3, 1), ("pool", 2), ("fc", 128)),
    10)

CNN10 = PaperNet(
    "cnn10", (32, 32, 3),
    (("conv", 32, 3, 3, 1), ("bn",), ("conv", 32, 3, 3, 1), ("pool", 2),
     ("conv", 64, 3, 3, 1), ("bn",), ("conv", 64, 3, 3, 1), ("pool", 2),
     ("fc", 512)),
    10)

VGG16_CIFAR = PaperNet(
    "vgg16", (32, 32, 3),
    (("conv", 64, 3, 3, 1), ("conv", 128, 3, 3, 1), ("pool", 2),
     ("conv", 128, 3, 3, 1), ("conv", 128, 3, 3, 1), ("pool", 2),
     ("conv", 256, 3, 3, 1), ("conv", 256, 3, 3, 1), ("conv", 256, 3, 3, 1), ("pool", 2),
     ("conv", 512, 3, 3, 1), ("conv", 512, 3, 3, 1), ("conv", 512, 3, 3, 1), ("pool", 2),
     ("fc", 512)),
    10)

ELU16 = PaperNet(
    "elu16", (32, 32, 3),
    (("conv", 192, 3, 3, 1), ("pool", 2),
     ("conv", 192, 1, 1, 1), ("conv", 240, 2, 2, 1), ("pool", 2),
     ("conv", 240, 1, 1, 1), ("conv", 260, 2, 2, 1), ("pool", 2),
     ("conv", 260, 1, 1, 1), ("conv", 280, 2, 2, 1), ("pool", 2),
     ("conv", 280, 1, 1, 1), ("conv", 300, 2, 2, 1), ("pool", 2),
     ("conv", 300, 1, 1, 1)),
    100)

PAPER_NETS = {n.name: n for n in (MINERVA, LENET5, CNN10, VGG16_CIFAR, ELU16)}
