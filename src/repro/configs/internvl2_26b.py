"""internvl2-26b [vlm] — InternLM2-20B backbone: 48L d_model=6144 48H
(GQA kv=8) d_ff=16384 vocab=92553.  The InternViT vision tower is a STUB:
input_specs() provides precomputed patch embeddings (B, n_patches, d_model).
[arXiv:2404.16821; hf]
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="internvl2_26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab=92_553,
    activation="swiglu",
    rope_theta=1_000_000.0,
    n_patches=256,
)

SMOKE = ModelConfig(
    name="internvl2_smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    activation="swiglu",
    n_patches=8,
)
