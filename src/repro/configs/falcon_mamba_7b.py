"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free mamba1,
ssm_state=16, vocab=65024. [arXiv:2410.05355]
"""
from repro.core.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="falcon_mamba_7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65_024,
    activation="silu",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
)

SMOKE = ModelConfig(
    name="falcon_mamba_smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=256,
    activation="silu",
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2, version=1),
)
