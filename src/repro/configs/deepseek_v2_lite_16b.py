"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H MLA(kv_lora=512)
d_ff_expert=1408, 64 routed experts top-6 + 2 shared. [arXiv:2405.04434; hf]

Note: the assignment line mentions "160 routed" which is DeepSeek-V2-*full*;
the named model V2-Lite has 64 routed + 2 shared (HF config), which we follow
(also consistent with the line's own "MoE 64e top-6").  Recorded in DESIGN.md.
"""
from repro.core.config import MLAConfig, MoEConfig, ModelConfig

FULL = ModelConfig(
    name="deepseek_v2_lite_16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    activation="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
)

SMOKE = ModelConfig(
    name="deepseek_v2_lite_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab=256,
    activation="swiglu",
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff_expert=48),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
)
