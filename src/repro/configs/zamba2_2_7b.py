"""zamba2-2.7b [hybrid] — 54 Mamba2 blocks d_model=2560 + a SHARED attention
block (32H kv=32, d_ff=10240) inserted every 6 mamba blocks, ssm_state=64.
[arXiv:2411.15242; hf]
"""
from repro.core.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="zamba2_2_7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10_240,
    vocab=32_000,
    activation="gelu",
    rope_theta=10_000.0,
    hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, version=2,
                  n_heads=80, head_dim=64, chunk=256),
)

SMOKE = ModelConfig(
    name="zamba2_smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    activation="gelu",
    hybrid_attn_every=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=2,
                  n_heads=8, head_dim=16, chunk=32),
)
