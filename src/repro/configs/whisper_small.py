"""whisper-small [audio] — enc-dec transformer backbone, conv frontend stub.

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865. [arXiv:2212.04356]
The audio frontend (mel + 2x conv) is a STUB: input_specs() provides
precomputed frame embeddings of shape (B, 1500, d_model).
"""
from repro.core.config import EncoderConfig, ModelConfig

FULL = ModelConfig(
    name="whisper_small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51_865,
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    encoder=EncoderConfig(n_layers=12, n_ctx=1500),
)

SMOKE = ModelConfig(
    name="whisper_small_smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=0.0,
    encoder=EncoderConfig(n_layers=2, n_ctx=16),
)
