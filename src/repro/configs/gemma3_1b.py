"""gemma3-1b [dense] — 26L d_model=1152 4H (kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt]
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="gemma3_1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262_144,
    activation="geglu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    window=512,              # gemma3 local window
    local_global_ratio=5,    # 5 local : 1 global
    max_seq=1_048_576,
)

SMOKE = ModelConfig(
    name="gemma3_smoke",
    family="dense",
    n_layers=3,              # exercises local/global mix (ratio 2)
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    activation="geglu",
    tie_embeddings=True,
    window=8,
    local_global_ratio=2,
)
