"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff_expert=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.core.config import MoEConfig, ModelConfig

FULL = ModelConfig(
    name="granite_moe_1b_a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,            # == d_ff_expert; all MLP capacity is in experts
    vocab=49_155,
    activation="swiglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=32, top_k=8, n_shared=0, d_ff_expert=512),
)

SMOKE = ModelConfig(
    name="granite_moe_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=32,
    vocab=256,
    activation="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff_expert=32),
)
