"""Sharding rule engine: divisibility guards, SP fallback, cell coverage."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.config import SHAPES, SHAPE_BY_NAME
from repro.dist.sharding import Rules, default_rules, rules_for


def _mesh2(d=1, m=1):
    devs = np.asarray(jax.devices()[:d * m])
    if devs.size < d * m:
        pytest.skip("not enough devices")
    return jax.sharding.Mesh(devs.reshape(d, m), ("data", "model"))


def test_divisibility_guard_falls_back_to_replicated():
    mesh = _mesh2(1, 1)
    rules = default_rules(mesh)
    # axis of size 1 -> never sharded
    assert rules.spec_for(("vocab", "d_model"), (100, 64)) == P()


def test_spec_construction():
    mesh = _mesh2(1, 1)
    r = Rules(table={"batch": "data", "d_ff": "model"}, mesh=mesh)
    spec = r.spec_for(("batch", None, "d_ff"), (8, 4, 16))
    assert spec == P()  # both axes size 1 -> unsharded


def test_rules_for_long_context_uses_sequence_parallel():
    cfg = get_config("gemma3_1b")
    shape = SHAPE_BY_NAME["long_500k"]

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    r = rules_for(cfg, shape, FakeMesh())
    assert r.table["batch"] is None          # batch=1 cannot shard
    assert r.table["kv_seq"] == "data"       # SP takes over
    # MQA fallback: kv head_dim sharded instead of kv_heads
    assert r.table["head_dim"] == "model"


def test_rules_for_train_shards_batch():
    cfg = get_config("tinyllama_1_1b")
    shape = SHAPE_BY_NAME["train_4k"]

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    r = rules_for(cfg, shape, FakeMesh())
    assert r.table["batch"] == ("pod", "data")
    assert r.table["heads_x_dim"] == "model"   # 32 % 16 == 0
    assert r.table["kv_heads_x_dim"] is None   # 4 % 16 != 0 -> replicated


def test_all_cells_have_consistent_rules():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            r = rules_for(cfg, shape, FakeMesh())
            assert isinstance(r.table, dict)
