"""Runtime-scheduler simulation: invariants (hypothesis) + paper behaviors."""
import threading
import time

from _hyp import given, settings, st

from repro.core.scheduler import ThreadPool, TileTask, simulate

task_st = st.lists(
    st.tuples(st.floats(1e-6, 1e-2), st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=40)


@given(tasks=task_st, n=st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_makespan_bounds(tasks, n):
    ts = [TileTask(f"t{i}", duration=d, affinity=a)
          for i, (d, a) in enumerate(tasks)]
    tl = simulate(ts, n)
    total = sum(t.duration for t in ts)
    longest = max(t.duration for t in ts)
    # work conservation: makespan within [max(W/n, longest), W]
    assert tl.makespan <= total + 1e-12
    assert tl.makespan >= max(total / n, longest) - 1e-12
    # affinity: all tasks with the same key ran on one worker
    for key in ("a", "b"):
        workers = {e.worker for e in tl.events
                   if e.kind == "compute" and any(
                       t.name == e.name and t.affinity == key for t in ts)}
        assert len(workers) <= 1


def test_affinity_serializes_reduction_tiles():
    """Paper Fig 14: tiles whose partials reduce in place share one queue,
    capping speedup below worker count."""
    ts = [TileTask(f"r{i}", duration=1e-3, affinity="out0") for i in range(8)]
    tl = simulate(ts, 8)
    assert abs(tl.makespan - 8e-3) < 1e-9
    ts = [TileTask(f"r{i}", duration=1e-3) for i in range(8)]
    tl = simulate(ts, 8)
    assert abs(tl.makespan - 1e-3) < 1e-9


def test_multi_worker_scaling_saturates():
    """Fig 12 shape: speedup scales until tile-level parallelism runs out."""
    ts = [TileTask(f"t{i}", duration=1e-3) for i in range(8)]
    m1 = simulate(ts, 1).makespan
    m4 = simulate(ts, 4).makespan
    m16 = simulate(ts, 16).makespan
    assert m1 / m4 >= 3.9
    assert abs(m16 - m4 * 4 / 8) < 2e-3 or m16 <= m4  # no gain past 8 tiles
    assert m16 >= 1e-3


def test_dependencies_respected():
    ts = [TileTask("a", duration=1e-3),
          TileTask("b", duration=1e-3, deps=("a",)),
          TileTask("c", duration=1e-3, deps=("b",))]
    tl = simulate(ts, 4)
    assert abs(tl.makespan - 3e-3) < 1e-9


def test_thread_pool_parallel_and_correct():
    pool = ThreadPool(4)
    try:
        results = pool.map(lambda x: x * x, list(range(32)))
        assert results == [x * x for x in range(32)]
        # GIL-releasing workloads actually overlap
        def sleepy(_):
            time.sleep(0.02)
            return threading.current_thread().name
        t0 = time.time()
        names = pool.map(sleepy, range(8))
        elapsed = time.time() - t0
        assert elapsed < 8 * 0.02 * 0.9  # faster than serial
        assert len(set(names)) > 1       # multiple workers participated
    finally:
        pool.shutdown()
