"""Hypothesis import guard.

The container may not ship ``hypothesis``; importing it unguarded used to
kill collection of four whole test modules.  Import ``given/settings/st``
from here instead: with hypothesis present they are the real thing, without
it the property tests are skipped while the plain tests in the same module
still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: any strategy call returns
        None — the decorated test is skipped before they are ever drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
