"""Pluggable per-op cost backends (``repro.sim.backends``).

Contract layers:

* **roofline bit-identity** — ``cost_backend=None`` (the default),
  an explicit ``RooflineBackend()`` and the ``"roofline"`` name are all
  bit-identical on random DAGs and chains, across the event loop, the
  fused typed-array core and the chain fast path (plus a hypothesis
  property sweep), so the backend seam cannot perturb the pre-backend
  engine.
* **systolic** — utilization in (0, 1], exactly 1.0 on array-aligned
  tiles, fill/drain exposure without double buffering, im2col traffic
  for conv tiles; a degenerate 1x1 array with im2col off degenerates to
  roofline bit-exactly.
* **table** — reproduces its own measured samples exactly, log-log
  interpolates a power law exactly between them, clamps outside the
  range, and prices identically through every engine path.
* **calibration fit** — ``fit_linear_cost`` recovers known synthetic
  (peak, bandwidth, overhead) parameters; ``repro.kernels.calibrate``
  reports ~0 fitted MAPE on synthetic linear-law records.
* **restrictions** — the analytic DSE layer (``CostModel``,
  ``chain_params_for``, ``batched``/``optimize``) refuses non-roofline
  backends with ``Unsupported`` instead of mispricing them.
* **bugfix regressions** — ``costmodel._has_jax`` warns exactly once on
  a broken (not merely absent) jax; ``repro.kernels.ops`` resolves
  interpret per call, not at import; GQA attention passes KV to the
  kernel at its native ``(B, Hkv, S, D)`` instead of materializing the
  broadcast.
"""
import dataclasses
import math
import random
import sys
import warnings

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.apps.paper_graphs import build_paper_graph
from repro.configs.paper_nets import PAPER_NETS
from repro.sim import backends, costmodel, engine, hw, ir
from repro.sim.sweep import batched, optimize, sweep

CONFIGS = [
    engine.EngineConfig(),
    engine.EngineConfig(n_workers=4, interface="hbm", hbm_ports=2),
    engine.EngineConfig(n_workers=8, interface="dma", hbm_ports=1),
    engine.EngineConfig(n_workers=3, interface="acp", hbm_ports=0.5,
                        host_dispatch_s=1e-6, host_bw=20e9, host_threads=4),
    engine.EngineConfig(n_workers=2, interface="ideal",
                        overlap_transfers=True, host_floor_s=1e-4),
]

SYSTOLIC = backends.SystolicBackend()
TABLE = backends.TableBackend(samples=(("", 1e6, 1e-4), ("", 1e9, 1e-2)))


def assert_bit_identical(a, b):
    assert a.makespan == b.makespan
    assert a.breakdown == b.breakdown
    assert a.roofline == b.roofline
    assert a.energy == b.energy
    assert a.timeline.events == b.timeline.events


def random_program(rng: random.Random, n: int, chain: bool) -> ir.Program:
    """Random DAG/chain with tile/op_kind metadata on a subset of ops —
    the shapes every backend must price."""
    ops = []
    for i in range(n):
        if chain:
            deps = (f"op{i-1}",) if i else ()
        else:
            deps = tuple(f"op{j}" for j in range(max(0, i - 6), i)
                         if rng.random() < 0.35)
        kind = rng.choice(["", "", "matmul", "conv"])
        tile = ((rng.choice([32, 100, 128, 256]),
                 rng.choice([32, 100, 128, 256]),
                 rng.choice([9, 64, 576])) if kind else ())
        ops.append(ir.CostedOp(
            name=f"op{i}",
            flops=rng.choice([0.0, 1e6, 5e8, 2e9]),
            dot_flops=rng.choice([0.0, 1e6, 4e8]),
            bytes_in=rng.choice([0.0, 1e5, 3e7, 2e8]),
            bytes_out=rng.choice([0.0, 1e5, 2e6]),
            transcendentals=rng.choice([0.0, 1e5]),
            deps=deps,
            phase=f"ph{i % 3}",
            duration_s=rng.choice([None, None, None, 1e-4]),
            tile=tile, op_kind=kind))
    return ir.Program(ops, name="rand-backend")


def _with(cfg, backend):
    return dataclasses.replace(cfg, cost_backend=backend)


# ---------------------------------------------------------------------------
# roofline bit-identity: the tentpole's "don't move the needle" gate


@pytest.mark.parametrize("chain", [False, True])
@pytest.mark.parametrize("spec", [backends.RooflineBackend(), "roofline"])
def test_explicit_roofline_bit_identical_to_default(chain, spec):
    rng = random.Random(515 + chain)
    for _ in range(10):
        prog = random_program(rng, rng.randint(1, 60), chain)
        for cfg in CONFIGS:
            base = engine.run(prog, cfg)
            assert_bit_identical(engine.run(prog, _with(cfg, spec)), base)


@pytest.mark.parametrize("fast,fuse", [(True, None), (False, True),
                                       (False, False)])
def test_explicit_roofline_every_engine_path(fast, fuse):
    rng = random.Random(99)
    prog = random_program(rng, 40, chain=True)
    for cfg in CONFIGS:
        base = engine.run(prog, cfg, fast=fast, fuse=fuse)
        got = engine.run(prog, _with(cfg, backends.RooflineBackend()),
                         fast=fast, fuse=fuse)
        assert_bit_identical(got, base)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40), st.booleans())
def test_roofline_identity_hypothesis(seed, n, chain):
    rng = random.Random(seed)
    prog = random_program(rng, n, chain)
    cfg = CONFIGS[seed % len(CONFIGS)]
    assert_bit_identical(
        engine.run(prog, _with(cfg, backends.RooflineBackend())),
        engine.run(prog, cfg))


# ---------------------------------------------------------------------------
# systolic


def test_systolic_utilization_bounds_and_alignment():
    rng = random.Random(3)
    for db in (True, False):
        bk = backends.SystolicBackend(double_buffered=db)
        for _ in range(200):
            tile = (rng.randint(1, 1000), rng.randint(1, 1000),
                    rng.randint(1, 4096))
            u = bk.utilization(tile)
            assert 0.0 < u <= 1.0, (tile, db)
    aligned = backends.SystolicBackend(rows=128, cols=128)
    for m, n in ((128, 128), (256, 128), (512, 384), (128, 1024)):
        assert aligned.utilization((m, n, 64)) == 1.0
    # partial folds idle PEs: exact closed form
    assert aligned.utilization((100, 100, 64)) == \
        (100 / 128) * (100 / 128)
    # no / short tile metadata -> full utilization (macro-op fallback)
    assert aligned.utilization(()) == 1.0
    assert aligned.utilization((5,)) == 1.0


def test_systolic_fill_drain_exposed_without_double_buffering():
    db = backends.SystolicBackend(double_buffered=True)
    nodb = backends.SystolicBackend(double_buffered=False)
    tile = (128, 128, 64)
    assert nodb.utilization(tile) == \
        db.utilization(tile) * 64 / (64 + 128 + 128 - 2)
    assert nodb.utilization(tile) < db.utilization(tile)


def test_systolic_op_time_contract():
    eff = engine.EngineConfig()
    bk = backends.SystolicBackend()
    op = ir.CostedOp("x", flops=1e9, tile=(100, 100, 64),
                     op_kind="matmul")
    assert bk.op_time(op, eff) == pytest.approx(
        1e9 / (eff.peak_flops * bk.utilization((100, 100, 64))))
    # duration_s always wins; zero flops is free
    assert bk.op_time(dataclasses.replace(op, duration_s=3e-5), eff) == 3e-5
    assert bk.op_time(ir.CostedOp("z", flops=0.0), eff) == 0.0


def test_systolic_im2col_charges_conv_patch_traffic():
    eff = engine.EngineConfig()
    tile = (256, 128, 576)                      # M x N x K patch matrix
    conv = ir.CostedOp("c", flops=1e9, bytes_in=1e5, tile=tile,
                       op_kind="conv")
    on = backends.SystolicBackend()
    off = backends.SystolicBackend(im2col=False)
    extra = (4.0 * tile[0] * tile[2] - 1e5) / eff.hbm_bw
    assert on.op_time(conv, eff) == pytest.approx(
        off.op_time(conv, eff) + extra)
    # matmul tiles never pay im2col
    mm = dataclasses.replace(conv, op_kind="matmul")
    assert on.op_time(mm, eff) == off.op_time(mm, eff)


def test_systolic_never_faster_than_roofline_on_real_graph():
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
    cfg = engine.EngineConfig(n_workers=4)
    roof = engine.run(prog, cfg).makespan
    sys_ = engine.run(prog, _with(cfg, SYSTOLIC)).makespan
    assert sys_ >= roof
    # a 1x1 array is always perfectly utilized: with im2col off the
    # systolic model degenerates to the roofline bit-exactly
    degenerate = backends.SystolicBackend(rows=1, cols=1, im2col=False)
    assert_bit_identical(engine.run(prog, _with(cfg, degenerate)),
                         engine.run(prog, cfg))


def test_from_graph_attaches_tile_metadata():
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
    kinds = {op.op_kind for op in prog.ops}
    assert "conv" in kinds and "matmul" in kinds
    for op in prog.ops:
        if op.op_kind:
            assert len(op.tile) == 3 and all(d > 0 for d in op.tile), op
        else:
            assert op.tile == ()


# ---------------------------------------------------------------------------
# table


def test_table_round_trips_its_samples():
    samples = (("matmul", 1e6, 3.1e-4), ("matmul", 1e8, 8.9e-3),
               ("conv", 2e6, 5.5e-4))
    bk = backends.TableBackend(samples=samples)
    eff = engine.EngineConfig()
    for kind, flops, secs in samples:
        assert bk.op_time(
            ir.CostedOp("o", flops=flops, op_kind=kind), eff) == secs
    # unknown kind falls back to the pooled table — still exact on a
    # sampled flop count that is unique across the pool
    assert bk.op_time(
        ir.CostedOp("o", flops=1e8, op_kind="mystery"), eff) == 8.9e-3


def test_table_interpolates_power_law_exactly():
    # t = c * f^0.8 sampled at two points: log-log interpolation is exact
    # at any flops between them
    c, a = 3e-10, 0.8
    f1, f2 = 1e6, 1e10
    bk = backends.TableBackend(samples=(("", f1, c * f1**a),
                                        ("", f2, c * f2**a)))
    eff = engine.EngineConfig()
    for f in (1e7, 1e8, 31e8):
        got = bk.op_time(ir.CostedOp("o", flops=f), eff)
        assert got == pytest.approx(c * f**a, rel=1e-12)
    # clamped outside the measured range
    assert bk.op_time(ir.CostedOp("o", flops=1e12), eff) == \
        pytest.approx(c * f2**a, rel=1e-12)
    assert bk.op_time(ir.CostedOp("o", flops=10.0), eff) == \
        pytest.approx(c * f1**a, rel=1e-12)


def test_table_rejects_empty():
    with pytest.raises(ValueError):
        backends.TableBackend(samples=())


@pytest.mark.parametrize("backend", [SYSTOLIC, TABLE])
def test_non_roofline_engine_paths_agree(backend):
    """fast chain path, dict event loop and fused typed-array core all
    price a non-roofline backend identically."""
    rng = random.Random(44)
    chain = random_program(rng, 30, chain=True)
    dag = random_program(rng, 40, chain=False)
    for cfg in CONFIGS[:3]:
        cfgb = _with(cfg, backend)
        fast = engine.run(chain, cfgb, fast=True)
        slow = engine.run(chain, cfgb, fast=False, fuse=False)
        fused = engine.run(chain, cfgb, fast=False, fuse=True)
        assert_bit_identical(fast, slow)
        assert_bit_identical(fast, fused)
        assert_bit_identical(engine.run(dag, cfgb, fuse=True),
                             engine.run(dag, cfgb, fuse=False))


def test_device_level_backend_override():
    """Device.cost_backend=None inherits the config; a per-device backend
    overrides it — priced like the flat config carrying that backend."""
    rng = random.Random(77)
    prog = random_program(rng, 25, chain=False)
    cfg = engine.EngineConfig(n_workers=2)
    topo = hw.SoCTopology(
        devices=(hw.Device("acc0", cost_backend=SYSTOLIC),
                 hw.Device("acc1", cost_backend=SYSTOLIC)),
        links=(hw.Link("hbm", bandwidth=cfg.hbm_bw,
                       ports=cfg.hbm_ports),),
        name="sys-devs")
    via_device = engine.run(prog, dataclasses.replace(cfg, topology=topo))
    via_config = engine.run(prog, _with(cfg, SYSTOLIC))
    assert_bit_identical(via_device, via_config)


# ---------------------------------------------------------------------------
# calibration fit


def test_fit_recovers_synthetic_parameters():
    rng = np.random.default_rng(5)
    f = rng.uniform(1e6, 1e10, 40)
    b = rng.uniform(1e4, 1e8, 40)
    peak, bw, c = 3.7e12, 6.1e10, 2.4e-5
    t = f / peak + b / bw + c
    fit = backends.fit_linear_cost(f, b, t)
    assert fit["peak_flops_eff"] == pytest.approx(peak, rel=1e-6)
    assert fit["bw_eff"] == pytest.approx(bw, rel=1e-6)
    assert fit["overhead_s"] == pytest.approx(c, rel=1e-6)
    assert fit["mape"] < 1e-9


def test_fit_drops_vanished_terms():
    # overhead-dominated samples whose time *decreases* with flops: the
    # unconstrained fit puts a negative coefficient on the flops column,
    # which the non-negativity projection must drop (rate -> inf)
    rng = np.random.default_rng(6)
    f = np.geomspace(1e6, 1e9, 12)
    b = rng.uniform(1e4, 1e6, 12)
    t = 4.2e-4 - 1e-16 * f
    fit = backends.fit_linear_cost(f, b, t)
    assert fit["peak_flops_eff"] == math.inf
    assert fit["overhead_s"] == pytest.approx(4.2e-4, rel=1e-3)
    assert fit["mape"] < 1e-3


def test_calibrate_fit_on_synthetic_records():
    from repro.kernels import calibrate
    rng = np.random.default_rng(11)
    peak, bw, c = 8e11, 3e10, 1e-5
    records = []
    for kernel in ("matmul", "attention", "mamba"):
        for _ in range(6):
            f = float(rng.uniform(1e7, 1e10))
            b = float(rng.uniform(1e5, 1e8))
            records.append({"kernel": kernel, "kind": kernel,
                            "shape": [1], "flops": f, "bytes": b,
                            "measured_s": f / peak + b / bw + c})
    fits = calibrate.calibrate(records)
    for kernel, fit in fits.items():
        assert fit["fitted_mape"] < 1e-9, kernel
        assert fit["fitted"]["peak_flops_eff"] == pytest.approx(
            peak, rel=1e-5)
        assert fit["fitted_mape"] < fit["roofline_mape"]
        assert fit["table_max_rel_err"] == 0.0
    report = calibrate.build_report(
        records, {"backend": "synthetic", "interpret": False,
                  "grid": "synthetic", "repeat": 1}, fits)
    assert report["n_improved"] == 3


def test_mape_and_table_from_samples():
    assert backends.mape([2.0, 2.0], [1.0, 4.0]) == pytest.approx(0.75)
    bk = backends.table_from_samples(
        [{"kind": "matmul", "flops": 1e6, "measured_s": 2e-4}])
    assert bk.op_time(
        ir.CostedOp("o", flops=1e6, op_kind="matmul"),
        engine.EngineConfig()) == 2e-4


# ---------------------------------------------------------------------------
# registry / config plumbing


def test_get_backend_resolution_and_errors():
    assert backends.get_backend(None) is backends.ROOFLINE
    assert backends.get_backend("roofline") is backends.ROOFLINE
    assert isinstance(backends.get_backend("systolic"),
                      backends.SystolicBackend)
    assert backends.get_backend(SYSTOLIC) is SYSTOLIC
    with pytest.raises(ValueError, match="unknown cost backend"):
        backends.get_backend("scale-sim")
    with pytest.raises(TypeError):
        backends.get_backend(42)
    assert isinstance(SYSTOLIC, backends.CostBackend)


def test_configs_with_backends_stay_hashable():
    for bk in (SYSTOLIC, TABLE, backends.RooflineBackend(), "systolic"):
        cfg = engine.EngineConfig(cost_backend=bk)
        assert hash(cfg) == hash(dataclasses.replace(cfg))


def test_analytic_layer_refuses_non_roofline():
    rng = random.Random(8)
    chain = random_program(rng, 12, chain=True)
    for bk in (SYSTOLIC, TABLE, "systolic"):
        cfg = _with(engine.EngineConfig(), bk)
        with pytest.raises(costmodel.Unsupported, match="backend"):
            costmodel.CostModel(chain, cfg)
        with pytest.raises(costmodel.Unsupported, match="backend"):
            costmodel.chain_params_for(cfg)
        with pytest.raises(costmodel.Unsupported):
            batched(chain, [cfg])
        with pytest.raises(costmodel.Unsupported):
            optimize(chain, {"peak_flops": (1e13, 1e14)},
                     base_config=cfg)
    # the explicit roofline instance is fully supported and exact
    cfgs = [_with(c, backends.RooflineBackend()) for c in CONFIGS[:2]]
    bs = batched(chain, cfgs, top_k=0)
    exact = [r.makespan for r in sweep(chain, cfgs)]
    np.testing.assert_allclose(bs.lower, exact, rtol=1e-12)


def test_sweep_batched_rejects_mixed_backends():
    rng = random.Random(9)
    chain = random_program(rng, 8, chain=True)
    cfgs = [engine.EngineConfig(), _with(engine.EngineConfig(), SYSTOLIC)]
    with pytest.raises(costmodel.Unsupported, match="backend"):
        batched(chain, cfgs)


def test_serving_step_table_degrades_gracefully():
    """StepCostTable falls back from the analytic chain params to
    backend-aware per-op pricing for non-roofline configs."""
    from repro.serve.policy import ContinuousBatching
    from repro.sim import serving
    from repro.configs.gemma_2b import FULL as GEMMA_2B
    trace = serving.poisson_trace(40, 80.0, prompt_len=64, output_len=8,
                                  seed=3)
    cfg = _with(engine.EngineConfig(), SYSTOLIC)
    res = serving.simulate_serving(GEMMA_2B, trace, ContinuousBatching(),
                                   config=cfg)
    assert res.makespan_s > 0.0


# ---------------------------------------------------------------------------
# bugfix regressions (the three satellites)


def test_has_jax_warns_once_on_broken_install(monkeypatch):
    import builtins
    monkeypatch.setattr(costmodel, "_JAX_PROBE_WARNED", False)
    real_import = builtins.__import__

    def broken(name, *a, **k):
        # a jax whose import *crashes* (broken jaxlib, bad wheel) — the
        # case the old blanket `except Exception: return False`
        # swallowed silently
        if name == "jax":
            raise RuntimeError("mock: jaxlib ABI mismatch")
        return real_import(name, *a, **k)
    monkeypatch.setattr(builtins, "__import__", broken)
    with pytest.warns(RuntimeWarning, match="jax import failed with "
                                            "RuntimeError"):
        assert costmodel._has_jax() is False
    with warnings.catch_warnings():
        warnings.simplefilter("error")              # second probe: silent
        assert costmodel._has_jax() is False


def test_has_jax_quiet_when_absent_or_present(monkeypatch):
    jax = pytest.importorskip("jax")
    monkeypatch.setattr(costmodel, "_JAX_PROBE_WARNED", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert costmodel._has_jax() is True         # healthy install
        # merely *absent* (ModuleNotFoundError for jax itself) stays
        # silent: None in sys.modules raises exactly that
        monkeypatch.setitem(sys.modules, "jax", None)
        assert costmodel._has_jax() is False
    assert costmodel._JAX_PROBE_WARNED is False
    del jax


def test_interpret_resolved_per_call(monkeypatch):
    jax = pytest.importorskip("jax")
    from repro.kernels import ops
    seen = []
    monkeypatch.setattr(ops, "_matmul",
                        lambda a, b, **kw: seen.append(kw) or a)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    ops.matmul(None, None)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    ops.matmul(None, None)
    # the regression: an import-time INTERPRET constant froze the first
    # answer; per-call resolution must see the backend flip
    assert [kw["interpret"] for kw in seen] == [False, True]
    ops.matmul(None, None, interpret=False)         # explicit kw wins
    assert seen[-1]["interpret"] is False


def test_gqa_kv_reaches_kernel_unmaterialized(monkeypatch):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels import ops
    B, H, Hkv, S, D = 1, 4, 2, 64, 16
    seen = {}

    def spy(q, k, v, **kw):
        seen["k"], seen["v"] = k.shape, v.shape
        return q
    monkeypatch.setattr(ops, "_flash", spy)
    q = jnp.zeros((B, H, S, D))
    kv = jnp.zeros((B, Hkv, S, D))
    ops.flash_attention(q, kv, kv)
    # the regression: the wrapper used to jnp.broadcast_to KV to the full
    # (B, H, S, D) before the kernel ever saw it
    assert seen["k"] == (B, Hkv, S, D)
    assert seen["v"] == (B, Hkv, S, D)


def test_gqa_native_kernel_matches_repeated_kv_reference():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels import ops
    B, H, Hkv, S, D = 1, 4, 2, 128, 16
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, S, D), jnp.float32)
    k = jax.random.normal(kk, (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(kv_, (B, Hkv, S, D), jnp.float32)
    native = ops.flash_attention(q, k, v, bq=64, bk=64)
    repeated = ops.flash_attention(q, jnp.repeat(k, H // Hkv, axis=1),
                                   jnp.repeat(v, H // Hkv, axis=1),
                                   bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(native), np.asarray(repeated),
                               rtol=1e-5, atol=1e-5)
