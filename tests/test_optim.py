"""Optimizers, schedules, and the camera ISP / energy models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, sgd_init, sgd_update)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, opt = adamw_update(g, opt, params, lr=5e-2, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_sgd_momentum_minimizes():
    params = {"w": jnp.asarray([4.0])}
    opt = sgd_init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt = sgd_update(g, opt, params, lr=1e-2)
    assert abs(float(params["w"][0])) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(5)) == pytest.approx(5e-4, rel=1e-5)


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_camera_isp_shapes_and_range():
    from repro.apps.camera import camera_pipeline
    raw = np.random.default_rng(0).random((64, 96), dtype=np.float32)
    rgb, dnn_in = camera_pipeline(raw, dnn_hw=(16, 16))
    assert rgb.shape == (64, 96, 3)
    assert dnn_in.shape == (16, 16, 3)
    assert float(jnp.min(rgb)) >= 0.0 and float(jnp.max(rgb)) <= 1.0
    assert not bool(jnp.isnan(rgb).any())


def test_energy_model_monotone():
    from repro.core.energy import DEFAULT_ENERGY as em
    assert em.hbm(2e9) == pytest.approx(2 * em.hbm(1e9))
    assert em.compute(1e12) > 0
    # HBM access costs far more per byte than VMEM
    assert em.pj_per_byte_hbm > 10 * em.pj_per_byte_vmem


def test_checkpoint_manager_error_propagates(tmp_path):
    from repro.ckpt import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "nope\x00bad"), keep=1)
    mgr.save_async(1, {"w": jnp.ones(3)})
    with pytest.raises(BaseException):
        mgr.wait()
