"""The heterogeneous SoC layer: per-device placement, per-link
contention, per-device reporting — verified against closed-form
arithmetic written with the engine's exact float expressions.

The centerpiece is a hand-computed 2-device (cpu + accel) 3-op pipeline
where every host, transfer, contention and compute term is checked with
``==`` (no tolerance) against the same IEEE operations the engine
performs; around it sit link-independence, placement-fallback, topology
validation, chain-fast-path and serving co-simulation checks.
"""
import dataclasses

import pytest

from repro.sim import engine, ir
from repro.sim.hw import Device, Link, SoCTopology

NO_OVERLAP = dict(overlap_transfers=False)

CPU_PEAK = 1e10
ACC_PEAK = 1e12
HBM_BW = 1e9

SOC = SoCTopology(
    devices=(Device("cpu0", kind="cpu", peak_flops=CPU_PEAK),
             Device("acc0", kind="accel", peak_flops=ACC_PEAK)),
    links=(Link("hbm", ports=1.0),),
    name="cpu+1acc")

CFG = engine.EngineConfig(interface="hbm", hbm_bw=HBM_BW,
                          host_dispatch_s=1e-5, host_bw=1e10,
                          host_threads=2, topology=SOC, **NO_OVERLAP)


def _pipeline():
    return ir.Program([
        ir.CostedOp("pre", flops=1e8, bytes_in=1e6, bytes_out=1e6,
                    device_class="cpu"),
        ir.CostedOp("mm", flops=1e9, bytes_in=2e6, deps=("pre",),
                    device_class="accel"),
        ir.CostedOp("post", flops=1e8, bytes_out=1e6, deps=("mm",),
                    device_class="accel"),
    ], name="pipe")


def test_two_device_pipeline_matches_closed_form():
    """Every term of the cpu->accel->accel pipeline, by hand, with the
    engine's own float expressions (division/addition order included)."""
    res = engine.run(_pipeline(), CFG)

    # host lane: dispatch + bytes/host_bw/host_threads, serialized
    h_pre = 1e-5 + 2e6 / 1e10 / 2
    h_mm = 1e-5 + 2e6 / 1e10 / 2
    h_post = 1e-5 + 1e6 / 1e10 / 2

    # pre on cpu0: gated by its own dispatch, transfer at factor 1
    # (alone on the link), compute at the CPU's peak
    x_pre = 2e6 / HBM_BW
    c_pre = 1e8 / CPU_PEAK
    done_pre = h_pre + x_pre + c_pre

    # mm on acc0: host dispatch re-gates after pre completes, its
    # transfer starts after pre's window ended -> factor 1 again
    t_mm = done_pre + h_mm
    x_mm = 2e6 / HBM_BW
    c_mm = 1e9 / ACC_PEAK
    done_mm = t_mm + x_mm + c_mm

    t_post = done_mm + h_post
    x_post = 1e6 / HBM_BW
    c_post = 1e8 / ACC_PEAK
    done_post = t_post + x_post + c_post

    assert res.makespan == done_post

    ev = {e.name: e for e in res.timeline.events}
    assert ev["pre"].worker == "cpu0" and ev["pre"].duration == c_pre
    assert ev["pre:xfer"].worker == "cpu0"
    assert ev["pre:xfer"].start == h_pre
    assert ev["pre:xfer"].duration == x_pre
    assert ev["mm"].worker == "acc0" and ev["mm"].duration == c_mm
    assert ev["mm:xfer"].start == t_mm
    assert ev["post"].worker == "acc0" and ev["post"].duration == c_post
    assert ev["mm:dispatch"].worker == "host"
    assert ev["mm:dispatch"].start == done_pre

    # per-device accounting
    pd = res.per_device
    assert pd["cpu0"] == {"transfer": x_pre, "compute": c_pre}
    assert pd["acc0"] == {"transfer": x_mm + x_post,
                          "compute": c_mm + c_post}
    assert pd["host"]["host"] == h_pre + h_mm + h_post

    bd = res.device_breakdowns()
    assert bd["cpu0"].accelerator_s == c_pre
    assert bd["cpu0"].transfer_s == x_pre
    assert bd["acc0"].accelerator_s == c_mm + c_post

    util = res.device_utilization()
    assert util["cpu0"] == (x_pre + c_pre) / done_post
    assert util["acc0"] == (x_mm + x_post + c_mm + c_post) / done_post
    # utilization() counts only the accelerator devices
    assert res.utilization() == util["acc0"]


def test_shared_link_contention_between_devices():
    """Two parallel ops on two accels, one 1-port link: the second
    transfer starts while the first is live -> factor 2.  The same ops on
    two independent 1-port links -> both at factor 1."""
    ops = [ir.CostedOp("a", flops=2e9, bytes_in=1e6),
           ir.CostedOp("b", flops=1e9, bytes_in=1e6)]
    prog = ir.Program(ops)
    x = 1e6 / HBM_BW
    base = dict(interface="hbm", hbm_bw=HBM_BW, **NO_OVERLAP)

    shared = SoCTopology(
        devices=(Device("acc0"), Device("acc1")),
        links=(Link("hbm", ports=1.0),), name="shared")
    res = engine.run(prog, engine.EngineConfig(topology=shared, **base))
    ev = {e.name: e for e in res.timeline.events}
    # LPT pops "a" (larger compute) first -> acc0 at factor 1; "b" starts
    # at t=0 with a's window live -> live=2, factor max(1, 2/1) = 2
    assert ev["a:xfer"].duration == x
    assert ev["b:xfer"].duration == x * 2.0
    assert ev["a"].start == x and ev["b"].start == x * 2.0

    split = SoCTopology(
        devices=(Device("acc0", link="m0"), Device("acc1", link="m1")),
        links=(Link("m0", ports=1.0), Link("m1", ports=1.0)),
        name="split")
    res2 = engine.run(prog, engine.EngineConfig(topology=split, **base))
    ev2 = {e.name: e for e in res2.timeline.events}
    assert ev2["a:xfer"].duration == x
    assert ev2["b:xfer"].duration == x          # independent links
    assert res2.makespan < res.makespan


def test_device_class_fallback():
    """A class with no matching device falls back to the accelerators;
    with no accelerators either, any device will do."""
    op = ir.CostedOp("k", flops=1e9, device_class="dsp")
    res = engine.run(ir.Program([op]), CFG)
    assert {e.worker for e in res.timeline.events
            if e.kind == "compute"} == {"acc0"}

    cpu_only = SoCTopology(devices=(Device("c0", kind="cpu"),))
    res2 = engine.run(ir.Program([op]),
                      engine.EngineConfig(topology=cpu_only))
    assert {e.worker for e in res2.timeline.events} == {"c0"}


def test_per_device_interface_and_bandwidth():
    """Device-level interface/bandwidth overrides route that device's
    traffic differently (acp frontend vs hbm accel)."""
    soc = SoCTopology(
        devices=(Device("cpu0", kind="cpu", interface="ideal"),
                 Device("acc0", hbm_bw=2e9)),
        links=(Link("hbm"),))
    cfg = engine.EngineConfig(interface="hbm", hbm_bw=HBM_BW,
                              topology=soc, **NO_OVERLAP)
    prog = ir.Program([
        ir.CostedOp("p", flops=1e6, bytes_in=1e6, device_class="cpu"),
        ir.CostedOp("q", flops=1e6, bytes_in=1e6, deps=("p",))])
    res = engine.run(prog, cfg)
    ev = {e.name: e for e in res.timeline.events}
    assert "p:xfer" not in ev                    # ideal: free staging
    assert ev["q:xfer"].duration == 1e6 / 2e9    # device bw override


def test_chain_fast_path_on_uniform_topology_matches_event_loop():
    """An all-accel chain on a heterogeneous (cpu + 2 identical accel)
    topology keeps the prefix-sum fast path, bit-identical to the event
    loop; a mixed-class chain falls back to the event loop silently."""
    soc = SoCTopology(
        devices=(Device("cpu0", kind="cpu", peak_flops=CPU_PEAK),
                 Device("acc0"), Device("acc1")),
        links=(Link("hbm", ports=2.0),))
    cfg = engine.EngineConfig(interface="hbm", topology=soc,
                              host_dispatch_s=1e-6)
    chain = ir.Program([
        ir.CostedOp(f"s{i}", flops=1e9, dot_flops=1e9, bytes_in=1e6,
                    deps=(f"s{i-1}",) if i else ())
        for i in range(40)])
    fast = engine.run(chain, cfg, fast=True)
    slow = engine.run(chain, cfg, fast=False)
    assert fast.makespan == slow.makespan
    assert fast.timeline.events == slow.timeline.events
    assert fast.breakdown == slow.breakdown
    assert fast.energy == slow.energy

    mixed = ir.Program([
        ir.CostedOp("p", flops=1e8, bytes_in=1e6, device_class="cpu"),
        ir.CostedOp("q", flops=1e9, bytes_in=1e6, deps=("p",))])
    a = engine.run(mixed, cfg, fast=True)    # falls back internally
    b = engine.run(mixed, cfg, fast=False)
    assert a.makespan == b.makespan
    assert a.timeline.events == b.timeline.events
    assert {e.worker for e in a.timeline.events
            if e.kind == "compute"} == {"cpu0", "acc0"}


def test_chain_op_costs_is_device_aware():
    """chain_op_costs charges an op at its class's reference device: the
    cpu op at the CPU peak, the accel op at the accelerator peak."""
    cpu_op = ir.CostedOp("p", flops=1e9, device_class="cpu")
    acc_op = ir.CostedOp("q", flops=1e9, device_class="accel")
    _, _, c_cpu, _ = engine.chain_op_costs(cpu_op, CFG)
    _, _, c_acc, _ = engine.chain_op_costs(acc_op, CFG)
    assert c_cpu == 1e9 / CPU_PEAK
    assert c_acc == 1e9 / ACC_PEAK


def test_serving_cosimulation_matches_on_heterogeneous_topology():
    """busy_s == engine.makespan stays bit-exact when the serving config
    carries a heterogeneous (cpu + 2 uniform accel) topology."""
    from repro.configs.gemma_2b import SMOKE
    from repro.serve.policy import ContinuousBatching
    from repro.sim.serving import poisson_trace, simulate_serving

    soc = SoCTopology(
        devices=(Device("cpu0", kind="cpu", peak_flops=CPU_PEAK),
                 Device("acc0"), Device("acc1")),
        links=(Link("hbm", ports=4.0),))
    cfg = engine.EngineConfig(interface="hbm", host_dispatch_s=1e-6,
                              topology=soc)
    trace = poisson_trace(12, 200.0, seed=3)
    res = simulate_serving(SMOKE, trace, ContinuousBatching(max_batch=4),
                           cfg)
    assert res.busy_s == res.engine.makespan
    assert res.makespan_s >= res.busy_s

    # a mixed-signature accelerator pool would silently break that
    # invariant (the event loop load-balances across devices with
    # different costs) -> simulate_serving rejects it up front
    mixed = SoCTopology(
        devices=(Device("acc0", peak_flops=1e12),
                 Device("acc1", peak_flops=2e12)),
        links=(Link("hbm", ports=4.0),))
    with pytest.raises(ValueError, match="uniform accelerator pool"):
        simulate_serving(SMOKE, trace, ContinuousBatching(max_batch=4),
                         dataclasses.replace(cfg, topology=mixed))


def test_uniform_class_params_detects_mixed_pools():
    """The precondition the serving co-simulation relies on: a pool is
    uniform only when every candidate device of the class shares one cost
    signature AND one link."""
    uniform = SoCTopology(
        devices=(Device("acc0", peak_flops=2e12),
                 Device("acc1", peak_flops=2e12)),
        links=(Link("hbm", ports=2.0),))
    assert engine.uniform_class_params(
        engine.EngineConfig(topology=uniform), "accel")
    # flat configs are trivially uniform
    assert engine.uniform_class_params(engine.EngineConfig(n_workers=8),
                                       "accel")
    # mixed peak flops -> two signatures
    mixed_peak = SoCTopology(
        devices=(Device("acc0", peak_flops=1e12),
                 Device("acc1", peak_flops=2e12)))
    assert not engine.uniform_class_params(
        engine.EngineConfig(topology=mixed_peak), "accel")
    # identical devices on DIFFERENT links are also non-uniform: the
    # same op would contend on different port pools per placement
    split_links = SoCTopology(
        devices=(Device("acc0", link="m0"), Device("acc1", link="m1")),
        links=(Link("m0", ports=1.0), Link("m1", ports=1.0)))
    assert not engine.uniform_class_params(
        engine.EngineConfig(topology=split_links), "accel")
    # mixed interface override -> non-uniform
    mixed_iface = SoCTopology(
        devices=(Device("acc0", interface="acp"), Device("acc1")))
    assert not engine.uniform_class_params(
        engine.EngineConfig(interface="hbm", topology=mixed_iface),
        "accel")


def test_mixed_pool_serving_error_is_actionable():
    """The clear-error path: a mixed accelerator pool is rejected up
    front with a message that names the problem and the fix surface,
    instead of silently breaking the busy_s == makespan invariant."""
    from repro.configs.gemma_2b import SMOKE
    from repro.serve.policy import ContinuousBatching
    from repro.sim.serving import poisson_trace, simulate_serving

    mixed = SoCTopology(
        devices=(Device("acc0", hbm_bw=1e9), Device("acc1", hbm_bw=2e9)))
    with pytest.raises(ValueError) as ei:
        simulate_serving(SMOKE, poisson_trace(4, 100.0, seed=0),
                         ContinuousBatching(max_batch=2),
                         engine.EngineConfig(topology=mixed))
    msg = str(ei.value)
    assert "uniform accelerator pool" in msg
    assert "cost signature" in msg and "chain_op_costs" in msg


def test_uniform_override_pool_serving_busy_equals_makespan_bitwise():
    """A pool that overrides device parameters UNIFORMLY (every accel at
    the same non-default peak/bandwidth, one shared link) still satisfies
    busy_s == engine.makespan bit for bit — the chain_op_costs pricing
    path equals the engine's charge on every op."""
    from repro.configs.gemma_2b import SMOKE
    from repro.serve.policy import get_policy
    from repro.sim.serving import poisson_trace, simulate_serving

    soc = SoCTopology(
        devices=(Device("cpu0", kind="cpu", peak_flops=CPU_PEAK),
                 Device("acc0", peak_flops=2e12, hbm_bw=2e9),
                 Device("acc1", peak_flops=2e12, hbm_bw=2e9)),
        links=(Link("hbm", ports=2.0),))
    cfg = engine.EngineConfig(interface="hbm", hbm_bw=HBM_BW,
                              host_dispatch_s=1e-6, topology=soc)
    assert engine.uniform_class_params(cfg, "accel")
    trace = poisson_trace(10, 150.0, seed=11)
    for kind in ("static", "dynamic", "continuous"):
        res = simulate_serving(SMOKE, trace, get_policy(kind, max_batch=4),
                               cfg)
        assert res.busy_s == res.engine.makespan
        assert res.makespan_s >= res.busy_s


def test_topology_validation():
    with pytest.raises(ValueError):
        SoCTopology(devices=())
    with pytest.raises(ValueError):
        SoCTopology(devices=(Device("a"), Device("a")))
    with pytest.raises(ValueError):
        SoCTopology(devices=(Device("a", link="nope"),),
                    links=(Link("hbm"),))
    bad_iface = SoCTopology(devices=(Device("a", interface="warp"),))
    with pytest.raises(ValueError):
        engine.run(ir.Program([ir.CostedOp("x", flops=1.0)]),
                   engine.EngineConfig(topology=bad_iface))


def test_sweep_layer_accepts_topology_grids():
    from repro.sim.sweep import as_records, topology_sweep
    prog = _pipeline()
    topos = [SoCTopology(devices=(Device("cpu0", kind="cpu"),)
                         + tuple(Device(f"acc{i}") for i in range(n)),
                         links=(Link("hbm", ports=1.0),),
                         name=f"cpu+{n}acc")
             for n in (1, 2, 4)]
    results = topology_sweep(prog, topos,
                             engine.EngineConfig(interface="hbm"))
    assert len(results) == 3
    rows = as_records(results)
    assert [r["topology"] for r in rows] == ["cpu+1acc", "cpu+2acc",
                                             "cpu+4acc"]
    assert [r["n_accel"] for r in rows] == [1, 2, 4]
    assert rows[0]["devices"] == "1cpu+1accel"
