"""Beyond-paper optimization paths (PerfFlags) preserve semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dist import context as dist_ctx
from repro.models import transformer as T
from repro.models.attention import chunked_attention, windowed_attention


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags())


def test_windowed_matches_masked_chunked():
    B, H, Hkv, S, D, w = 1, 4, 2, 256, 16, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, D))
    a = windowed_attention(q, k, v, window=w, chunk=64)
    b = chunked_attention(q, k, v, causal=True, window=w, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_attn_remat_chunk_same_grads():
    B, H, S, D = 1, 2, 128, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))

    def loss(q):
        return jnp.sum(chunked_attention(q, k, v, causal=True, chunk=32) ** 2)

    g_base = jax.grad(loss)(q)
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags(attn_remat_chunk=True))
    g_remat = jax.grad(loss)(q)
    np.testing.assert_allclose(np.asarray(g_base), np.asarray(g_remat),
                               atol=1e-5)


@pytest.mark.parametrize("arch,flags", [
    ("gemma3_1b", dict(attn_remat_chunk=True, windowed_attention=True)),
    ("falcon_mamba_7b", dict(ssm_impl="chunked")),
    ("phi3_mini_3_8b", dict(attn_remat_chunk=True)),
])
def test_flagged_forward_matches_baseline(arch, flags):
    cfg = get_smoke_config(arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    base, _ = T.train_forward(cfg, params, batch)
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags(**flags))
    opt, _ = T.train_forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32),
                               rtol=0.05, atol=0.05)


def test_ssm_chunked_matches_scan_gradients():
    from repro.models.ssm import mamba1_forward, mamba1_init
    cfg = get_smoke_config("falcon_mamba_7b")
    p = mamba1_init(jax.random.PRNGKey(0), cfg)
    from repro.models.layers import split_leaves
    p, _ = split_leaves(p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)

    def loss(x, impl):
        y, _ = mamba1_forward(p, x, cfg, impl=impl)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    g1 = jax.grad(lambda x: loss(x, "scan"))(x)
    g2 = jax.grad(lambda x: loss(x, "chunked"))(x)
    np.testing.assert_allclose(np.asarray(g1, np.float32),
                               np.asarray(g2, np.float32), rtol=0.1,
                               atol=0.1)


def test_windowed_prefill_cache_compatible():
    """Optimized (windowed) prefill fills a cache the decode path can
    continue from, matching the baseline prefill."""
    import numpy as np
    cfg = get_smoke_config("gemma3_1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)
    ref, cache_ref = T.prefill_forward(cfg, params, {"tokens": toks[:, :8]},
                                       max_seq=12)
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags(windowed_attention=True,
                                               attn_remat_chunk=True))
    opt, cache_opt = T.prefill_forward(cfg, params, {"tokens": toks[:, :8]},
                                       max_seq=12)
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags())
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(opt, np.float32), atol=0.05)
    ld_ref, _ = T.decode_forward(cfg, params, cache_ref, toks[:, 8:9], 8)
    ld_opt, _ = T.decode_forward(cfg, params, cache_opt, toks[:, 8:9], 8)
    np.testing.assert_allclose(np.asarray(ld_ref, np.float32),
                               np.asarray(ld_opt, np.float32), atol=0.05)


def test_moe_einsum_dispatch_matches_gather():
    import dataclasses
    import numpy as np
    cfg = get_smoke_config("granite_moe_1b_a400m")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    from repro.models import moe as M
    from repro.models.layers import split_leaves
    p, _ = split_leaves(M.moe_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)
                          ).astype(jnp.bfloat16)
    a, _ = M.moe_apply(p, x, cfg)
    b, _ = M.moe_apply_einsum(p, x, cfg)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=0.05)


def test_windowed_decode_matches_baseline():
    """Sliced-cache decode (static_window) == full-cache masked decode."""
    import numpy as np
    cfg = get_smoke_config("gemma3_1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, cache = T.prefill_forward(cfg, params, {"tokens": toks[:, :10]},
                                 max_seq=16)
    ref, cache_ref = T.decode_forward(cfg, params, cache, toks[:, 10:11], 10)
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags(windowed_attention=True))
    opt, cache_opt = T.decode_forward(cfg, params, cache, toks[:, 10:11], 10)
    step2_opt, _ = T.decode_forward(cfg, params, cache_opt,
                                    toks[:, 11:12], 11)
    dist_ctx.set_perf_flags(dist_ctx.PerfFlags())
    step2_ref, _ = T.decode_forward(cfg, params, cache_ref,
                                    toks[:, 11:12], 11)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(opt, np.float32), atol=0.05)
    np.testing.assert_allclose(np.asarray(step2_ref, np.float32),
                               np.asarray(step2_opt, np.float32), atol=0.05)
