"""HLO analyzer: trip-count unsampling + collective accounting on real
compiled modules (single-device; the 512-device path is covered by the
dry-run artifact)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hlo import analyze_hlo


def test_scan_trip_count_unsampled():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        y, _ = jax.lax.scan(body, x, w)
        return y
    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    res = analyze_hlo(jax.jit(f).lower(xs, ws).compile().as_text())
    per_iter = 2 * 64 * 128 * 128
    assert abs(res["dot_flops"] - 10 * per_iter) / (10 * per_iter) < 0.05
    assert res["n_while"] >= 1
    # XLA's own cost_analysis counts the body once — we must exceed it ~10x
    from repro.core.compat import cost_analysis_dict
    ca = cost_analysis_dict(jax.jit(f).lower(xs, ws).compile())
    assert res["dot_flops"] > 5 * ca["flops"]


def test_nested_scan():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), ()
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, w)
        return y
    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    res = analyze_hlo(jax.jit(f).lower(xs, ws).compile().as_text())
    expect = 3 * 4 * 2 * 32 * 64 * 64
    assert abs(res["dot_flops"] - expect) / expect < 0.05


def test_elementwise_and_transcendentals():
    def f(x):
        return jnp.sum(jnp.exp(x) * x + jnp.tanh(x))
    xs = jax.ShapeDtypeStruct((1024,), jnp.float32)
    res = analyze_hlo(jax.jit(f).lower(xs).compile().as_text())
    assert res["transcendentals"] >= 2 * 1024
    assert res["flops"] >= 3 * 1024


def test_bytes_reasonable_for_copy():
    def f(x):
        return x * 2.0
    xs = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    res = analyze_hlo(jax.jit(f).lower(xs).compile().as_text())
    nbytes = 4 * (1 << 20)
    assert nbytes <= res["bytes"] <= 4 * nbytes


def test_dryrun_artifact_has_collectives():
    """The committed sweep results must show collectives on every multi-chip
    train cell (proves the pod axis actually shards)."""
    import json
    import pathlib
    p = pathlib.Path("experiments/dryrun/results.json")
    if not p.exists():
        import pytest
        pytest.skip("dry-run sweep not present")
    res = json.loads(p.read_text())
    ok = [r for r in res.values() if r["status"] == "ok"]
    assert len(ok) >= 60
    for r in ok:
        if r["kind"] == "train":
            assert r["hlo"]["collective_bytes"] > 0, r["arch"]
