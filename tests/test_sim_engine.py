"""Unified simulation engine: one run yields Timeline + Breakdown +
Roofline + energy, and the engine-derived values match the closed-form
``roofline()``/``breakdown()`` wrappers (acceptance: within 5%; in practice
exact for HLO programs)."""
import math

import pytest

from repro.configs.paper_nets import PAPER_NETS
from repro.core.simulator import (HBM_BW, HOST_OVERHEAD_S, ICI_BW,
                                  PEAK_FLOPS, breakdown, roofline)
from repro.apps.paper_graphs import build_paper_graph
from repro.sim import engine, ir

HLO = {"flops": 1e15, "dot_flops": 9e14, "bytes": 1e12,
       "collective_bytes": 1e10, "wire_bytes": 1.5e10,
       "transcendentals": 1e9, "collectives": {}, "n_while": 1,
       "custom_calls": {}}


# ---------------------------------------------------------------------------
# IR lowerings


def test_from_hlo_preserves_aggregates_exactly():
    prog = ir.from_hlo(HLO, n_ops=8)
    t = prog.totals()
    assert t["flops"] == pytest.approx(HLO["flops"], rel=1e-12)
    assert t["dot_flops"] == pytest.approx(HLO["dot_flops"], rel=1e-12)
    assert t["bytes_in"] + t["bytes_out"] == pytest.approx(HLO["bytes"],
                                                           rel=1e-12)
    assert t["collective_bytes"] == pytest.approx(HLO["collective_bytes"],
                                                  rel=1e-12)
    assert t["wire_bytes"] == pytest.approx(HLO["wire_bytes"], rel=1e-12)
    back = prog.as_hlo_dict()
    assert back["bytes"] == pytest.approx(HLO["bytes"], rel=1e-12)


def test_from_graph_lowers_every_node():
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
    compute_nodes = [n for n in g.nodes.values()
                     if n.op not in ("input", "weight")]
    phases = {op.phase for op in prog.ops}
    assert phases == {n.name for n in compute_nodes}
    assert prog.total("flops") > 0
    assert prog.total("bytes_in") > 0
    # wavefront deps stay inside the program
    names = {op.name for op in prog.ops}
    for op in prog.ops:
        assert all(d in names for d in op.deps)


def test_program_then_bridges_every_root():
    a = ir.Program([ir.CostedOp("a0", duration_s=1e-3),
                    ir.CostedOp("a1", deps=("a0",), duration_s=1e-3)],
                   name="a")
    # b has TWO roots; both must wait for a's sinks
    b = ir.Program([ir.CostedOp("b0", duration_s=1e-3),
                    ir.CostedOp("b1", duration_s=1e-3),
                    ir.CostedOp("b2", deps=("b0", "b1"), duration_s=1e-3)],
                   name="b")
    c = a.then(b)
    by_name = {op.name: op for op in c.ops}
    assert "a1" in by_name["b0"].deps
    assert "a1" in by_name["b1"].deps
    assert "a1" not in by_name["b2"].deps    # non-root keeps its own deps
    res = engine.run(c, engine.EngineConfig(n_workers=4))
    order = {e.name: e.start for e in res.timeline.events
             if e.kind == "compute"}
    assert order["b0"] >= order["a1"]
    assert order["b1"] >= order["a1"]


def test_wire_bytes_zero_key_not_overridden():
    """A present-but-zero wire_bytes (group-size-1 collectives) must NOT
    fall back to the operand-sum metric — only an absent key does."""
    zero_wire = dict(HLO, wire_bytes=0.0)
    rl = roofline(zero_wire, None, None, 1)
    assert rl.collective_s == 0.0
    no_key = {k: v for k, v in HLO.items() if k != "wire_bytes"}
    rl2 = roofline(no_key, None, None, 1)
    assert rl2.collective_s == pytest.approx(
        HLO["collective_bytes"] / ICI_BW)


# ---------------------------------------------------------------------------
# closed-form equivalence (the acceptance criterion)


def test_engine_roofline_matches_closed_form():
    rl = roofline(HLO, None, None, 256)
    assert rl.compute_s == pytest.approx(HLO["flops"] / PEAK_FLOPS)
    assert rl.memory_s == pytest.approx(HLO["bytes"] / HBM_BW)
    assert rl.collective_s == pytest.approx(HLO["wire_bytes"] / ICI_BW)
    assert rl.bound == "compute"
    assert rl.step_s == pytest.approx(
        max(rl.compute_s, rl.memory_s, rl.collective_s) + HOST_OVERHEAD_S)


def test_engine_breakdown_matches_closed_form():
    b = breakdown(HLO, host_prep_s=100e-6)
    accel = HLO["flops"] / PEAK_FLOPS
    transfer = max(HLO["bytes"] / HBM_BW - HLO["dot_flops"] / PEAK_FLOPS, 0.0)
    assert b.accelerator_s == pytest.approx(accel, rel=0.05)
    assert b.transfer_s == pytest.approx(transfer, rel=0.05, abs=1e-12)
    assert b.collective_s == pytest.approx(HLO["collective_bytes"] / ICI_BW,
                                           rel=0.05)
    assert b.host_s == pytest.approx(100e-6 + HOST_OVERHEAD_S)


def test_one_run_yields_all_figures():
    prog = ir.from_hlo(HLO, n_ops=4)
    res = engine.run(prog, engine.EngineConfig(n_workers=1, interface="hbm",
                                               host_floor_s=HOST_OVERHEAD_S))
    # timeline, breakdown, roofline and energy all from the same run
    kinds = res.per_kind
    assert res.breakdown.accelerator_s == pytest.approx(kinds["compute"])
    assert res.breakdown.transfer_s == pytest.approx(
        kinds.get("transfer", 0.0))
    assert res.roofline.compute_s == pytest.approx(
        HLO["flops"] / PEAK_FLOPS)
    assert res.energy["total_j"] > 0
    assert res.makespan > 0
    # the serialized single-worker makespan is the sum of exposed phases
    assert res.makespan == pytest.approx(
        kinds["compute"] + kinds.get("transfer", 0.0)
        + kinds.get("collective", 0.0), rel=1e-6)


@pytest.mark.parametrize("net", ["lenet5", "cnn10", "vgg16"])
def test_graph_breakdown_within_5pct_of_closed_form(net):
    """Engine aggregation over a tile-level graph program stays within 5%
    of the closed-form breakdown of the same aggregate costs."""
    g = build_paper_graph(PAPER_NETS[net], batch=1)
    prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
    res = engine.run(prog, engine.EngineConfig(n_workers=1, interface="hbm",
                                               host_floor_s=HOST_OVERHEAD_S))
    ref = breakdown(prog.as_hlo_dict())
    assert res.breakdown.accelerator_s == pytest.approx(
        ref.accelerator_s, rel=0.05)
    assert res.breakdown.transfer_s == pytest.approx(
        ref.transfer_s, rel=0.05, abs=1e-9)
    rl = roofline(prog.as_hlo_dict(), None, None, 1)
    assert res.roofline.compute_s == pytest.approx(rl.compute_s, rel=0.05)
    assert res.roofline.memory_s == pytest.approx(rl.memory_s, rel=0.05)


# ---------------------------------------------------------------------------
# interface study (Fig 11 ordering) and scheduling behaviors


def test_dma_vs_acp_ordering_all_paper_nets():
    """Engine runs reproduce the bench_interfaces ordering: the fused/
    resident path beats software-managed DMA staging on time AND energy."""
    for name, net in PAPER_NETS.items():
        g = build_paper_graph(net, batch=1)
        prog = ir.from_graph(g, batch=1, max_tile_elems=16384)
        dma = engine.run(prog, engine.EngineConfig(n_workers=1,
                                                   interface="dma"))
        acp = engine.run(prog, engine.EngineConfig(n_workers=1,
                                                   interface="acp"))
        assert acp.makespan < dma.makespan, name
        assert acp.energy["total_j"] < dma.energy["total_j"], name


def test_affinity_pins_to_one_worker():
    ops = [ir.CostedOp(f"r{i}", duration_s=1e-3, affinity="out0")
           for i in range(8)]
    res = engine.run(ir.Program(ops), engine.EngineConfig(n_workers=8))
    workers = {e.worker for e in res.timeline.events if e.kind == "compute"}
    assert len(workers) == 1
    assert res.makespan == pytest.approx(8e-3)


def test_hbm_port_contention_slows_transfers():
    ops = [ir.CostedOp(f"t{i}", duration_s=1e-4, transfer_s=1e-4)
           for i in range(8)]
    free = engine.run(ir.Program(ops),
                      engine.EngineConfig(n_workers=8, hbm_ports=0))
    contended = engine.run(ir.Program(ops),
                           engine.EngineConfig(n_workers=8, hbm_ports=1))
    f_kinds = free.per_kind
    c_kinds = contended.per_kind
    assert c_kinds["transfer"] > f_kinds["transfer"]
    assert contended.makespan > free.makespan


def test_host_dispatch_serializes_and_threads_help():
    ops = [ir.CostedOp(f"o{i}", flops=1e6, bytes_in=1e6, bytes_out=1e6)
           for i in range(16)]
    one = engine.run(ir.Program(ops), engine.EngineConfig(
        n_workers=4, host_dispatch_s=1e-6, host_bw=20e9, host_threads=1))
    eight = engine.run(ir.Program(ops), engine.EngineConfig(
        n_workers=4, host_dispatch_s=1e-6, host_bw=20e9, host_threads=8))
    assert one.per_kind["host"] > eight.per_kind["host"]
    # host lane never overlaps itself
    host_evs = sorted((e for e in one.timeline.events if e.kind == "host"),
                      key=lambda e: e.start)
    for a, b in zip(host_evs, host_evs[1:]):
        assert b.start >= a.end - 1e-15


def test_dependency_cycle_raises():
    ops = [ir.CostedOp("a", deps=("b",)), ir.CostedOp("b", deps=("a",))]
    with pytest.raises(ValueError):
        engine.run(ir.Program(ops), engine.EngineConfig())
