"""Docs gate as a tier-1 test: the fenced Python blocks in README.md and
docs/GUIDE.md must execute (same runner ``tools/ci.sh`` uses), and the
extractor itself must parse fences correctly."""
import importlib.util
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _runner():
    spec = importlib.util.spec_from_file_location(
        "run_doc_snippets", ROOT / "tools" / "run_doc_snippets.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_extract_blocks_parses_fences():
    mod = _runner()
    text = ("pre\n```python\na = 1\n```\n"
            "```bash\nls\n```\n"
            "```python no-run\nraise RuntimeError\n```\n"
            "```\nplain\n```\n")
    blocks = mod.extract_blocks(text)
    assert [info for _, info, _ in blocks] == ["python", "bash",
                                               "python no-run"]
    assert blocks[0][2] == "a = 1\n"


# marked slow so tools/ci.sh (pytest -m "not slow" + the explicit
# run_doc_snippets gate) executes the snippets once, not twice; plain
# tier-1 (`pytest -x -q`) still runs this
@pytest.mark.slow
@pytest.mark.parametrize("doc", ["README.md", "docs/GUIDE.md"])
def test_doc_snippets_execute(doc, capsys):
    mod = _runner()
    ran, failures = mod.run_file(ROOT / doc)
    assert failures == 0, f"{doc} has failing python blocks (see stderr)"
    assert ran > 0, f"{doc} has no runnable python blocks"
