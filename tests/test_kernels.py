"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py oracles
(interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(i, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(i), shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 128, 384, 128, 128, 128),
    (512, 256, 256, 256, 128, 256),
    (128, 512, 640, 128, 256, 128),
])
def test_matmul_sweep(m, n, k, bm, bn, bk, dtype):
    a = _rand(0, (m, k), dtype)
    b = _rand(1, (k, n), dtype)
    out = ops.matmul(a, b, bm=bm, bn=bn, bk=bk)
    expect = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * k ** 0.5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,D,bq,bk,causal,window", [
    (1, 2, 2, 128, 32, 64, 64, True, 0),
    (2, 4, 2, 128, 64, 64, 32, True, 0),      # GQA
    (1, 2, 1, 256, 32, 128, 64, True, 48),    # MQA + sliding window
    (1, 2, 2, 128, 32, 64, 64, False, 0),     # non-causal (encoder)
])
def test_flash_attention_sweep(B, H, Hkv, S, D, bq, bk, causal, window,
                               dtype):
    q = _rand(2, (B, H, S, D), dtype)
    k = _rand(3, (B, Hkv, S, D), dtype)
    v = _rand(4, (B, Hkv, S, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=bq, bk=bk)
    kf = jnp.repeat(k, H // Hkv, 1)
    vf = jnp.repeat(v, H // Hkv, 1)
    expect = ref.flash_attention_ref(q, kf, vf, causal=causal, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,S,d,N,bd,chunk", [
    (1, 32, 16, 8, 16, 16),
    (2, 64, 32, 16, 16, 32),
    (1, 128, 64, 8, 32, 64),
])
def test_mamba_scan_sweep(b, S, d, N, bd, chunk, dtype):
    x = _rand(5, (b, S, d), dtype)
    dt = jax.nn.softplus(_rand(6, (b, S, d), jnp.float32)).astype(dtype)
    B = _rand(7, (b, S, N), dtype)
    C = _rand(8, (b, S, N), dtype)
    A = -jnp.exp(_rand(9, (d, N), jnp.float32) * 0.3)
    D = jnp.ones((d,), jnp.float32)
    out = ops.mamba_scan(x, dt, B, C, A, D, bd=bd, chunk=chunk)
    expect = ref.mamba_scan_ref(x, dt, B, C, A, D)
    tol = 8e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * 4)


def test_matmul_uses_tiling_optimizer_defaults():
    a = _rand(0, (256, 256), jnp.float32)
    b = _rand(1, (256, 256), jnp.float32)
    out = ops.matmul(a, b)  # block shapes from choose_matmul_tiling
    np.testing.assert_allclose(np.asarray(out), np.asarray(
        ref.matmul_ref(a, b)), rtol=1e-4, atol=1e-3)


def test_chunked_attention_matches_flash_kernel():
    """The jnp chunked implementation and the Pallas kernel implement the
    same dataflow — cross-validate them."""
    from repro.models.attention import chunked_attention
    q = _rand(0, (1, 2, 128, 32), jnp.float32)
    k = _rand(1, (1, 2, 128, 32), jnp.float32)
    v = _rand(2, (1, 2, 128, 32), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, chunk=32)
    b = ops.flash_attention(q, k, v, causal=True, bq=64, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)
