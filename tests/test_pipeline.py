"""Pipeline parallelism + multi-device paths, run in subprocesses so the
XLA host-device-count flag never leaks into this test process."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 4, timeout: int = 300):
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_gpipe_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ("stage",))
        S, B, D = 4, 8, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        y_pipe = pipeline_apply(mesh, stage_fn, ws, x, n_microbatches=4)
        y_seq = x
        for i in range(S):
            y_seq = stage_fn(ws[i], y_seq)
        err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
        print("ERR", err)
        assert err < 1e-5, err
    """)
    assert "ERR" in out


def test_moe_ep_matches_single_device():
    """shard_map expert parallelism == single-shard MoE output."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke_config
        from repro.dist import context as dist_ctx
        from repro.models import moe as M
        from repro.models.layers import split_leaves
        cfg = get_smoke_config("granite_moe_1b_a400m")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        p, _ = split_leaves(M.moe_init(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)
                              ).astype(jnp.bfloat16)
        ref, _ = M.moe_apply(p, x, cfg)                    # no mesh
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        dist_ctx.set_mesh(mesh)
        out, _ = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(p, x)
        dist_ctx.set_mesh(None)
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                    - out.astype(jnp.float32))))
        print("ERR", err)
        assert err < 0.05, err
    """)
    assert "ERR" in out


@pytest.mark.slow
def test_dryrun_one_cell_end_to_end(tmp_path):
    """The dry-run driver lowers+compiles a real cell on the 16x16 mesh."""
    out = _run(f"""
        import sys
        sys.argv = ["dryrun", "--arch", "tinyllama_1_1b",
                    "--shape", "decode_32k", "--mesh", "single",
                    "--out", "{tmp_path}"]
        import runpy
        runpy.run_module("repro.launch.dryrun", run_name="__main__")
    """, devices=512, timeout=580)
    import json
    import pathlib
    res = json.loads((pathlib.Path(str(tmp_path)) / "results.json")
                     .read_text())
    rec = list(res.values())[0]
    assert rec["status"] == "ok", rec
    assert rec["hlo"]["collective_bytes"] > 0