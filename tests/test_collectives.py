"""First-class collectives vs the analytic bounds.

The contract under test: ``ir.from_collective`` lowers ring / tree /
hierarchical collectives into explicit per-hop fabric transfers, and the
engine's makespan on an uncontended fabric equals the textbook closed
forms EXACTLY (the lowering is a sum of identical steps, so the engine's
left-to-right accumulation and the product-form bound agree to the last
couple of ulps) — plus the structural properties: monotonicity in bytes /
group size / latency, 1-member no-op bit-identity, and lane contention
(same links serialize, disjoint links run in parallel).
"""
import dataclasses
import math

import pytest

from tests._hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.sim import engine, ir
from repro.sim.engine import EngineConfig
from repro.sim.hw import Fabric, FabricTier, resolve_tier_params
from repro.sim.ir import collective_time, from_collective

REL = 1e-12

# nonzero per-hop ICI latency so the latency terms of the bounds are
# actually exercised (the flat default is 0.0 for legacy bit-compat)
CONFIG = EngineConfig(ici_lat_s=2e-6)


def _run(prog, config=CONFIG):
    return engine.run(prog, config).makespan


def _rel(a, b):
    return abs(a - b) / max(abs(a), abs(b), 1e-300)


# ---------------------------------------------------------------------------
# closed forms, exact


@pytest.mark.parametrize("p", [2, 3, 4, 8, 16])
def test_ring_all_reduce_closed_form(p):
    """Engine makespan == 2 (p-1)/p B/bw + 2 (p-1) lat, rel <= 1e-12."""
    B = 96e6
    fab = Fabric.single_tier(p)
    lat, bw = resolve_tier_params(CONFIG, "ici")
    t = _run(from_collective("all_reduce", B, p, fab))
    closed = 2.0 * (p - 1) / p * B / bw + 2.0 * (p - 1) * lat
    assert _rel(t, closed) <= REL
    assert collective_time("all_reduce", B, p, fab, config=CONFIG) == t


@pytest.mark.parametrize("p", [2, 3, 8])
def test_ring_engine_equals_python_sum_bitwise(p):
    """The engine IS the left-to-right accumulation of the hop costs."""
    B = 50e6
    fab = Fabric.single_tier(p)
    lat, bw = resolve_tier_params(CONFIG, "ici")
    t = _run(from_collective("all_reduce", B, p, fab))
    acc = 0.0
    for _ in range(2 * (p - 1)):        # 2(p-1) steps of B/p on one lane
        acc += lat + (B / p) / bw
    assert t == acc


@pytest.mark.parametrize("kind", ["reduce_scatter", "all_gather"])
@pytest.mark.parametrize("p", [2, 4, 16])
def test_ring_rs_ag_closed_form(kind, p):
    B = 96e6
    fab = Fabric.single_tier(p)
    lat, bw = resolve_tier_params(CONFIG, "ici")
    t = _run(from_collective(kind, B, p, fab))
    closed = (p - 1) / p * B / bw + (p - 1) * lat
    assert _rel(t, closed) <= REL


@pytest.mark.parametrize("p", [2, 3, 4, 8, 16])
def test_tree_all_reduce_log_depth(p):
    """Tree all-reduce: 2 ceil(log2 p) full-size hops — log-latency
    depth, no (p-1)/p bandwidth discount."""
    B = 96e6
    fab = Fabric.single_tier(p)
    lat, bw = resolve_tier_params(CONFIG, "ici")
    t = _run(from_collective("all_reduce", B, p, fab, algo="tree"))
    depth = max(1, (p - 1).bit_length())
    assert _rel(t, 2.0 * depth * (lat + B / bw)) <= REL
    assert depth == math.ceil(math.log2(p)) or p == 1


def test_tree_beats_ring_when_latency_dominates():
    """Tiny payload, many members: O(log p) latency < O(p) latency."""
    p, B = 32, 8.0
    fab = Fabric.single_tier(p)
    ring = collective_time("all_reduce", B, p, fab, config=CONFIG)
    tree = collective_time("all_reduce", B, p, fab, algo="tree",
                           config=CONFIG)
    assert tree < ring


@pytest.mark.parametrize("p", [2, 4, 8])
def test_all_to_all_closed_form(p):
    B = 64e6
    fab = Fabric.single_tier(p)
    lat, bw = resolve_tier_params(CONFIG, "ici")
    t = _run(from_collective("all_to_all", B, p, fab))
    assert _rel(t, (p - 1) * (lat + (B / p) / bw)) <= REL


def test_hierarchical_composed_per_tier_bound():
    """2-tier hierarchical all-reduce == ring-RS within the inner tier
    + ring all-reduce of B/k across the tier leads + ring-AG back, each
    phase priced at ITS tier's latency/bandwidth."""
    k, n = 4, 4
    fab = Fabric(tiers=(FabricTier("node", k), FabricTier("inter", n)))
    B = 128e6
    lat_n, bw_n = resolve_tier_params(CONFIG, "node")
    lat_i, bw_i = resolve_tier_params(CONFIG, "inter")
    t = _run(from_collective("all_reduce", B, k * n, fab,
                             algo="hierarchical"))
    rs = (k - 1) * (lat_n + (B / k) / bw_n)
    ar = 2.0 * (n - 1) * (lat_i + (B / (k * n)) / bw_i)
    assert _rel(t, 2.0 * rs + ar) <= REL
    assert collective_time("all_reduce", B, k * n, fab,
                           algo="hierarchical", config=CONFIG) == t


def test_hierarchical_le_ring_on_multi_tier():
    """The hierarchical decomposition never loses to a flat ring on the
    slow spanning tier (bandwidths decrease outward by construction)."""
    fab = Fabric.cluster(64)
    for p in (8, 16, 32, 64):
        ring = collective_time("all_reduce", 128e6, p, fab, config=CONFIG)
        hier = collective_time("all_reduce", 128e6, p, fab,
                               algo="hierarchical", config=CONFIG)
        assert hier <= ring * (1.0 + REL)


def test_count_compression_is_exact():
    """count=c back-to-back collectives cost exactly c x one (steps
    serialize on the lane, so bytes and hops scale together)."""
    fab = Fabric.single_tier(8)
    one = collective_time("all_reduce", 32e6, 8, fab, config=CONFIG)
    three = collective_time("all_reduce", 32e6, 8, fab, count=3.0,
                            config=CONFIG)
    assert _rel(three, 3.0 * one) <= REL


# ---------------------------------------------------------------------------
# structure: no-op identity, lanes, errors


def test_one_member_group_is_noop_bit_identical():
    assert from_collective("all_reduce", 1e9, (3,)).ops == []
    assert from_collective("all_to_all", 1e9, 1).ops == []
    base = ir.from_decode(_toy(), 4)
    merged = ir.Program(list(base.ops)
                        + list(from_collective("all_reduce", 1e9, 1).ops),
                        name=base.name)
    a = engine.run(base, CONFIG)
    b = engine.run(merged, CONFIG)
    assert a.makespan == b.makespan
    assert a.energy["total_j"] == b.energy["total_j"]


def test_same_lane_serializes_disjoint_lanes_parallel():
    fab = Fabric.single_tier(8)
    g1, g2 = tuple(range(4)), tuple(range(4, 8))
    one = _run(from_collective("all_reduce", 64e6, g1, fab))
    both_same = _run(ir.Program(
        list(from_collective("all_reduce", 64e6, g1, fab,
                             prefix="a").ops)
        + list(from_collective("all_reduce", 64e6, g1, fab,
                               prefix="b").ops), name="same-lane"))
    both_disjoint = _run(ir.Program(
        list(from_collective("all_reduce", 64e6, g1, fab,
                             prefix="a").ops)
        + list(from_collective("all_reduce", 64e6, g2, fab,
                               prefix="b").ops), name="disjoint"))
    assert _rel(both_same, 2.0 * one) <= REL    # same links: serialized
    assert _rel(both_disjoint, one) <= REL      # disjoint links: parallel


def test_hierarchical_subgroups_run_in_parallel():
    """Phase 1/3 of hierarchical run one ring per inner group on
    DISJOINT lanes: the makespan charges one group's ring, not k."""
    k, n = 4, 2
    fab = Fabric(tiers=(FabricTier("node", k), FabricTier("inter", n)))
    B = 64e6
    prog = from_collective("all_reduce", B, k * n, fab,
                           algo="hierarchical")
    lanes = {op.lane for op in prog.ops if op.name.startswith("c/rs")
             or "/rs" in op.name}
    assert len({op.lane for op in prog.ops}) >= 3   # 2 rs/ag lanes + inter
    assert _run(prog) == collective_time("all_reduce", B, k * n, fab,
                                         algo="hierarchical",
                                         config=CONFIG)
    assert lanes  # sanity: reduce-scatter phase exists


def test_validation_errors():
    fab = Fabric.single_tier(4)
    with pytest.raises(ValueError):
        from_collective("bogus", 1e6, 4, fab)
    with pytest.raises(ValueError):
        from_collective("all_reduce", 1e6, 4, fab, algo="bogus")
    with pytest.raises(ValueError):
        Fabric(tiers=(FabricTier("bogus", 4),))
    with pytest.raises(ValueError):
        # tiers must come in canonical inner-to-outer order
        Fabric(tiers=(FabricTier("inter", 2), FabricTier("ici", 4)))


def _toy():
    from repro.core.config import ModelConfig
    return ModelConfig(name="toy", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                       head_dim=16)


# ---------------------------------------------------------------------------
# property tests: monotonicity


@settings(max_examples=40, deadline=None)
@given(b1=st.floats(1e3, 1e9), b2=st.floats(1e3, 1e9),
       algo=st.sampled_from(["ring", "tree", "hierarchical"]))
def test_monotone_in_bytes(b1, b2, algo):
    lo, hi = sorted((b1, b2))
    fab = Fabric.cluster(16)
    t_lo = collective_time("all_reduce", lo, 16, fab, algo=algo,
                           config=CONFIG)
    t_hi = collective_time("all_reduce", hi, 16, fab, algo=algo,
                           config=CONFIG)
    assert t_lo <= t_hi * (1.0 + REL)


@settings(max_examples=30, deadline=None)
@given(p1=st.integers(1, 32), p2=st.integers(1, 32))
def test_ring_monotone_in_group_size(p1, p2):
    lo, hi = sorted((p1, p2))
    fab = Fabric.single_tier(32)
    t_lo = collective_time("all_reduce", 64e6, lo, fab, config=CONFIG)
    t_hi = collective_time("all_reduce", 64e6, hi, fab, config=CONFIG)
    assert t_lo <= t_hi * (1.0 + REL)


@settings(max_examples=30, deadline=None)
@given(lat1=st.floats(0.0, 1e-4), lat2=st.floats(0.0, 1e-4),
       algo=st.sampled_from(["ring", "tree", "hierarchical"]))
def test_monotone_in_per_tier_latency(lat1, lat2, algo):
    lo, hi = sorted((lat1, lat2))
    fab = Fabric.cluster(16)
    c_lo = dataclasses.replace(CONFIG, node_lat_s=lo, ici_lat_s=lo)
    c_hi = dataclasses.replace(CONFIG, node_lat_s=hi, ici_lat_s=hi)
    t_lo = collective_time("all_reduce", 16e6, 16, fab, algo=algo,
                           config=c_lo)
    t_hi = collective_time("all_reduce", 16e6, 16, fab, algo=algo,
                           config=c_hi)
    assert t_lo <= t_hi * (1.0 + REL)
