"""Sampled-simulation (loop tree) tests — §II-E1 analogue."""
from _hyp import given, settings, st

from repro.core.sampling import (LoopNode, measure_sampled, sampling_error,
                                 unsample)


def test_unsample_linear_exact():
    # cost(n) = startup + n*per_iter must unsample exactly from 2 samples
    fn = lambda n: 7e-6 + n * 3e-4
    node = measure_sampled(fn, trips=1000, sample=2)
    assert sampling_error(unsample(node), fn(1000)) < 1e-9


@given(startup=st.floats(0, 1e-3), per=st.floats(1e-6, 1e-2),
       trips=st.integers(2, 10_000),
       sample=st.integers(2, 64))
@settings(max_examples=60, deadline=None)
def test_unsample_property(startup, per, trips, sample):
    fn = lambda n: startup + n * per
    node = measure_sampled(fn, trips=trips, sample=sample)
    assert sampling_error(unsample(node), fn(trips)) < 1e-6


def test_nested_tree():
    # layers(22) x chunks(8): body 1ms per chunk + 2ms layer overhead
    tree = LoopNode("step", trips=1, children=[
        LoopNode("layers", trips=22, body_cost=2e-3, children=[
            LoopNode("chunks", trips=8, body_cost=1e-3)])])
    assert abs(unsample(tree) - 22 * (2e-3 + 8e-3)) < 1e-12


def test_sampling_factor():
    tree = LoopNode("run", trips=1, children=[
        LoopNode("iters", trips=100, body_cost=1.0, sampled_trips=2)])
    assert abs(tree.sampling_factor() - 50.0) < 1e-9
