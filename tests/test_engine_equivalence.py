"""The O(E log E) engine == the frozen PR-base loop, bit for bit.

Four layers of evidence:

  * seeded random DAGs and chains across interfaces / worker counts /
    contention / host models: Timeline, Breakdown, Roofline, energy and
    makespan all compare with ``==`` (no tolerance) against
    ``tests/_reference_engine.run_reference`` — for the heap event loop
    AND the numpy chain fast path;
  * the homogeneous-topology gate: an explicit ``SoCTopology`` that is
    the homogeneous expansion of a flat config (implicit inheritance AND
    fully spelled-out device/link fields) is bit-identical to the flat
    config — i.e. the per-device/per-link engine degenerates exactly to
    the pre-topology engine;
  * hypothesis property tests drawing arbitrary DAG shapes (skipped
    automatically when hypothesis isn't installed, via ``_hyp``);
  * the acceptance benchmark: a ≥5k-op transformer decode chain swept over
    8 configs through ``sweep()`` must be ≥10x faster than 8 serial
    PR-base runs, with bit-identical results.
"""
import dataclasses
import random
import time

import pytest

from _hyp import given, settings, st
from _reference_engine import run_reference
from repro.configs.gemma_2b import FULL as GEMMA_2B
from repro.sim import engine, hw, ir
from repro.sim.sweep import sweep

CONFIGS = [
    engine.EngineConfig(),
    engine.EngineConfig(n_workers=4, interface="hbm", hbm_ports=2),
    engine.EngineConfig(n_workers=8, interface="dma", hbm_ports=1),
    engine.EngineConfig(n_workers=3, interface="acp", hbm_ports=0.5,
                        host_dispatch_s=1e-6, host_bw=20e9, host_threads=4),
    engine.EngineConfig(n_workers=2, interface="ideal",
                        overlap_transfers=True, host_floor_s=1e-4),
    engine.EngineConfig(n_workers=4, interface="hbm", hbm_ports=4,
                        datapath_scale=0.5, host_dispatch_s=2e-6),
]


def assert_bit_identical(a, b):
    assert a.makespan == b.makespan
    assert a.breakdown == b.breakdown
    assert a.roofline == b.roofline
    assert a.energy == b.energy
    assert a.timeline.events == b.timeline.events


def random_program(rng: random.Random, n: int, chain: bool) -> ir.Program:
    ops = []
    for i in range(n):
        if chain:
            deps = (f"op{i-1}",) if i else ()
            aff = None
        else:
            deps = tuple(f"op{j}" for j in range(max(0, i - 6), i)
                         if rng.random() < 0.35)
            aff = rng.choice([None, None, None, "red0", "red1"])
        ops.append(ir.CostedOp(
            name=f"op{i}",
            flops=rng.choice([0.0, 1e6, 5e8, 2e9]),
            dot_flops=rng.choice([0.0, 1e6, 4e8]),
            bytes_in=rng.choice([0.0, 1e5, 3e7, 2e8]),
            bytes_out=rng.choice([0.0, 1e5, 2e6]),
            collective_bytes=rng.choice([0.0, 0.0, 1e6]),
            wire_bytes=rng.choice([0.0, 2e6]),
            transcendentals=rng.choice([0.0, 1e5]),
            deps=deps,
            affinity=aff,
            phase=f"ph{i % 3}",
            duration_s=rng.choice([None, None, None, 1e-4, 0.0]),
            transfer_s=rng.choice([None, None, None, 0.0, 2e-5])))
    return ir.Program(ops, name="rand")


@pytest.mark.parametrize("chain", [False, True])
def test_engine_matches_reference_on_random_programs(chain):
    rng = random.Random(1234 + chain)
    for _ in range(25):
        prog = random_program(rng, rng.randint(1, 70), chain)
        for cfg in CONFIGS:
            ref = run_reference(prog, cfg, model_flops=1e9)
            new = engine.run(prog, cfg, model_flops=1e9)
            assert_bit_identical(new, ref)


def test_chain_fast_path_equals_event_loop():
    """fast=True (prefix-sum path) and fast=False (heap loop) agree with
    the reference — and with each other — on chains."""
    rng = random.Random(7)
    for _ in range(15):
        prog = random_program(rng, rng.randint(1, 50), chain=True)
        plan = engine.prepare(prog)
        assert plan.is_chain
        for cfg in CONFIGS:
            ref = run_reference(prog, cfg)
            fast = engine.run(prog, cfg, plan=plan, fast=True)
            slow = engine.run(prog, cfg, plan=plan, fast=False)
            assert_bit_identical(fast, ref)
            assert_bit_identical(slow, ref)


def test_fast_path_rejects_non_chain():
    ops = [ir.CostedOp("a", flops=1e6), ir.CostedOp("b", flops=1e6),
           ir.CostedOp("c", flops=1e6, deps=("a", "b"))]
    plan = engine.prepare(ir.Program(ops))
    assert not plan.is_chain


def test_contention_incremental_structure_is_exact():
    """Heavy fan-out with small port count: many overlapping windows, so
    the bisect/expiry structure is exercised past its compaction points."""
    rng = random.Random(99)
    layers, ops, prev_layer = 14, [], []
    for li in range(layers):
        cur = []
        for j in range(rng.randint(4, 24)):
            nm = f"l{li}n{j}"
            deps = tuple(rng.sample(prev_layer,
                                    k=min(len(prev_layer), rng.randint(0, 3))))
            ops.append(ir.CostedOp(nm, flops=rng.choice([1e6, 1e8]),
                                   bytes_in=rng.choice([1e6, 5e7]),
                                   bytes_out=1e6, deps=deps))
            cur.append(nm)
        prev_layer = cur
    prog = ir.Program(ops)
    for ports in (0.5, 1, 2, 4):
        cfg = engine.EngineConfig(n_workers=8, interface="hbm",
                                  hbm_ports=ports)
        assert_bit_identical(engine.run(prog, cfg),
                             run_reference(prog, cfg))


def test_affinity_pinned_expiry_stays_exact():
    """Every op pinned to one of two affinity keys on an 8-worker config:
    six provisioned workers stay idle forever, so window expiry must key on
    the pinned workers' avail (not min over all) to keep compacting — and
    the counts must stay exact through those compactions."""
    rng = random.Random(5)
    ops = []
    for i in range(400):
        deps = (f"op{i-1}",) if i and rng.random() < 0.5 else ()
        ops.append(ir.CostedOp(f"op{i}", flops=rng.choice([1e6, 1e8]),
                               bytes_in=5e7, bytes_out=1e6, deps=deps,
                               affinity="a" if i % 2 else "b"))
    prog = ir.Program(ops)
    for ports in (0.5, 1, 4):
        cfg = engine.EngineConfig(n_workers=8, interface="hbm",
                                  hbm_ports=ports)
        assert_bit_identical(engine.run(prog, cfg),
                             run_reference(prog, cfg))


def _homogeneous_topology(cfg: engine.EngineConfig,
                          explicit: bool) -> hw.SoCTopology:
    """The homogeneous expansion of a flat config, two ways: all fields
    inherited (``SoCTopology.homogeneous``) or every Device/Link field
    spelled out with the flat values."""
    n = max(cfg.n_workers, 1)
    if not explicit:
        return hw.SoCTopology.homogeneous(n)
    devices = tuple(
        hw.Device(f"acc{i}", kind="accel", peak_flops=cfg.peak_flops,
                  datapath_scale=cfg.datapath_scale,
                  interface=cfg.interface, hbm_bw=cfg.hbm_bw,
                  vmem_bw=cfg.vmem_bw, link="hbm")
        for i in range(n))
    return hw.SoCTopology(
        devices=devices,
        links=(hw.Link("hbm", bandwidth=cfg.hbm_bw, ports=cfg.hbm_ports),),
        name="explicit-homog")


@pytest.mark.parametrize("explicit", [False, True])
@pytest.mark.parametrize("chain", [False, True])
def test_homogeneous_topology_bit_identical_to_flat(chain, explicit):
    """The tentpole gate: a homogeneous SoCTopology reproduces the legacy
    flat config bit-for-bit (Timeline/Breakdown/Roofline/energy) on random
    DAGs and chains — so it also equals the frozen PR-base reference."""
    rng = random.Random(4321 + chain)
    for _ in range(10):
        prog = random_program(rng, rng.randint(1, 60), chain)
        for cfg in CONFIGS:
            tcfg = dataclasses.replace(
                cfg, topology=_homogeneous_topology(cfg, explicit))
            assert_bit_identical(engine.run(prog, tcfg),
                                 engine.run(prog, cfg))
            assert_bit_identical(engine.run(prog, tcfg),
                                 run_reference(prog, cfg))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_property_homogeneous_topology_matches_flat(data):
    n = data.draw(st.integers(min_value=1, max_value=40))
    chain = data.draw(st.booleans())
    seed = data.draw(st.integers(min_value=0, max_value=2**20))
    explicit = data.draw(st.booleans())
    prog = random_program(random.Random(seed), n, chain)
    cfg = CONFIGS[data.draw(st.integers(min_value=0,
                                        max_value=len(CONFIGS) - 1))]
    tcfg = dataclasses.replace(cfg,
                               topology=_homogeneous_topology(cfg, explicit))
    assert_bit_identical(engine.run(prog, tcfg), engine.run(prog, cfg))


# ---------------------------------------------------------------------------
# linear-run fusion + typed-array event core: the fused DAG loop is
# bit-identical to the dict-based loop (and hence to the frozen reference)


def _fabric_dag(rng: random.Random) -> ir.Program:
    """Parallel collective lanes over a two-tier fabric: a genuine DAG
    whose ring hops are LPT-neutral linear runs — the fusion target."""
    fab = hw.Fabric.cluster(8)
    kind = rng.choice(["all_reduce", "reduce_scatter", "all_gather"])
    a = ir.from_collective(kind, rng.choice([1e6, 32e6]), (0, 1, 2, 3),
                           fab, prefix="a")
    b = ir.from_collective("all_reduce", rng.choice([4e6, 16e6]),
                           (4, 5, 6, 7), fab, prefix="b")
    return ir.Program(list(a.ops) + list(b.ops), name="lanes")


FABRIC_CONFIGS = [
    engine.EngineConfig(n_workers=4),
    engine.EngineConfig(n_workers=4, ici_bw=10e9, ici_lat_s=2e-6),
    engine.EngineConfig(n_workers=8, node_bw=5e9, node_lat_s=1e-6,
                        interface="hbm", hbm_ports=2),
]


def test_linear_runs_match_compiled_plan():
    """ir.linear_runs (the IR-level view of LPT-neutral hop runs) agrees
    with what the compiled plan actually contracts."""
    rng = random.Random(11)
    for _ in range(5):
        prog = _fabric_dag(rng)
        runs = ir.linear_runs(prog.ops)
        cp = engine.prepare(prog).compiled()
        assert runs and all(len(r) >= 2 for r in runs)
        assert sum(len(r) - 1 for r in runs) == cp.n_run_interior
    # a non-LPT-neutral hop (nonzero flops or pinned duration) can never
    # be part of a run: its priority is config-dependent
    prog = _fabric_dag(random.Random(3))
    ops = list(prog.ops)
    mid = next(i for i, op in enumerate(ops)
               if op.tier is not None and 0 < i < len(ops) - 1)
    heavy = ir.replace(ops[mid], flops=1e9)
    runs = ir.linear_runs(ops[:mid] + [heavy] + ops[mid + 1:])
    assert all(heavy.name not in r for r in runs)
    cp2 = engine.prepare(
        ir.Program(ops[:mid] + [heavy] + ops[mid + 1:])).compiled()
    assert sum(len(r) - 1 for r in runs) == cp2.n_run_interior


def test_fused_loop_equals_dict_loop_on_random_dags():
    rng = random.Random(2025)
    for _ in range(15):
        prog = random_program(rng, rng.randint(2, 60), chain=False)
        plan = engine.prepare(prog)
        for cfg in CONFIGS:
            assert_bit_identical(
                engine.run(prog, cfg, plan=plan, fuse=True),
                engine.run(prog, cfg, plan=plan, fuse=False))


def test_fused_loop_equals_dict_loop_on_fabric_dags():
    rng = random.Random(77)
    for _ in range(6):
        prog = _fabric_dag(rng)
        plan = engine.prepare(prog)
        assert engine.fusion_resolvable(plan)
        for cfg in FABRIC_CONFIGS:
            assert_bit_identical(
                engine.run(prog, cfg, plan=plan, fuse=True),
                engine.run(prog, cfg, plan=plan, fuse=False))


def test_fused_core_matches_frozen_reference():
    """The typed-array core (fuse=True, the default) reproduces the
    frozen PR-base loop bit for bit on flat configs."""
    rng = random.Random(31)
    for _ in range(10):
        prog = random_program(rng, rng.randint(1, 50), chain=False)
        for cfg in CONFIGS:
            assert_bit_identical(engine.run(prog, cfg, fuse=True),
                                 run_reference(prog, cfg))


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_property_fused_matches_unfused(data):
    """Random DAGs x interfaces x (flat | homogeneous-topology) configs,
    plus fabric-lane DAGs: fuse=True == fuse=False, events and all."""
    seed = data.draw(st.integers(min_value=0, max_value=2**20))
    rng = random.Random(seed)
    if data.draw(st.booleans()):
        prog = _fabric_dag(rng)
        cfg = FABRIC_CONFIGS[data.draw(st.integers(
            min_value=0, max_value=len(FABRIC_CONFIGS) - 1))]
    else:
        n = data.draw(st.integers(min_value=2, max_value=40))
        prog = random_program(rng, n, chain=False)
        cfg = CONFIGS[data.draw(st.integers(min_value=0,
                                            max_value=len(CONFIGS) - 1))]
        if data.draw(st.booleans()):
            cfg = dataclasses.replace(
                cfg, topology=_homogeneous_topology(
                    cfg, data.draw(st.booleans())))
    plan = engine.prepare(prog)
    assert_bit_identical(engine.run(prog, cfg, plan=plan, fuse=True),
                         engine.run(prog, cfg, plan=plan, fuse=False))


def test_cycle_still_detected():
    ops = [ir.CostedOp("a", deps=("b",)), ir.CostedOp("b", deps=("a",))]
    with pytest.raises(ValueError):
        engine.run(ir.Program(ops), engine.EngineConfig())
    ops = [ir.CostedOp("r"), ir.CostedOp("a", deps=("r", "b")),
           ir.CostedOp("b", deps=("a",))]
    with pytest.raises(ValueError):
        engine.run(ir.Program(ops), engine.EngineConfig())


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_property_random_dags_match_reference(data):
    n = data.draw(st.integers(min_value=1, max_value=40))
    chain = data.draw(st.booleans())
    seed = data.draw(st.integers(min_value=0, max_value=2**20))
    prog = random_program(random.Random(seed), n, chain)
    idx = data.draw(st.integers(min_value=0, max_value=len(CONFIGS) - 1))
    cfg = CONFIGS[idx]
    assert_bit_identical(engine.run(prog, cfg), run_reference(prog, cfg))


# ---------------------------------------------------------------------------
# acceptance: >=10x on a >=5k-op decode sweep of 8 configs, bit-identical


SWEEP_CONFIGS = [
    engine.EngineConfig(n_workers=1, interface="hbm", hbm_ports=4),
    engine.EngineConfig(n_workers=1, interface="acp", hbm_ports=4),
    engine.EngineConfig(n_workers=2, interface="dma", hbm_ports=4),
    engine.EngineConfig(n_workers=4, interface="hbm", hbm_ports=1,
                        host_dispatch_s=1e-6),
    engine.EngineConfig(n_workers=1, interface="hbm"),
    engine.EngineConfig(n_workers=8, interface="acp", hbm_ports=2,
                        host_dispatch_s=1e-6, host_bw=20e9, host_threads=8),
    engine.EngineConfig(n_workers=1, interface="dma", hbm_ports=4,
                        host_dispatch_s=1e-6),
    engine.EngineConfig(n_workers=2, interface="hbm", hbm_ports=0.5,
                        datapath_scale=0.5),
]


@pytest.mark.slow
def test_sweep_10x_faster_than_serial_reference_and_bit_identical():
    prog = ir.from_decode(GEMMA_2B, n_tokens=640, ops_per_token=8)
    assert len(prog.ops) >= 5000
    # warm both sides (numpy import, allocator) off the clock
    sweep(prog, SWEEP_CONFIGS[:1])
    run_reference(ir.from_decode(GEMMA_2B, n_tokens=2), SWEEP_CONFIGS[0])

    # best-of-3 on the (cheap) sweep side so a transient load spike on a
    # shared box can't sink the measured ratio; the reference side is
    # measured once — noise there only inflates the PR-base time
    t_sweep = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        new = sweep(prog, SWEEP_CONFIGS)
        t_sweep = min(t_sweep, time.perf_counter() - t0)

    t0 = time.perf_counter()
    ref = [run_reference(prog, cfg) for cfg in SWEEP_CONFIGS]
    t_serial = time.perf_counter() - t0

    for a, b in zip(new, ref):
        assert_bit_identical(a, b)
    speedup = t_serial / t_sweep
    assert speedup >= 10.0, (
        f"sweep {t_sweep:.3f}s vs serial PR-base {t_serial:.3f}s "
        f"= {speedup:.1f}x (< 10x)")
