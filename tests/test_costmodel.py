"""The analytic cost model: bit-identical to the engine on chains, a
certified lower/upper bracket on DAGs, and the gradient-DSE layer on top.

The chain tests assert ``==`` (not approx): ``engine._run_chain`` computes
its per-op terms through the very same ``costmodel.chain_terms`` the
batched matrix path evaluates, and numpy's row-wise ``cumsum`` adds in the
same strict left-to-right order as the event loop's ``accumulate`` — so
any drift is a real extraction bug, not float noise."""
import dataclasses

import numpy as np
import pytest

from repro.apps.paper_graphs import build_paper_graph
from repro.configs.gemma_2b import SMOKE
from repro.configs.paper_nets import PAPER_NETS
from repro.core.energy import EnergyModel
from repro.sim import engine, hw, ir
from repro.sim.costmodel import (CHAIN_INTERFACES, CostModel, Unsupported,
                                 _has_jax, relaxation_err)
from repro.sim.hw import (PARAM_FIELDS, SoCTopology, apply_params,
                          params_dict, params_from_config, with_ports)
from repro.sim.sweep import as_records, batched, lower_graph, optimize, sweep
from tests._hyp import given, settings, st

HLO = {"flops": 1e15, "dot_flops": 9e14, "bytes": 1e12,
       "collective_bytes": 1e10, "wire_bytes": 1.5e10,
       "transcendentals": 1e9, "collectives": {}, "n_while": 1,
       "custom_calls": {}}


def _rand_chain(rng, n=24):
    """A serial chain mixing every op flavor the fast path prices:
    derived compute, dot-heavy, collective, explicit duration/transfer."""
    ops, prev = [], ()
    for i in range(n):
        kind = int(rng.integers(0, 5))
        if kind == 0:
            op = ir.CostedOp(f"op{i}", deps=prev,
                             duration_s=float(rng.uniform(1e-6, 1e-3)))
        elif kind == 1:
            op = ir.CostedOp(f"op{i}", deps=prev,
                             collective_bytes=float(rng.uniform(0, 1e8)),
                             wire_bytes=float(rng.uniform(0, 1e8)))
        else:
            op = ir.CostedOp(
                f"op{i}", deps=prev,
                flops=float(rng.uniform(0, 1e12)),
                dot_flops=float(rng.uniform(0, 5e11)),
                bytes_in=float(rng.uniform(0, 1e9)),
                bytes_out=float(rng.uniform(0, 1e8)),
                transcendentals=float(rng.uniform(0, 1e6)),
                transfer_s=(float(rng.uniform(0, 1e-4))
                            if kind == 4 else None))
        ops.append(op)
        prev = (f"op{i}",)
    return ir.Program(ops, name="rand_chain")


def _rand_config(rng, iface):
    return engine.EngineConfig(
        interface=iface,
        n_workers=int(rng.integers(1, 9)),
        peak_flops=float(rng.uniform(1e13, 4e14)),
        datapath_scale=float(rng.choice((1.0, 0.5, 0.25))),
        hbm_bw=float(rng.uniform(1e11, 1.6e12)),
        vmem_bw=float(rng.uniform(1e12, 2e13)),
        ici_bw=float(rng.uniform(1e10, 1e11)),
        hbm_ports=float(rng.choice((0.0, 0.5, 1.0, 2.0, 4.0))),
        host_dispatch_s=float(rng.choice((0.0, 5e-7, 1e-6))),
        host_bw=float(rng.choice((0.0, 2e10))),
        host_threads=int(rng.integers(1, 5)))


# ---------------------------------------------------------------------------
# chains: the model IS the engine fast path, bit for bit


@pytest.mark.parametrize("iface", sorted(CHAIN_INTERFACES))
def test_chain_bit_identical_random_chains(iface):
    rng = np.random.default_rng(hash(iface) % 2**32)
    for trial in range(4):
        prog = _rand_chain(rng)
        assert engine.prepare(prog).is_chain
        cfgs = [_rand_config(rng, iface) for _ in range(6)]
        model = CostModel(prog, cfgs[0], backend="numpy")
        P = np.array([params_from_config(c) for c in cfgs])
        ms = model.makespans(P)
        for got, cfg in zip(ms, cfgs):
            assert float(got) == engine.run(prog, cfg).makespan


@pytest.mark.parametrize("make", [
    lambda: ir.from_decode(SMOKE, n_tokens=12, ops_per_token=4),
    lambda: ir.from_hlo(HLO, n_ops=16),
], ids=["from_decode", "from_hlo"])
def test_chain_bit_identical_real_lowerings(make):
    prog = make()
    assert engine.prepare(prog).is_chain
    rng = np.random.default_rng(3)
    for iface in sorted(CHAIN_INTERFACES):
        cfgs = [_rand_config(rng, iface) for _ in range(4)]
        bs = batched(prog, cfgs, top_k=len(cfgs))
        assert bs.is_chain and bs.backend == "numpy"
        for v in bs.verified:
            assert v["relaxation_err"] == 0.0
            assert v["analytic_s"] == v["exact_s"]
        np.testing.assert_array_equal(bs.lower, bs.upper)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1),
       st.sampled_from(sorted(CHAIN_INTERFACES)))
def test_chain_bit_identical_property(seed, iface):
    rng = np.random.default_rng(seed)
    prog = _rand_chain(rng, n=int(rng.integers(1, 16)))
    cfg = _rand_config(rng, iface)
    model = CostModel(prog, cfg, backend="numpy")
    assert model.makespan() == engine.run(prog, cfg).makespan


def test_empty_program_is_zero():
    prog = ir.Program([], name="empty")
    model = CostModel(prog, engine.EngineConfig(), backend="numpy")
    assert model.makespan() == 0.0
    lo, up = model.bounds(np.array([model.params0]))
    assert lo[0] == 0.0 and up[0] == 0.0


# ---------------------------------------------------------------------------
# DAGs: certified lower <= exact <= upper


def test_dag_bounds_bracket_tile_graph():
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    dag = lower_graph(g, batch=1, max_tile_elems=2048)
    assert not engine.prepare(dag).is_chain
    rng = np.random.default_rng(11)
    for iface in sorted(CHAIN_INTERFACES):
        cfgs = [dataclasses.replace(_rand_config(rng, iface), n_workers=nw)
                for nw in (1, 2, 8) for _ in range(2)]
        for cfg in cfgs:
            model = CostModel(dag, cfg, backend="numpy")
            lo, up = model.bounds(np.array([params_from_config(cfg)]))
            exact = engine.run(dag, cfg).makespan
            assert lo[0] <= exact * (1 + 1e-12), (iface, cfg)
            assert exact <= up[0] * (1 + 1e-12), (iface, cfg)
            err = relaxation_err(engine.run(dag, cfg))
            assert err is not None and err <= 1e-12


def test_dag_single_worker_serial_chain_collapses():
    """On one worker with no contention (ports=0) an embarrassingly
    parallel DAG is priced exactly: every op runs back to back, so the
    work bound meets the serial sum and lower == exact == upper."""
    ops = [ir.CostedOp(f"op{i}", duration_s=1e-4) for i in range(8)]
    prog = ir.Program(ops, name="par8")
    cfg = engine.EngineConfig(n_workers=1, interface="ideal")
    model = CostModel(prog, cfg, backend="numpy")
    lo, up = model.bounds(np.array([params_from_config(cfg)]))
    exact = engine.run(prog, cfg).makespan
    assert lo[0] == pytest.approx(exact, rel=1e-12)
    assert up[0] == pytest.approx(exact, rel=1e-12)


# ---------------------------------------------------------------------------
# jax backend: same terms, float32 jit+vmap (allclose, not bit-equal)


@pytest.mark.skipif(not _has_jax(), reason="jax not importable")
def test_jax_chain_matches_numpy():
    prog = ir.from_decode(SMOKE, n_tokens=16, ops_per_token=4)
    rng = np.random.default_rng(5)
    cfgs = [_rand_config(rng, "hbm") for _ in range(8)]
    P = np.array([params_from_config(c) for c in cfgs])
    m_np = CostModel(prog, cfgs[0], backend="numpy")
    m_jx = CostModel(prog, cfgs[0], backend="jax")
    np.testing.assert_allclose(m_jx.makespans(P), m_np.makespans(P),
                               rtol=1e-4)


@pytest.mark.skipif(not _has_jax(), reason="jax not importable")
def test_jax_gradient_agrees_with_finite_differences():
    prog = ir.from_decode(SMOKE, n_tokens=8, ops_per_token=4)
    space = {"peak_flops": (1e13, 4e14), "hbm_bw": (1e11, 1.6e12)}
    o_jx = CostModel(prog, backend="jax").objective(space)
    o_np = CostModel(prog, backend="numpy").objective(space)
    assert o_jx.backend == "jax" and o_np.backend == "numpy"
    Z = np.array([[0.3, 0.7], [0.5, 0.5], [0.9, 0.1]])
    np.testing.assert_allclose(o_jx.grad(Z), o_np.grad(Z),
                               rtol=5e-2, atol=1e-3)


def test_jax_backend_rejects_dags():
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    dag = lower_graph(g, batch=1, max_tile_elems=2048)
    with pytest.raises(Unsupported):
        CostModel(dag, backend="jax")


# ---------------------------------------------------------------------------
# optimize(): the returned design is exact-verified and competitive


def test_optimize_latency_hits_grid_best():
    prog = ir.from_decode(SMOKE, n_tokens=12, ops_per_token=4)
    base = engine.EngineConfig(interface="hbm", host_dispatch_s=1e-6)
    space = {"peak_flops": (1e13, 4e14), "hbm_bw": (1e11, 1.6e12)}
    grid = [apply_params(base, {"peak_flops": p, "hbm_bw": b})
            for p in np.geomspace(1e13, 4e14, 8)
            for b in np.geomspace(1e11, 1.6e12, 8)]
    grid_best = min(r.makespan for r in sweep(prog, grid))
    opt = optimize(prog, space, base_config=base, n_starts=4, steps=40,
                   seed=0, backend="numpy")
    assert opt.exact_s <= grid_best * 1.02
    assert opt.relaxation_err == 0.0        # chain: model == engine
    assert opt.feasible is None and opt.n_evals > 0


def test_optimize_target_mode_prefers_feasible_cheap_designs():
    prog = ir.from_decode(SMOKE, n_tokens=12, ops_per_token=4)
    base = engine.EngineConfig(interface="hbm", host_dispatch_s=1e-6)
    space = {"peak_flops": (1e13, 4e14), "hbm_bw": (1e11, 1.6e12)}
    lo = engine.run(prog, apply_params(base, {"peak_flops": 1e13,
                                              "hbm_bw": 1e11})).makespan
    hi = engine.run(prog, apply_params(base, {"peak_flops": 4e14,
                                              "hbm_bw": 1.6e12})).makespan
    target = float(np.sqrt(lo * hi))        # feasibility is nontrivial
    opt = optimize(prog, space, base_config=base, target_s=target,
                   n_starts=6, steps=40, seed=0, backend="numpy")
    assert opt.feasible is True
    assert opt.exact_s <= target * (1 + 1e-9)
    # cheaper than the max-hardware corner (mean z strictly below 1)
    assert opt.objective < 1.0
    assert opt.candidates and opt.candidates[0]["config"] is opt.config


def test_optimize_rejects_topologies_and_unknown_fields():
    prog = ir.from_decode(SMOKE, n_tokens=4, ops_per_token=2)
    topo_cfg = engine.EngineConfig(topology=SoCTopology.homogeneous(2))
    with pytest.raises(Unsupported):
        optimize(prog, {"hbm_bw": (1e11, 1e12)}, base_config=topo_cfg)
    with pytest.raises(ValueError):
        optimize(prog, {"warp_speed": (1.0, 2.0)})


# ---------------------------------------------------------------------------
# parameter-vector mapping (hw.py)


def test_params_roundtrip():
    cfg = engine.EngineConfig(peak_flops=1e14, hbm_ports=2.0,
                              host_dispatch_s=1e-6)
    vec = params_from_config(cfg)
    assert len(vec) == len(PARAM_FIELDS)
    again = apply_params(engine.EngineConfig(), vec)
    assert params_from_config(again) == vec
    # partial mapping touches only the named fields
    bumped = apply_params(cfg, {"hbm_bw": 5e11})
    assert bumped.hbm_bw == 5e11 and bumped.peak_flops == cfg.peak_flops


def test_params_dict_validates():
    with pytest.raises(ValueError):
        params_dict({"not_a_knob": 1.0})
    with pytest.raises(ValueError):
        params_dict([1.0, 2.0])             # wrong length vector


def test_with_ports_rewrites_every_link():
    topo = SoCTopology.homogeneous(4)       # implicit shared link
    t2 = with_ports(topo, 2.0)
    assert t2.links and all(l.ports == 2.0 for l in t2.links)
    two = SoCTopology(devices=topo.devices,
                      links=(hw.Link("a", ports=1.0), hw.Link("b")))
    t3 = with_ports(two, 0.5)
    assert [l.ports for l in t3.links] == [0.5, 0.5]


# ---------------------------------------------------------------------------
# Unsupported boundaries: the event engine stays the universal path


def test_custom_interface_is_unsupported_but_still_runs():
    engine.INTERFACES["probe-iface"] = lambda nbytes, cfg: (nbytes / 1e9,
                                                            0.0)
    try:
        prog = ir.from_decode(SMOKE, n_tokens=4, ops_per_token=2)
        cfg = engine.EngineConfig(interface="probe-iface")
        with pytest.raises(Unsupported):
            CostModel(prog, cfg)
        res = engine.run(prog, cfg)         # event loop still prices it
        assert res.makespan > 0
        assert relaxation_err(res) is None
    finally:
        del engine.INTERFACES["probe-iface"]


def test_custom_energy_model_is_unsupported():
    class Doubled(EnergyModel):
        pass

    cfg = engine.EngineConfig(energy=Doubled())
    prog = ir.from_decode(SMOKE, n_tokens=4, ops_per_token=2)
    with pytest.raises(Unsupported):
        CostModel(prog, cfg)


def test_heterogeneous_topology_is_unsupported():
    topo = SoCTopology(devices=(hw.Device("big", peak_flops=2e14),
                                hw.Device("small", peak_flops=5e13)))
    prog = ir.from_decode(SMOKE, n_tokens=4, ops_per_token=2)
    with pytest.raises(Unsupported):
        CostModel(prog, engine.EngineConfig(topology=topo))


def test_unknown_backend_rejected():
    prog = ir.from_decode(SMOKE, n_tokens=4, ops_per_token=2)
    with pytest.raises(ValueError):
        CostModel(prog, backend="abacus")


# ---------------------------------------------------------------------------
# record plumbing


def test_as_records_relaxation_err_column():
    prog = ir.from_decode(SMOKE, n_tokens=8, ops_per_token=4)
    rows = as_records(sweep(prog, [engine.EngineConfig(),
                                   engine.EngineConfig(interface="dma")]))
    assert all(row["relaxation_err"] == 0.0 for row in rows)
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    dag = lower_graph(g, batch=1, max_tile_elems=2048)
    rows = as_records(sweep(dag, [engine.EngineConfig(n_workers=4)]))
    assert rows[0]["relaxation_err"] <= 1e-12


def test_batched_records_and_best():
    prog = ir.from_decode(SMOKE, n_tokens=8, ops_per_token=4)
    cfgs = [engine.EngineConfig(peak_flops=p)
            for p in (5e13, 1e14, 2e14, 4e14)]
    bs = batched(prog, cfgs, top_k=2)
    recs = bs.records()
    assert len(recs) == len(cfgs)
    exact_rows = [r for r in recs if r["exact_s"] is not None]
    assert len(exact_rows) == 2
    assert bs.best()["exact_s"] == min(r["exact_s"] for r in exact_rows)
    assert bs.top(1) == [int(np.argmin(bs.makespans))]
    empty = batched(prog, [], top_k=3)
    assert empty.records() == [] and len(empty.makespans) == 0
    with pytest.raises(ValueError):
        batched(prog, cfgs, top_k=0).best()


# ---------------------------------------------------------------------------
# collectives in the analytic model: chain exactness, the DAG bracket,
# and the batched winner on a collective-bearing grid


def _collective_chain(dp=4):
    """Single-stage training chain whose dp gradient all-reduce lowers
    to ring hops on a single-tier fabric — still chain-shaped, so the
    analytic fast path must price it bit-identically."""
    return ir.from_training_step(SMOKE, seq_len=128, batch=4,
                                 dp_degree=dp,
                                 fabric=hw.Fabric.single_tier(dp))


def test_collective_chain_bit_identical():
    prog = _collective_chain()
    assert engine.prepare(prog).is_chain
    cfgs = [engine.EngineConfig(),
            engine.EngineConfig(ici_bw=10e9),
            engine.EngineConfig(ici_lat_s=5e-6),
            engine.EngineConfig(ici_bw=200e9, ici_lat_s=1e-6,
                                peak_flops=5e13)]
    model = CostModel(prog, cfgs[0], backend="numpy")
    P = np.array([params_from_config(c) for c in cfgs])
    for got, cfg in zip(model.makespans(P), cfgs):
        assert float(got) == engine.run(prog, cfg).makespan


def test_collective_chain_multi_tier_bit_identical():
    """A node-tier-spanning ring: the chain fast path must charge the
    NODE latency/bandwidth fields, not the ici lane."""
    fab = hw.Fabric.cluster(8)          # 4ici x 2node
    prog = ir.from_collective("all_reduce", 64e6, 8, fab)
    assert engine.prepare(prog).is_chain
    cfgs = [engine.EngineConfig(node_bw=b, node_lat_s=l)
            for b, l in ((25e9, 0.0), (5e9, 1e-6), (100e9, 4e-6))]
    model = CostModel(prog, cfgs[0], backend="numpy")
    P = np.array([params_from_config(c) for c in cfgs])
    for got, cfg in zip(model.makespans(P), cfgs):
        exact = engine.run(prog, cfg).makespan
        assert float(got) == exact
        # and the node fields actually bite: recompute by hand
        assert exact == pytest.approx(
            2 * 7 * (cfg.node_lat_s + (64e6 / 8) / cfg.node_bw),
            rel=1e-12)


def test_dag_bounds_bracket_collectives():
    """lower <= exact <= upper on DAGs whose collectives run on several
    parallel lanes (hierarchical sub-group rings)."""
    fab = hw.Fabric.cluster(16)
    progs = [
        ir.from_collective("all_reduce", 64e6, 16, fab,
                           algo="hierarchical"),
        ir.Program(
            list(ir.from_collective("all_reduce", 32e6, (0, 1, 2, 3),
                                    fab, prefix="a").ops)
            + list(ir.from_collective("all_reduce", 32e6, (4, 5, 6, 7),
                                      fab, prefix="b").ops),
            name="parallel-lanes"),
    ]
    cfg = engine.EngineConfig(ici_lat_s=1e-6, n_workers=4)
    for prog in progs:
        exact = engine.run(prog, cfg).makespan
        model = CostModel(prog, cfg, backend="numpy")
        lo, up = model.bounds(np.array([model.params0]))
        assert lo[0] <= exact * (1 + 1e-12)
        assert exact <= up[0] * (1 + 1e-12)
        assert lo[0] > 0.0


def test_batched_winner_matches_exact_on_collective_grid():
    """sweep.batched over a grid varying the FABRIC rate fields picks the
    same winner the engine picks (exact on chains)."""
    fab = hw.Fabric.cluster(8)
    prog = ir.Program(
        list(ir.from_training_step(SMOKE, seq_len=128, batch=4).ops)
        + list(ir.from_collective("all_reduce", 256e6, 8, fab,
                                  deps=("train/update",),
                                  prefix="grad").ops),
        name="train+node-ring")
    assert engine.prepare(prog).is_chain
    cfgs = [engine.EngineConfig(node_bw=b, node_lat_s=l)
            for b in (5e9, 25e9, 100e9) for l in (0.0, 2e-6)]
    bs = batched(prog, cfgs, top_k=len(cfgs))
    exact = [engine.run(prog, c).makespan for c in cfgs]
    assert bs.top(1) == [int(np.argmin(exact))]
    for v in bs.verified:
        assert v["analytic_s"] == v["exact_s"]


def test_fabric_overrides_are_unsupported_in_the_analytic_model():
    """Explicit per-tier rates live outside the PARAM_FIELDS vector: the
    analytic layer must refuse (and the engine still runs them)."""
    fab = hw.Fabric(tiers=(hw.FabricTier("ici", 8, bandwidth=99e9),))
    cfg = engine.EngineConfig(fabric=fab)
    prog = ir.from_collective("all_reduce", 1e6, 8, fab)
    with pytest.raises(Unsupported):
        CostModel(prog, cfg, backend="numpy")
    assert engine.run(prog, cfg).makespan > 0.0
